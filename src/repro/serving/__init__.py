"""Asynchronous shape-bucketed BLAS L3 serving on top of the ADSALA runtime.

    BlasService — submit()/call() front-end, scheduler + bounded worker pool
    ServeConfig — bucket/flush knobs (max_batch, linger_ms, workers, ...)
    ServeStats  — service-level counters (per-bucket detail on the runtime)
    Retuner     — drift-aware online retraining loop (opt-in; pass one to
                  BlasService to close the serving→install feedback loop)
    FaultPlan   — deterministic seeded fault injection (chaos harness); give
                  one plan to the service/runtime/retuner to drive every
                  failure path on purpose

Failure semantics: every submitted request resolves — result, or a typed
error (ServiceClosedError / DeadlineExpiredError / ExecutionFailedError).
See ``repro/serving/service.py`` for the life-of-a-request diagram and the
degradation ladder, ``repro/serving/retune.py`` for the drift/refit/hot-swap
semantics, ``repro/serving/faults.py`` for the named injection sites, and
``benchmarks/chaos_bench.py`` for the seeded fault scenarios.
"""

from .faults import FaultPlan, FaultSpec, InjectedFault
from .retune import Retuner, RetuneConfig, RetuneStats
from .service import (BlasService, DeadlineExpiredError, ExecutionFailedError,
                      ServeConfig, ServeStats, ServiceClosedError, bucket_key)

__all__ = ["BlasService", "ServeConfig", "ServeStats", "bucket_key",
           "Retuner", "RetuneConfig", "RetuneStats",
           "FaultPlan", "FaultSpec", "InjectedFault",
           "ServiceClosedError", "DeadlineExpiredError",
           "ExecutionFailedError"]
