"""Asynchronous shape-bucketed BLAS L3 serving on top of the ADSALA runtime.

    BlasService — submit()/call() front-end, scheduler + bounded worker pool
    ServeConfig — bucket/flush knobs (max_batch, linger_ms, workers, ...)
    ServeStats  — service-level counters (per-bucket detail on the runtime)
    Retuner     — drift-aware online retraining loop (opt-in; pass one to
                  BlasService to close the serving→install feedback loop)

See ``repro/serving/service.py`` for the life-of-a-request diagram,
``repro/serving/retune.py`` for the drift/refit/hot-swap semantics, and
``benchmarks/serve_bench.py`` for the batched-vs-unbatched load harness.
"""

from .retune import Retuner, RetuneConfig, RetuneStats
from .service import BlasService, ServeConfig, ServeStats, bucket_key

__all__ = ["BlasService", "ServeConfig", "ServeStats", "bucket_key",
           "Retuner", "RetuneConfig", "RetuneStats"]
