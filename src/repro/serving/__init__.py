"""Asynchronous shape-bucketed BLAS L3 serving on top of the ADSALA runtime.

    BlasService — submit()/call() front-end, scheduler + bounded worker pool
    FleetService — the same front-end sharded over N executor *processes*
                  (shared-journal decision coherence, fingerprint-resolved
                  artifacts; see ``repro/serving/fleet.py``)
    ServeConfig — bucket/flush knobs (max_batch, linger_ms, workers, ...)
    ServeStats  — service-level counters (per-bucket detail on the runtime)
    Retuner     — drift-aware online retraining loop (opt-in; pass one to
                  BlasService to close the serving→install feedback loop)
    ErrorBudgetLedger — per-(backend, op) rolling failure budgets gating the
                  degradation ladder (over-budget rungs skip their retries)
    FaultPlan   — deterministic seeded fault injection (chaos harness); give
                  one plan to the service/runtime/retuner to drive every
                  failure path on purpose

Failure semantics: every submitted request resolves — result, or a typed
error (ServiceClosedError / DeadlineExpiredError / ExecutionFailedError);
overload is shed synchronously at submit with AdmissionRejectedError.
See ``repro/serving/service.py`` for the life-of-a-request diagram and the
budget-gated degradation ladder, ``repro/serving/budget.py`` for the error
budgets, ``repro/serving/retune.py`` for the drift/refit/hot-swap
semantics, ``repro/serving/faults.py`` for the named injection sites, and
``benchmarks/chaos_bench.py`` / ``benchmarks/recovery_bench.py`` for the
seeded fault and crash-recovery scenarios.
"""

from .budget import BudgetConfig, ErrorBudgetLedger
from .faults import FaultPlan, FaultSpec, InjectedFault
from .fleet import ExecutorDiedError, FleetConfig, FleetService
from .retune import Retuner, RetuneConfig, RetuneStats
from .service import (AdmissionRejectedError, BlasService,
                      DeadlineExpiredError, ExecutionFailedError,
                      ServeConfig, ServeStats, ServiceClosedError, bucket_key)

__all__ = ["BlasService", "ServeConfig", "ServeStats", "bucket_key",
           "FleetService", "FleetConfig", "ExecutorDiedError",
           "Retuner", "RetuneConfig", "RetuneStats",
           "BudgetConfig", "ErrorBudgetLedger",
           "FaultPlan", "FaultSpec", "InjectedFault",
           "ServiceClosedError", "DeadlineExpiredError",
           "ExecutionFailedError", "AdmissionRejectedError"]
