"""Asynchronous shape-bucketed BLAS L3 serving on top of the ADSALA runtime.

    BlasService — submit()/call() front-end, scheduler + bounded worker pool
    ServeConfig — bucket/flush knobs (max_batch, linger_ms, workers, ...)
    ServeStats  — service-level counters (per-bucket detail on the runtime)

See ``repro/serving/service.py`` for the life-of-a-request diagram and
``benchmarks/serve_bench.py`` for the batched-vs-unbatched load harness.
"""

from .service import BlasService, ServeConfig, ServeStats, bucket_key

__all__ = ["BlasService", "ServeConfig", "ServeStats", "bucket_key"]
