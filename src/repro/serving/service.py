"""Shape-bucketed asynchronous BLAS L3 serving (BLASX-style batching on top
of the ADSALA runtime).

The paper's runtime (Fig. 1b) decides a knob per *single* call.  Under
serving traffic the same handful of shapes repeats across many concurrent
requests, so the profitable unit of work is the *bucket*: all pending
requests with identical ``(backend, op, dtype_bytes, dims)`` — the same key
the runtime's decision cache uses — stacked along a new leading axis and
executed as ONE call through :func:`repro.kernels.ops.run_op`.  One ML knob
selection then amortises over the whole bucket, and the backend sees a
single stacked launch instead of B dispatches.

Life of a request::

    submit() ──► bucket[(backend, op, bytes, dims, extra)] ─┐
                                                            │ full (max_batch)
    scheduler thread: linger-deadline watch ────────────────┤ or aged (linger)
                                                            ▼
    ready queue ──► worker pool (bounded) ──► run_op(stacked) ──► futures

Flush policy is per bucket: a bucket flushes when it holds ``max_batch``
requests (size trigger, checked at submit) or when its oldest request has
waited ``linger_ms`` (time trigger, checked by the scheduler thread).
``max_pending`` bounds the number of in-flight requests — ``submit`` blocks
once the bound is hit, which is the service's backpressure signal.

The hot submit path stays cheap on purpose: one mutex acquisition, no
broadcast.  Workers block on the ready *queue* (not a shared condition), the
scheduler sleeps on an event it only needs when a bucket is *opened*, and
completion broadcasts fire per batch, not per request.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Optional, Sequence

import numpy as np

from repro.core.runtime import AdsalaRuntime, global_runtime

__all__ = ["BlasService", "ServeConfig", "ServeStats", "bucket_key"]

#: ops the service accepts (import-light mirror of backends.L3_OPS)
SERVABLE_OPS = ("gemm", "symm", "syrk", "syr2k", "trmm", "trsm")

#: lazily bound repro.backends.resolve_backend (keeps the serving module's
#: import graph light; the backends package pulls in jax)
_resolve_backend = None


def _backend_resolver():
    global _resolve_backend
    if _resolve_backend is None:
        from repro.backends import resolve_backend
        _resolve_backend = resolve_backend
    return _resolve_backend


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Bucket/flush knobs of the serving layer."""
    backend: str = "pallas"       # default execution backend for submit()
    max_batch: int = 32           # size trigger: flush a full bucket at once
    linger_ms: float = 2.0        # time trigger: max wait of a bucket's head
    workers: int = 2              # bounded executor pool size
    max_pending: int = 1024       # backpressure: submit() blocks beyond this
    pad_batches: bool = True      # pad stacks to power-of-two widths so jit
                                  # backends reuse one executable per width
    min_steal: Optional[int] = None   # smallest bucket an *idle* worker may
                                  # flush before its linger expires (work-
                                  # conserving scheduling); None = max_batch/2
    trace_batching: bool | str = False
                                  # "auto"/True: install the process-wide
                                  # trace-time decision batcher
                                  # (ops.trace_batching) around the worker
                                  # pool for the service's lifetime, so
                                  # buckets whose workers trace new shapes
                                  # concurrently batch their uncached knob
                                  # decisions through ONE select_many call.
                                  # Scoped: the previous batcher (usually
                                  # none) is restored on close().  Off by
                                  # default — the combining window adds its
                                  # linger (sub-ms) to every COLD trace, a
                                  # poor trade when traffic is single-
                                  # threaded or shapes rarely repeat.

    def __post_init__(self) -> None:
        if self.trace_batching not in (True, False, "auto"):
            # any other string ("off", "no", ...) would truthiness-enable
            # the batcher — the exact opposite of the author's intent
            raise ValueError('trace_batching must be True, False, or "auto"')
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.workers < 1:
            raise ValueError("workers must be >= 1")
        if self.linger_ms < 0:
            raise ValueError("linger_ms must be >= 0")
        if self.max_pending < 1:
            raise ValueError("max_pending must be >= 1")


@dataclasses.dataclass
class ServeStats:
    """Service-level aggregates; per-bucket detail lives in
    ``runtime.stats.buckets`` (see :meth:`BlasService.bucket_stats`).

    End-to-end latency is split into its two phases: ``queue_sum`` is time
    spent parked in a bucket (linger/backlog — a batching-policy artifact),
    ``exec_sum`` is time inside the stacked ``run_op`` call.  The split is
    load-bearing: the online retuner compares *execution* time against the
    model's predictions, and a span that silently included scheduler wait
    would read as drift whenever the flush policy lingered."""
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    batches: int = 0
    max_batch: int = 0
    padded_items: int = 0         # filler rows added for canonical widths
    latency_sum: float = 0.0      # submit→result, seconds, completed only
    queue_sum: float = 0.0        # submit→execution-start (bucket wait)
    exec_sum: float = 0.0         # per-request share: its batch's exec span

    @property
    def mean_batch(self) -> float:
        done = self.completed + self.failed
        return done / self.batches if self.batches else 0.0

    @property
    def mean_latency(self) -> float:
        return self.latency_sum / self.completed if self.completed else 0.0

    @property
    def mean_queue_latency(self) -> float:
        return self.queue_sum / self.completed if self.completed else 0.0

    @property
    def mean_exec_latency(self) -> float:
        return self.exec_sum / self.completed if self.completed else 0.0


def bucket_key(op: str, shapes: Sequence[tuple[int, ...]], dtypes,
               backend: str, extra: tuple = ()) -> tuple:
    """The grouping key: runtime decision-cache key + dtypes + scalar-kwargs.

    Requests in one bucket must be exchangeable under a single stacked call,
    so anything that changes semantics splits the bucket: the exact dtype
    *name* of every operand (itemsize alone would stack float32 with int32,
    and operand 0 alone would miss a mixed-precision second operand — both
    silently promote under np.stack) and any scalar kwargs (alpha, beta) —
    two alphas never share a stack.  The first four fields remain the
    runtime decision-cache key.
    """
    from repro.kernels.ops import dims_of
    names = tuple(np.dtype(d).name for d in dtypes)
    return (backend, op, int(np.dtype(dtypes[0]).itemsize),
            dims_of(op, tuple(shapes)), names, extra)


@dataclasses.dataclass
class _Request:
    op: str
    operands: tuple
    kw: dict
    future: Future
    t_submit: float


class _Bucket:
    __slots__ = ("key", "requests", "t_head")

    def __init__(self, key: tuple, t_head: float) -> None:
        self.key = key
        self.requests: list[_Request] = []
        self.t_head = t_head          # monotonic enqueue time of the head


class BlasService:
    """Asynchronous shape-bucketed BLAS front-end over an ADSALA runtime.

    ``submit`` returns a :class:`concurrent.futures.Future`; buckets are
    executed by a bounded worker pool as single stacked ``run_op`` calls.
    Pass a :class:`~repro.core.registry.ModelRegistry` to warm-start the
    runtime's decision cache on startup and persist it on ``close`` — a
    restarted server then re-serves previously seen shapes with zero model
    evaluations.

    Usage::

        with BlasService(runtime=rt, config=ServeConfig(max_batch=16)) as s:
            futs = [s.submit("gemm", (a, b)) for a, b in work]
            outs = [f.result() for f in futs]
    """

    def __init__(self, *, runtime: Optional[AdsalaRuntime] = None,
                 config: Optional[ServeConfig] = None,
                 registry=None, retuner=None) -> None:
        self.runtime = runtime if runtime is not None else global_runtime()
        self.config = config if config is not None else ServeConfig()
        self.registry = registry
        self.stats = ServeStats()
        self.warm_started = 0
        if registry is not None:
            self.warm_started = registry.load_decision_cache(self.runtime)
        # optional online feedback loop (repro.serving.retune.Retuner):
        # started once the workers are up, stopped before the decision
        # cache is persisted on close so the saved cache reflects the final
        # artifact generations.  Omit it (the default) for reproducibility
        # runs.
        self.retuner = retuner

        # scoped trace-time decision batcher (ServeConfig.trace_batching):
        # entered before the workers start, exited (previous batcher
        # restored) after they stop
        self._trace_cm = None
        self.trace_batcher = None
        if self.config.trace_batching:
            from repro.kernels.ops import trace_batching
            self._trace_cm = trace_batching()
            self.trace_batcher = self._trace_cm.__enter__()
        try:
            self._start()
        except BaseException:
            # never leak the process-global batcher if startup fails
            if self._trace_cm is not None:
                self._trace_cm.__exit__(None, None, None)
                self._trace_cm = None
            raise
        if self.retuner is not None:
            self.retuner.start()

    def _start(self) -> None:
        self._mutex = threading.Lock()
        self._done = threading.Condition(self._mutex)   # batch completions
        self._buckets: dict[tuple, _Bucket] = {}
        self._ready: "queue.Queue[Optional[_Bucket]]" = queue.Queue()
        self._wake = threading.Event()    # scheduler: new bucket opened
        self._pending = 0                 # submitted, result not yet set
        self._closed = False

        self._scheduler = threading.Thread(
            target=self._scheduler_loop, name="blas-serve-scheduler",
            daemon=True)
        self._workers = [
            threading.Thread(target=self._worker_loop,
                             name=f"blas-serve-worker-{i}", daemon=True)
            for i in range(self.config.workers)]
        self._scheduler.start()
        for w in self._workers:
            w.start()

    # -- submission -----------------------------------------------------------
    def submit(self, op: str, operands: tuple, *,
               backend: Optional[str] = None, **kw) -> Future:
        """Enqueue one BLAS call; returns a Future resolving to its result.

        Blocks (backpressure) while ``max_pending`` requests are in flight.
        """
        if op not in SERVABLE_OPS:
            raise ValueError(f"unknown op {op!r}; servable: {SERVABLE_OPS}")
        operands = tuple(np.asarray(x) for x in operands)
        if any(x.ndim != 2 for x in operands):
            raise ValueError("submit takes one 2-D problem per request; "
                             "stacking is the service's job")
        be = backend or self.config.backend
        key = bucket_key(op, [x.shape for x in operands],
                         [x.dtype for x in operands], be,
                         tuple(sorted(kw.items())))
        now = time.monotonic()
        req = _Request(op=op, operands=operands, kw=kw, future=Future(),
                       t_submit=now)
        with self._mutex:
            if self._closed:
                raise RuntimeError("service is closed")
            while self._pending >= self.config.max_pending:
                self._done.wait(0.05)
                if self._closed:
                    raise RuntimeError("service is closed")
            self._pending += 1
            self.stats.submitted += 1
            bucket = self._buckets.get(key)
            opened = bucket is None
            if opened:
                bucket = self._buckets[key] = _Bucket(key, now)
            bucket.requests.append(req)
            if len(bucket.requests) >= self.config.max_batch:
                del self._buckets[key]
                self._ready.put(bucket)
                opened = False            # flushed already; no linger watch
        if opened:
            self._wake.set()
        return req.future

    def call(self, op: str, operands: tuple, *,
             backend: Optional[str] = None, **kw):
        """Synchronous convenience wrapper: ``submit(...).result()``."""
        return self.submit(op, operands, backend=backend, **kw).result()

    def flush(self) -> None:
        """Force every pending bucket onto the execution queue now."""
        with self._mutex:
            buckets = [self._buckets.pop(key) for key in list(self._buckets)]
        self._prewarm(buckets)
        for b in buckets:
            self._ready.put(b)

    # -- batched knob prewarm -------------------------------------------------
    def _prewarm(self, buckets: list) -> None:
        """One batched knob selection (``AdsalaRuntime.select_many``) for a
        set of buckets about to execute: all uncached decisions share a
        single fused feature-build + model-predict call instead of one
        model evaluation per bucket inside the workers.  Keys are selected
        under the backend name the executor will resolve to, so the
        workers' own selections become cache hits.  Prewarm lookups of
        already-cached keys stay out of the hit statistics
        (``record_hits=False``) — only the executors' selections count as
        traffic.  Best-effort — any failure just leaves the decisions to
        the executors."""
        if len(buckets) < 2:
            return                    # a lone bucket gains nothing
        requests = []
        for b in buckets:
            backend, op, dtype_bytes, dims = b.key[:4]
            try:
                backend = _backend_resolver()(backend).name
            except Exception:        # noqa: BLE001 — unresolvable backend
                continue
            requests.append((op, dims, dtype_bytes, backend))
        if len(requests) >= 2:
            try:
                self.runtime.select_many(requests, record_hits=False)
            except Exception:        # noqa: BLE001 — executors still select
                pass

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Flush and wait until no request is in flight; True on success."""
        deadline = None if timeout is None else time.monotonic() + timeout
        self.flush()
        with self._mutex:
            while self._pending > 0:
                if deadline is not None and time.monotonic() >= deadline:
                    return False
                self._done.wait(0.05)
        return True

    # -- stats ----------------------------------------------------------------
    def bucket_stats(self) -> dict[tuple, object]:
        """Per-bucket serving stats recorded on the runtime, keyed
        ``(backend, op, dtype_bytes, dims)``."""
        return self.runtime.stats.buckets    # stats snapshots under its lock

    # -- lifecycle ------------------------------------------------------------
    def close(self, timeout: float = 30.0) -> None:
        """Drain in-flight work, persist the decision cache (when a registry
        was given), and stop the threads.  Idempotent.

        New submissions are rejected *before* the drain starts — otherwise a
        submit racing the shutdown could park a request in a bucket no
        scheduler or worker would ever flush."""
        with self._mutex:
            if self._closed:
                return
            self._closed = True
            self._done.notify_all()
        self.drain(timeout=timeout)
        self._wake.set()
        for _ in self._workers:
            self._ready.put(None)         # worker shutdown sentinels
        self._scheduler.join(timeout=5.0)
        for w in self._workers:
            w.join(timeout=5.0)
        if self._trace_cm is not None:      # restore the previous batcher
            self._trace_cm.__exit__(None, None, None)
            self._trace_cm = None
        if self.retuner is not None:        # before the cache is persisted:
            self.retuner.stop()             # no swap may race the export
        if self.registry is not None:
            self.registry.save_decision_cache(self.runtime)

    def __enter__(self) -> "BlasService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- scheduler / workers --------------------------------------------------
    def _scheduler_loop(self) -> None:
        """Linger watchdog: flush buckets whose head request has aged out."""
        linger = max(self.config.linger_ms / 1000.0, 1e-4)
        while not self._closed:
            self._wake.clear()
            timeout = linger
            aged = []
            with self._mutex:
                now = time.monotonic()
                for key, bucket in list(self._buckets.items()):
                    age = now - bucket.t_head
                    if age >= linger:
                        del self._buckets[key]
                        aged.append(bucket)
                    else:
                        timeout = min(timeout, linger - age)
                idle = not self._buckets
            if aged:
                # one batched decision for the whole sweep, then enqueue
                self._prewarm(aged)
                for bucket in aged:
                    self._ready.put(bucket)
            # empty table: sleep until a bucket opens; else until the
            # earliest linger deadline
            self._wake.wait(None if idle else timeout)

    def _worker_loop(self) -> None:
        """Workers drain the ready queue; an *idle* worker steals the
        largest worthwhile pending bucket instead of waiting out its linger
        — work-conserving scheduling, so linger only delays requests while
        every worker is busy (during which the next batch accumulates
        anyway; batch size adapts to execution speed).  Buckets below
        ``min_steal`` are left to fill: a stacked launch has a fixed
        dispatch cost, so tiny early flushes would *lose* throughput."""
        min_steal = self.config.min_steal
        if min_steal is None:
            min_steal = max(1, self.config.max_batch // 2)
        poll = 0.001
        while True:
            try:
                bucket = self._ready.get(timeout=poll)
            except queue.Empty:
                bucket, table_empty = self._steal(min_steal)
                if bucket is None:
                    # fast 1 ms polls only while partial buckets are still
                    # filling; a fully idle service backs off (new work
                    # reaches us through the queue or the linger watchdog)
                    poll = 0.05 if table_empty else 0.001
                    continue
            if bucket is None:            # shutdown sentinel
                return
            self._execute(bucket)
            poll = 0.001

    def _steal(self, min_steal: int) -> tuple[Optional[_Bucket], bool]:
        """(largest steal-eligible bucket or None, was-the-table-empty)."""
        with self._mutex:
            if not self._buckets:
                return None, True
            key = max(self._buckets,
                      key=lambda k: len(self._buckets[k].requests))
            if len(self._buckets[key].requests) < min_steal:
                return None, False
            return self._buckets.pop(key), False

    def _pad_width(self, n: int, backend: str) -> int:
        """Canonical stack width for a bucket of ``n``: next power of two,
        capped at ``max_batch`` — bounds the set of distinct batch shapes a
        jit backend ever compiles (one executable per width, reused).
        Backends that execute stacks as a loop (``jit_stacked`` False) are
        never padded: filler rows would just run as wasted extra ops."""
        if not self.config.pad_batches or n >= self.config.max_batch:
            return n
        try:
            if not _backend_resolver()(backend).jit_stacked:
                return n
        except KeyError:
            return n
        width = 1
        while width < n:
            width <<= 1
        return min(width, self.config.max_batch)

    def _execute(self, bucket: _Bucket) -> None:
        from repro.kernels.ops import run_op
        reqs = bucket.requests
        backend, op, dtype_bytes, dims, _dtype, _extra = bucket.key
        width = self._pad_width(len(reqs), backend)
        # the stack build is accounted as queue time, not execution: only
        # the run_op span is "executing" — the retuner compares it against
        # the model's per-call predictions, and folding scheduler-side work
        # (queue wait, linger, stacking) into it would read as drift
        try:
            stacked = tuple(
                np.stack([r.operands[i] for r in reqs] +
                         [reqs[-1].operands[i]] * (width - len(reqs)))
                for i in range(len(reqs[0].operands)))
            t_exec = time.monotonic()
            out = np.asarray(run_op(op, stacked, backend=backend,
                                    runtime=self.runtime, stacked=True,
                                    **reqs[0].kw))
        except Exception as e:           # noqa: BLE001 — fail the whole bucket
            for r in reqs:
                r.future.set_exception(e)
            # futures resolve BEFORE the pending count drops: drain()/close()
            # promise that no request is in flight once they return
            with self._mutex:
                self.stats.failed += len(reqs)
                self.stats.batches += 1
                self._pending -= len(reqs)
                self._done.notify_all()
            return
        t_done = time.monotonic()
        exec_span = t_done - t_exec
        queue_span = sum(t_exec - r.t_submit for r in reqs)
        self.runtime.record_batch(op, dims, dtype_bytes, backend, len(reqs),
                                  exec_seconds=exec_span, exec_items=width,
                                  queue_seconds=queue_span)
        now = time.monotonic()
        for i, r in enumerate(reqs):
            # copy: a view of out would pin the whole (possibly padded)
            # stack in memory for as long as any one result is referenced
            r.future.set_result(out[i].copy())
        # futures resolve BEFORE the pending count drops: drain()/close()
        # promise that no request is in flight once they return
        with self._mutex:
            self.stats.completed += len(reqs)
            self.stats.batches += 1
            self.stats.max_batch = max(self.stats.max_batch, len(reqs))
            self.stats.padded_items += width - len(reqs)
            self.stats.latency_sum += sum(now - r.t_submit for r in reqs)
            self.stats.queue_sum += queue_span
            self.stats.exec_sum += exec_span * len(reqs)
            self._pending -= len(reqs)
            self._done.notify_all()
