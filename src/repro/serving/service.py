"""Shape-bucketed asynchronous BLAS L3 serving (BLASX-style batching on top
of the ADSALA runtime).

The paper's runtime (Fig. 1b) decides a knob per *single* call.  Under
serving traffic the same handful of shapes repeats across many concurrent
requests, so the profitable unit of work is the *bucket*: all pending
requests with identical ``(backend, op, dtype_bytes, dims)`` — the same key
the runtime's decision cache uses — stacked along a new leading axis and
executed as ONE call through :func:`repro.kernels.ops.run_op`.  One ML knob
selection then amortises over the whole bucket, and the backend sees a
single stacked launch instead of B dispatches.

Life of a request::

    submit() ──► bucket[(backend, op, bytes, dims, extra)] ─┐
                                                            │ full (max_batch)
    scheduler thread: linger-deadline watch ────────────────┤ or aged (linger)
                                                            ▼
    ready queue ──► worker pool (bounded) ──► run_op(stacked) ──► futures

Flush policy is per bucket: a bucket flushes when it holds ``max_batch``
requests (size trigger, checked at submit) or when its oldest request has
waited ``linger_ms`` (time trigger, checked by the scheduler thread).
``max_pending`` bounds the number of in-flight requests — ``submit`` blocks
once the bound is hit, which is the service's backpressure signal.

The hot submit path stays cheap on purpose: one mutex acquisition, no
broadcast.  Workers block on the ready *queue* (not a shared condition), the
scheduler sleeps on an event it only needs when a bucket is *opened*, and
completion broadcasts fire per batch, not per request.

Failure semantics (the budget-gated degradation ladder)::

    per rung: error-budget gate (serving.budget)
      ├─ closed  → the rung runs its normal ladder step below
      ├─ open    → rung SKIPPED outright: no attempts, no retries, no
      │            backoff sleeps (ServeStats.budget_skips) — a backend
      │            that has been failing all minute has nothing new to say
      └─ probe   → ONE single-attempt execution; success closes the
                   breaker, failure re-opens it (ServeStats.budget_probes)

    stacked run_op crashes (on an admitted rung)
      ├─► bounded exponential-backoff retries on the same backend/knob
      │     (each sleep capped at the bucket's earliest request deadline)
      ├─► default-knob probe — success pins the crash on the *knob*:
      │     quarantine (backend, op, dtype, knob) in the runtime (TTL'd
      │     circuit breaker) and serve the probe's result
      ├─► next backend down degradation_chain() (pallas → cpu_blocked → ref)
      ├─► bisect the bucket: one poisoned request must not sink batchmates
      └─► typed ExecutionFailedError on the survivors' futures — except
          requests whose deadline lapsed during the ladder, which fail
          with DeadlineExpiredError (they timed out, the backend merely
          also happened to be broken)

Overload is shed at the front door (admission control, all knobs on
``ServeConfig``): a request whose ``deadline`` cannot be met given the
bucket's observed mean queue delay is rejected synchronously with
``AdmissionRejectedError`` instead of being parked to die; lower priority
classes (``submit(priority="batch"/"exploration")`` — retuner/exploration
traffic) shed at a fraction of ``max_pending`` while user traffic still
gets the full buffer; and past ``brownout_pending`` in-flight requests the
workers serve cached-or-default knobs only (``runtime.peek``) — zero model
evaluations until the backlog drains.

Every submitted request therefore resolves — to a result, a
``DeadlineExpiredError`` (its ``submit(deadline=)`` lapsed before
execution), an ``ExecutionFailedError`` (ladder exhausted), or a
``ServiceClosedError`` (``close()`` aborted it before execution).  Workers
are supervised: a dead worker's claimed bucket is requeued and the thread
respawned (``ServeStats.worker_respawns``).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Optional, Sequence

import numpy as np

from repro.core.runtime import AdsalaRuntime, global_runtime

__all__ = ["BlasService", "ServeConfig", "ServeStats", "bucket_key",
           "ServiceClosedError", "DeadlineExpiredError",
           "ExecutionFailedError", "AdmissionRejectedError"]


class ServiceClosedError(RuntimeError):
    """submit() on a closed service, or a request abandoned by close()."""


class AdmissionRejectedError(RuntimeError):
    """submit() shed this request at the front door: its deadline cannot be
    met given the bucket's observed queue delay, or its priority class is
    above its shed threshold while the service is backlogged.  Raised
    synchronously — no future is created, nothing is enqueued."""


class DeadlineExpiredError(TimeoutError):
    """The request's ``submit(deadline=)`` lapsed before execution began."""


class ExecutionFailedError(RuntimeError):
    """Terminal execution failure: every rung of the degradation ladder
    (retries → default-knob probe → backend fallback → bisection) failed.
    The last underlying exception is chained as ``__cause__``."""

#: ops the service accepts (import-light mirror of backends.L3_OPS)
SERVABLE_OPS = ("gemm", "symm", "syrk", "syr2k", "trmm", "trsm")

#: admission-control priority classes, in shed order: "exploration"
#: (retuner probes, speculative traffic) sheds first, then "batch"
#: (offline/bulk callers), and "user" traffic keeps the full buffer
_PRIORITY_LEVELS = {"user": 0, "batch": 1, "exploration": 2}

#: lazily bound repro.backends.resolve_backend (keeps the serving module's
#: import graph light; the backends package pulls in jax)
_resolve_backend = None


def _backend_resolver():
    global _resolve_backend
    if _resolve_backend is None:
        from repro.backends import resolve_backend
        _resolve_backend = resolve_backend
    return _resolve_backend


_degradation_chain = None


def _degrader():
    global _degradation_chain
    if _degradation_chain is None:
        from repro.backends import degradation_chain
        _degradation_chain = degradation_chain
    return _degradation_chain


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Bucket/flush knobs of the serving layer."""
    backend: str = "pallas"       # default execution backend for submit()
    max_batch: int = 32           # size trigger: flush a full bucket at once
    linger_ms: float = 2.0        # time trigger: max wait of a bucket's head
    workers: int = 2              # bounded executor pool size
    max_pending: int = 1024       # backpressure: submit() blocks beyond this
    pad_batches: bool = True      # pad stacks to power-of-two widths so jit
                                  # backends reuse one executable per width
    min_steal: Optional[int] = None   # smallest bucket an *idle* worker may
                                  # flush before its linger expires (work-
                                  # conserving scheduling); None = max_batch/2
    trace_batching: bool | str = False
                                  # "auto"/True: install the process-wide
                                  # trace-time decision batcher
                                  # (ops.trace_batching) around the worker
                                  # pool for the service's lifetime, so
                                  # buckets whose workers trace new shapes
                                  # concurrently batch their uncached knob
                                  # decisions through ONE select_many call.
                                  # Scoped: the previous batcher (usually
                                  # none) is restored on close().  Off by
                                  # default — the combining window adds its
                                  # linger (sub-ms) to every COLD trace, a
                                  # poor trade when traffic is single-
                                  # threaded or shapes rarely repeat.
    # -- resilience (the degradation ladder) --
    exec_retries: int = 1         # same-backend/knob retries after a crash
    retry_backoff_s: float = 0.005    # backoff base, doubled per retry
    backend_fallback: bool = True     # walk degradation_chain() on failure
    bisect_failures: bool = True      # split a failing multi-request bucket
    quarantine_ttl_s: float = 30.0    # knob circuit-breaker open duration
    # -- error budgets (serving.budget: skip known-bad rungs outright) --
    error_budget: bool = True     # gate ladder rungs on rolling failure rate
    budget_window: int = 16       # outcomes per (backend, op) rolling window
    budget_threshold: float = 0.5     # failure rate that exhausts the budget
    budget_min_count: int = 4     # outcomes before a rung may be skipped
    budget_probe_interval_s: float = 5.0  # open-breaker half-open cadence
    # -- admission control (shed overload at submit, not in the queue) --
    admission_control: bool = True    # deadline-aware + priority shedding
    shed_batch_at: float = 0.9    # "batch" priority sheds at this fraction
                                  # of max_pending (user gets the full buffer)
    shed_explore_at: float = 0.6  # "exploration" (retuner probes) sheds first
    brownout_pending: Optional[int] = None
                                  # queue depth past which workers serve
                                  # cached-or-default knobs with ZERO model
                                  # evaluations; None disables brownout

    def __post_init__(self) -> None:
        if self.trace_batching not in (True, False, "auto"):
            # any other string ("off", "no", ...) would truthiness-enable
            # the batcher — the exact opposite of the author's intent
            raise ValueError('trace_batching must be True, False, or "auto"')
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.workers < 1:
            raise ValueError("workers must be >= 1")
        if self.linger_ms < 0:
            raise ValueError("linger_ms must be >= 0")
        if self.max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        if self.exec_retries < 0:
            raise ValueError("exec_retries must be >= 0")
        if self.retry_backoff_s < 0:
            raise ValueError("retry_backoff_s must be >= 0")
        if self.quarantine_ttl_s <= 0:
            raise ValueError("quarantine_ttl_s must be > 0")
        if self.budget_window < 1:
            raise ValueError("budget_window must be >= 1")
        if not 0.0 < self.budget_threshold <= 1.0:
            raise ValueError("budget_threshold must be in (0, 1]")
        if self.budget_min_count < 1:
            raise ValueError("budget_min_count must be >= 1")
        if self.budget_probe_interval_s <= 0:
            raise ValueError("budget_probe_interval_s must be > 0")
        if not 0.0 <= self.shed_batch_at <= 1.0:
            raise ValueError("shed_batch_at must be in [0, 1]")
        if not 0.0 <= self.shed_explore_at <= 1.0:
            raise ValueError("shed_explore_at must be in [0, 1]")
        if self.brownout_pending is not None and self.brownout_pending < 1:
            raise ValueError("brownout_pending must be >= 1 or None")


@dataclasses.dataclass
class ServeStats:
    """Service-level aggregates; per-bucket detail lives in
    ``runtime.stats.buckets`` (see :meth:`BlasService.bucket_stats`).

    End-to-end latency is split into its two phases: ``queue_sum`` is time
    spent parked in a bucket (linger/backlog — a batching-policy artifact),
    ``exec_sum`` is time inside the stacked ``run_op`` call.  The split is
    load-bearing: the online retuner compares *execution* time against the
    model's predictions, and a span that silently included scheduler wait
    would read as drift whenever the flush policy lingered."""
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    batches: int = 0
    max_batch: int = 0
    padded_items: int = 0         # filler rows added for canonical widths
    latency_sum: float = 0.0      # submit→result, seconds, completed only
    queue_sum: float = 0.0        # submit→execution-start (bucket wait)
    exec_sum: float = 0.0         # per-request share: its batch's exec span
    # -- resilience counters --
    retries: int = 0              # same-backend re-executions after a crash
    fallback_executions: int = 0  # stacked runs completed on a degraded
                                  # backend (below the requested one)
    quarantined_knobs: int = 0    # knob circuit breakers this service opened
    deadline_expired: int = 0     # requests dropped before execution (or
                                  # expired during the ladder's retries)
    worker_respawns: int = 0      # dead workers detected and replaced
    warm_start_errors: int = 0    # registry load/save failures (survived)
    retuner_abandoned: int = 0    # close() retuner joins that timed out
                                  # mid-refit (bounded by the close budget)
    # -- error budgets (per-rung state: BlasService.budget_state()) --
    budget_skips: int = 0         # ladder rungs skipped outright (budget
                                  # exhausted: no attempts, no sleeps)
    budget_probes: int = 0        # half-open single-attempt probes let
                                  # through an open breaker
    # -- admission control --
    shed_deadline: int = 0        # submits rejected: deadline infeasible
                                  # given the bucket's mean queue delay
    shed_priority: int = 0        # batch/exploration submits rejected at
                                  # their shed fraction of max_pending
    brownout_batches: int = 0     # buckets served cached-or-default knobs
                                  # (zero model evals) under brownout

    @property
    def mean_batch(self) -> float:
        done = self.completed + self.failed
        return done / self.batches if self.batches else 0.0

    @property
    def mean_latency(self) -> float:
        return self.latency_sum / self.completed if self.completed else 0.0

    @property
    def mean_queue_latency(self) -> float:
        return self.queue_sum / self.completed if self.completed else 0.0

    @property
    def mean_exec_latency(self) -> float:
        return self.exec_sum / self.completed if self.completed else 0.0


def bucket_key(op: str, shapes: Sequence[tuple[int, ...]], dtypes,
               backend: str, extra: tuple = ()) -> tuple:
    """The grouping key: runtime decision-cache key + dtypes + scalar-kwargs.

    Requests in one bucket must be exchangeable under a single stacked call,
    so anything that changes semantics splits the bucket: the exact dtype
    *name* of every operand (itemsize alone would stack float32 with int32,
    and operand 0 alone would miss a mixed-precision second operand — both
    silently promote under np.stack) and any scalar kwargs (alpha, beta) —
    two alphas never share a stack.  The first four fields remain the
    runtime decision-cache key.
    """
    from repro.kernels.ops import dims_of
    names = tuple(np.dtype(d).name for d in dtypes)
    return (backend, op, int(np.dtype(dtypes[0]).itemsize),
            dims_of(op, tuple(shapes)), names, extra)


def _resolve_result(fut: Future, value) -> bool:
    """Set a future's result; False if it was already resolved (a bucket
    re-executed after worker recovery must keep the first resolution)."""
    try:
        fut.set_result(value)
        return True
    except Exception:        # concurrent.futures.InvalidStateError
        return False


def _resolve_exc(fut: Future, exc: BaseException) -> bool:
    try:
        fut.set_exception(exc)
        return True
    except Exception:        # already resolved — keep the first outcome
        return False


@dataclasses.dataclass
class _Request:
    op: str
    operands: tuple
    kw: dict
    future: Future
    t_submit: float
    deadline: Optional[float] = None   # absolute monotonic; None = no limit


class _Bucket:
    __slots__ = ("key", "requests", "t_head", "recovered")

    def __init__(self, key: tuple, t_head: float) -> None:
        self.key = key
        self.requests: list[_Request] = []
        self.t_head = t_head          # monotonic enqueue time of the head
        self.recovered = 0            # times requeued after a worker death


class BlasService:
    """Asynchronous shape-bucketed BLAS front-end over an ADSALA runtime.

    ``submit`` returns a :class:`concurrent.futures.Future`; buckets are
    executed by a bounded worker pool as single stacked ``run_op`` calls.
    Pass a :class:`~repro.core.registry.ModelRegistry` to warm-start the
    runtime's decision cache on startup and persist it on ``close`` — a
    restarted server then re-serves previously seen shapes with zero model
    evaluations.

    Usage::

        with BlasService(runtime=rt, config=ServeConfig(max_batch=16)) as s:
            futs = [s.submit("gemm", (a, b)) for a, b in work]
            outs = [f.result() for f in futs]
    """

    def __init__(self, *, runtime: Optional[AdsalaRuntime] = None,
                 config: Optional[ServeConfig] = None,
                 registry=None, retuner=None, faults=None) -> None:
        self.runtime = runtime if runtime is not None else global_runtime()
        self.config = config if config is not None else ServeConfig()
        self.registry = registry
        self.stats = ServeStats()
        #: optional repro.serving.faults.FaultPlan (chaos harness); every
        #: site is behind an `is not None` check — disabled costs nothing
        self._faults = faults
        # error budgets: attach the ledger BEFORE the warm start so
        # persisted {"budget": 1} records land in it (a rung that was
        # burning its budget when the last process died stays skipped)
        self.budgets = None
        if self.config.error_budget:
            from repro.serving.budget import BudgetConfig, ErrorBudgetLedger
            existing = self.runtime.attached_budgets()
            if existing is not None:
                self.budgets = existing     # shared runtime: shared budgets
            else:
                self.budgets = ErrorBudgetLedger(BudgetConfig(
                    window=self.config.budget_window,
                    threshold=self.config.budget_threshold,
                    min_count=self.config.budget_min_count,
                    probe_interval_s=self.config.budget_probe_interval_s))
                self.runtime.attach_budgets(self.budgets)
        # crash-safe incremental persistence: every NEW cached decision and
        # quarantine is journaled beside the snapshot, so a SIGKILL between
        # save_decision_cache calls loses nothing
        if registry is not None and self.runtime.decision_journal is None:
            self.runtime.decision_journal = registry.journal_decision
        self.warm_started = 0
        if registry is not None:
            # a corrupt or missing persisted cache must not stop the server
            # from starting cold — warm start is an optimization, not a
            # dependency
            try:
                self.warm_started = registry.load_decision_cache(self.runtime)
            except Exception:        # noqa: BLE001 — cold start instead
                self.stats.warm_start_errors += 1
        # optional online feedback loop (repro.serving.retune.Retuner):
        # started once the workers are up, stopped before the decision
        # cache is persisted on close so the saved cache reflects the final
        # artifact generations.  Omit it (the default) for reproducibility
        # runs.
        self.retuner = retuner

        # scoped trace-time decision batcher (ServeConfig.trace_batching):
        # entered before the workers start, exited (previous batcher
        # restored) after they stop
        self._trace_cm = None
        self.trace_batcher = None
        if self.config.trace_batching:
            from repro.kernels.ops import trace_batching
            self._trace_cm = trace_batching()
            self.trace_batcher = self._trace_cm.__enter__()
        try:
            self._start()
        except BaseException:
            # never leak the process-global batcher if startup fails
            if self._trace_cm is not None:
                self._trace_cm.__exit__(None, None, None)
                self._trace_cm = None
            raise
        if self.retuner is not None:
            self.retuner.start()

    def _start(self) -> None:
        self._mutex = threading.Lock()
        self._done = threading.Condition(self._mutex)   # batch completions
        self._buckets: dict[tuple, _Bucket] = {}
        self._ready: "queue.Queue[Optional[_Bucket]]" = queue.Queue()
        self._wake = threading.Event()    # scheduler: new bucket opened
        self._pending = 0                 # submitted, result not yet set
        self._closed = False
        # per-worker claim slots: the bucket worker i is currently holding
        # (set BEFORE any code that could die, cleared after execution) —
        # the supervisor requeues a dead worker's claimed bucket from here
        self._claims: list[Optional[_Bucket]] = \
            [None] * self.config.workers

        self._scheduler = threading.Thread(
            target=self._scheduler_loop, name="blas-serve-scheduler",
            daemon=True)
        self._workers = [
            threading.Thread(target=self._worker_main, args=(i,),
                             name=f"blas-serve-worker-{i}", daemon=True)
            for i in range(self.config.workers)]
        # workers first: the scheduler doubles as the worker supervisor and
        # must never observe a not-yet-started thread as "dead"
        for w in self._workers:
            w.start()
        self._scheduler.start()

    # -- submission -----------------------------------------------------------
    def submit(self, op: str, operands: tuple, *,
               backend: Optional[str] = None,
               deadline: Optional[float] = None,
               priority: str = "user", **kw) -> Future:
        """Enqueue one BLAS call; returns a Future resolving to its result.

        Blocks (backpressure) while ``max_pending`` requests are in flight.
        ``deadline`` (seconds from now) bounds the request's life: a request
        still waiting in a bucket when its deadline lapses is dropped before
        execution and its future fails with :class:`DeadlineExpiredError`.
        Raises :class:`ServiceClosedError` after :meth:`close`.

        Admission control (``ServeConfig.admission_control``) sheds
        overload *synchronously* with :class:`AdmissionRejectedError`
        instead of parking doomed work: a deadlined request whose bucket's
        observed mean queue delay already exceeds the deadline is rejected
        up front, and non-``"user"`` priority classes (``"batch"``, then
        ``"exploration"`` first — retuner probes and other speculative
        traffic) are rejected once the in-flight count crosses their shed
        fraction of ``max_pending``, keeping the tail of the buffer for
        user traffic.
        """
        if op not in SERVABLE_OPS:
            raise ValueError(f"unknown op {op!r}; servable: {SERVABLE_OPS}")
        if deadline is not None and deadline <= 0:
            raise ValueError("deadline must be > 0 seconds from now")
        level = _PRIORITY_LEVELS.get(priority)
        if level is None:
            raise ValueError(f"unknown priority {priority!r}; one of "
                             f"{tuple(_PRIORITY_LEVELS)}")
        operands = tuple(np.asarray(x) for x in operands)
        if any(x.ndim != 2 for x in operands):
            raise ValueError("submit takes one 2-D problem per request; "
                             "stacking is the service's job")
        be = backend or self.config.backend
        key = bucket_key(op, [x.shape for x in operands],
                         [x.dtype for x in operands], be,
                         tuple(sorted(kw.items())))
        cfg = self.config
        if cfg.admission_control and deadline is not None:
            # deadline feasibility against the bucket's OBSERVED queue
            # delay (lock-free peek; keyed by the requested backend — the
            # same key this request will bucket under).  No history means
            # no evidence of infeasibility: admit.
            bstats = self.runtime.bucket_stats_peek(key[:4])
            if bstats is not None and bstats.requests:
                est = bstats.mean_queue
                if est > deadline:
                    with self._mutex:
                        self.stats.shed_deadline += 1
                    raise AdmissionRejectedError(
                        f"deadline {deadline:.4f}s infeasible: bucket "
                        f"{key[:4]} mean queue delay is {est:.4f}s")
        now = time.monotonic()
        req = _Request(op=op, operands=operands, kw=kw, future=Future(),
                       t_submit=now,
                       deadline=None if deadline is None else now + deadline)
        with self._mutex:
            if self._closed:
                raise ServiceClosedError("service is closed")
            if level and cfg.admission_control:
                frac = cfg.shed_batch_at if level == 1 \
                    else cfg.shed_explore_at
                if self._pending >= frac * cfg.max_pending:
                    self.stats.shed_priority += 1
                    raise AdmissionRejectedError(
                        f"{priority!r} traffic sheds at {frac:.0%} of "
                        f"max_pending ({self._pending} in flight)")
            while self._pending >= self.config.max_pending:
                self._done.wait(0.05)
                if self._closed:
                    raise ServiceClosedError("service is closed")
            self._pending += 1
            self.stats.submitted += 1
            bucket = self._buckets.get(key)
            opened = bucket is None
            if opened:
                bucket = self._buckets[key] = _Bucket(key, now)
            bucket.requests.append(req)
            if len(bucket.requests) >= self.config.max_batch:
                del self._buckets[key]
                self._ready.put(bucket)
                opened = False            # flushed already; no linger watch
        if opened:
            self._wake.set()
        return req.future

    def call(self, op: str, operands: tuple, *,
             backend: Optional[str] = None, **kw):
        """Synchronous convenience wrapper: ``submit(...).result()``."""
        return self.submit(op, operands, backend=backend, **kw).result()

    def flush(self) -> None:
        """Force every pending bucket onto the execution queue now."""
        with self._mutex:
            buckets = [self._buckets.pop(key) for key in list(self._buckets)]
        self._prewarm(buckets)
        for b in buckets:
            self._ready.put(b)

    # -- batched knob prewarm -------------------------------------------------
    def _prewarm(self, buckets: list) -> None:
        """One batched knob selection (``AdsalaRuntime.select_many``) for a
        set of buckets about to execute: all uncached decisions share a
        single fused feature-build + model-predict call instead of one
        model evaluation per bucket inside the workers.  Keys are selected
        under the backend name the executor will resolve to, so the
        workers' own selections become cache hits.  Prewarm lookups of
        already-cached keys stay out of the hit statistics
        (``record_hits=False``) — only the executors' selections count as
        traffic.  Best-effort — any failure just leaves the decisions to
        the executors."""
        if len(buckets) < 2:
            return                    # a lone bucket gains nothing
        requests = []
        for b in buckets:
            backend, op, dtype_bytes, dims = b.key[:4]
            try:
                backend = _backend_resolver()(backend).name
            except Exception:        # noqa: BLE001 — unresolvable backend
                continue
            requests.append((op, dims, dtype_bytes, backend))
        if len(requests) >= 2:
            try:
                self.runtime.select_many(requests, record_hits=False)
            except Exception:        # noqa: BLE001 — executors still select
                pass

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Flush and wait until no request is in flight; True on success."""
        deadline = None if timeout is None else time.monotonic() + timeout
        self.flush()
        with self._mutex:
            while self._pending > 0:
                if deadline is not None and time.monotonic() >= deadline:
                    return False
                self._done.wait(0.05)
        return True

    # -- stats ----------------------------------------------------------------
    def bucket_stats(self) -> dict[tuple, object]:
        """Per-bucket serving stats recorded on the runtime, keyed
        ``(backend, op, dtype_bytes, dims)``."""
        return self.runtime.stats.buckets    # stats snapshots under its lock

    # -- lifecycle ------------------------------------------------------------
    def close(self, timeout: float = 30.0) -> None:
        """Drain in-flight work, persist the decision cache (when a registry
        was given), and stop the threads.  Idempotent.

        New submissions are rejected *before* the drain starts — otherwise a
        submit racing the shutdown could park a request in a bucket no
        scheduler or worker would ever flush.  Requests the drain could NOT
        finish (hung backend, dead workers past the drain timeout) are
        *failed* with :class:`ServiceClosedError`, never leaked — no caller
        blocks forever on a future the service has abandoned."""
        deadline = time.monotonic() + max(0.0, timeout)
        with self._mutex:
            if self._closed:
                return
            self._closed = True
            self._done.notify_all()
        self.drain(timeout=timeout)
        self._wake.set()
        for _ in self._workers:
            self._ready.put(None)         # worker shutdown sentinels
        # the join budget scales with the caller's close timeout: a caller
        # asking for a fast close must not wait 5 s per stuck worker — the
        # worker's bucket is reclaimed from its claim slot below instead
        join_s = min(5.0, max(0.1, timeout))
        self._scheduler.join(timeout=join_s)
        for w in self._workers:
            w.join(timeout=join_s)
        self._abort_leftovers()
        if self._trace_cm is not None:      # restore the previous batcher
            self._trace_cm.__exit__(None, None, None)
            self._trace_cm = None
        if self.retuner is not None:        # before the cache is persisted:
            # no swap may race the export — but a retuner mid-refit can
            # outlast any close budget, so the join is bounded by whatever
            # remains of the caller's timeout.  A timed-out join abandons
            # the refit *counted*, never silently: the halted thread exits
            # after its in-flight step, and its swap (if any) lands on a
            # runtime nobody serves from anymore
            remaining = max(0.1, deadline - time.monotonic())
            if not self.retuner.stop(timeout=remaining):
                with self._mutex:
                    self.stats.retuner_abandoned += 1
        if self.registry is not None:
            try:
                self.registry.save_decision_cache(self.runtime)
            except Exception:    # noqa: BLE001 — persistence is best-effort
                with self._mutex:
                    self.stats.warm_start_errors += 1

    def _abort_leftovers(self) -> None:
        """Fail (never leak) every request the drain could not finish: still
        bucketed, parked on the ready queue, or claimed by a worker that
        died without completing it."""
        leftovers: list[_Bucket] = []
        with self._mutex:
            for key in list(self._buckets):
                leftovers.append(self._buckets.pop(key))
        while True:
            try:
                b = self._ready.get_nowait()
            except queue.Empty:
                break
            if b is not None:             # drop stale worker sentinels
                leftovers.append(b)
        for i, b in enumerate(self._claims):
            if b is not None:
                self._claims[i] = None
                leftovers.append(b)
        exc = ServiceClosedError(
            "service is closed; request abandoned before execution")
        n = sum(_resolve_exc(r.future, exc)
                for b in leftovers for r in b.requests)
        if n:
            with self._mutex:
                self.stats.failed += n
                self._pending -= n
                self._done.notify_all()

    def __enter__(self) -> "BlasService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- scheduler / workers --------------------------------------------------
    def _scheduler_loop(self) -> None:
        """Linger watchdog + worker supervisor: flush buckets whose head
        request has aged out, and detect/replace dead workers (requeueing
        whatever bucket the casualty had claimed)."""
        linger = max(self.config.linger_ms / 1000.0, 1e-4)
        while not self._closed:
            self._wake.clear()
            timeout = linger
            aged = []
            with self._mutex:
                now = time.monotonic()
                for key, bucket in list(self._buckets.items()):
                    age = now - bucket.t_head
                    if age >= linger:
                        del self._buckets[key]
                        aged.append(bucket)
                    else:
                        timeout = min(timeout, linger - age)
                idle = not self._buckets
            if aged:
                # one batched decision for the whole sweep, then enqueue
                self._prewarm(aged)
                for bucket in aged:
                    self._ready.put(bucket)
            self._supervise_workers()
            # the wait is bounded even when the bucket table is idle —
            # supervision must keep running while requests sit on the ready
            # queue or inside a (possibly dying) worker
            self._wake.wait(min(timeout, 0.05) if not idle else 0.05)

    def _supervise_workers(self) -> None:
        """Replace dead workers.  The casualty's claimed bucket (its claim
        slot is set before any fallible work) is requeued so its requests
        survive the death; a bucket that keeps killing workers is failed
        after 3 recoveries instead of crash-looping the pool."""
        if self._closed:
            return
        for i, t in enumerate(self._workers):
            if t.is_alive():
                continue
            bucket = self._claims[i]
            self._claims[i] = None
            w = threading.Thread(target=self._worker_main, args=(i,),
                                 name=f"blas-serve-worker-{i}", daemon=True)
            self._workers[i] = w
            w.start()
            with self._mutex:
                self.stats.worker_respawns += 1
            if bucket is None:
                continue
            # requests the dead worker already resolved stay resolved
            bucket.requests = [r for r in bucket.requests
                               if not r.future.done()]
            bucket.recovered += 1
            if not bucket.requests:
                continue
            if bucket.recovered > 3:
                exc = ExecutionFailedError(
                    f"bucket {bucket.key[:4]} killed "
                    f"{bucket.recovered} workers; not requeueing again")
                n = sum(_resolve_exc(r.future, exc)
                        for r in bucket.requests)
                with self._mutex:
                    self.stats.failed += n
                    self._pending -= n
                    self._done.notify_all()
            else:
                self._ready.put(bucket)

    def _worker_main(self, idx: int) -> None:
        try:
            self._worker_loop(idx)
        except BaseException:    # noqa: BLE001 — a dying worker must exit
            return               # quietly; the supervisor sees the death

    def _worker_loop(self, idx: int) -> None:
        """Workers drain the ready queue; an *idle* worker steals the
        largest worthwhile pending bucket instead of waiting out its linger
        — work-conserving scheduling, so linger only delays requests while
        every worker is busy (during which the next batch accumulates
        anyway; batch size adapts to execution speed).  Buckets below
        ``min_steal`` are left to fill: a stacked launch has a fixed
        dispatch cost, so tiny early flushes would *lose* throughput."""
        min_steal = self.config.min_steal
        if min_steal is None:
            min_steal = max(1, self.config.max_batch // 2)
        claims = self._claims
        poll = 0.001
        while True:
            try:
                bucket = self._ready.get(timeout=poll)
            except queue.Empty:
                bucket, table_empty = self._steal(min_steal)
                if bucket is None:
                    # fast 1 ms polls only while partial buckets are still
                    # filling; a fully idle service backs off (new work
                    # reaches us through the queue or the linger watchdog)
                    poll = 0.05 if table_empty else 0.001
                    continue
            if bucket is None:            # shutdown sentinel
                return
            # claim BEFORE any fallible work: if this thread dies from here
            # on, the supervisor finds the bucket in the claim slot
            claims[idx] = bucket
            if self._faults is not None:
                self._faults.fire("worker", worker=idx, key=bucket.key)
            self._execute(bucket, idx)
            claims[idx] = None
            poll = 0.001

    def _steal(self, min_steal: int) -> tuple[Optional[_Bucket], bool]:
        """(largest steal-eligible bucket or None, was-the-table-empty)."""
        with self._mutex:
            if not self._buckets:
                return None, True
            key = max(self._buckets,
                      key=lambda k: len(self._buckets[k].requests))
            if len(self._buckets[key].requests) < min_steal:
                return None, False
            return self._buckets.pop(key), False

    def _pad_width(self, n: int, backend: str) -> int:
        """Canonical stack width for a bucket of ``n``: next power of two,
        capped at ``max_batch`` — bounds the set of distinct batch shapes a
        jit backend ever compiles (one executable per width, reused).
        Backends that execute stacks as a loop (``jit_stacked`` False) are
        never padded: filler rows would just run as wasted extra ops."""
        if not self.config.pad_batches or n >= self.config.max_batch:
            return n
        try:
            if not _backend_resolver()(backend).jit_stacked:
                return n
        except KeyError:
            return n
        width = 1
        while width < n:
            width <<= 1
        return min(width, self.config.max_batch)

    def _execute(self, bucket: _Bucket, worker_idx: int = 0) -> None:
        """Execute one bucket: drop deadline-expired requests, then hand the
        survivors to :meth:`_dispatch` (every future resolves)."""
        now = time.monotonic()
        live, expired = [], []
        for r in bucket.requests:
            (live if r.deadline is None or now < r.deadline
             else expired).append(r)
        if expired:
            exc = DeadlineExpiredError(
                "request deadline expired before execution")
            n = sum(_resolve_exc(r.future, exc) for r in expired)
            with self._mutex:
                self.stats.deadline_expired += n
                self._pending -= n
                self._done.notify_all()
        if live:
            self._dispatch(bucket, live, worker_idx)

    def _dispatch(self, bucket: _Bucket, reqs: list,
                  worker_idx: int) -> None:
        """Execution transport seam: the in-process service runs the
        degradation ladder right here on the worker thread;
        :class:`~repro.serving.fleet.FleetService` overrides this to ship
        the bucket to the executor process paired with ``worker_idx``."""
        self._execute_chain(bucket, reqs)

    def budget_state(self) -> dict:
        """Per-(backend, op) error-budget rung state (breaker state,
        rolling failure rate, skip/probe counters); empty when budgets are
        disabled."""
        return self.budgets.snapshot() if self.budgets is not None else {}

    def _execute_chain(self, bucket: _Bucket, reqs: list,
                       bisected: bool = False) -> None:
        """The budget-gated degradation ladder for one stack of requests:
        per backend rung — error-budget gate first (an over-budget rung is
        skipped outright, a due breaker gets one single-attempt probe) —
        then bounded-backoff retries with the selected knob (each sleep
        capped at the bucket's earliest deadline), then a default-knob
        probe whose success quarantines the selected knob — then the next
        rung of ``degradation_chain()``; an exhausted chain bisects
        multi-request buckets (one poisoned request must not sink its
        batchmates) and finally fails futures with a typed error
        (``DeadlineExpiredError`` for requests that timed out along the
        way, ``ExecutionFailedError`` for the rest)."""
        backend, op, dtype_bytes, dims = bucket.key[:4]
        cfg = self.config
        ledger = self.budgets
        chain = self._degrade_chain(backend) if cfg.backend_fallback \
            else (backend,)
        resolver = _backend_resolver()
        last_exc: Exception | None = None
        # the earliest live deadline bounds every backoff sleep: a bucket
        # must never sleep through its own deadline and then report the
        # backend failure instead of the timeout
        min_deadline = min((r.deadline for r in reqs
                            if r.deadline is not None), default=None)
        # brownout: past the configured backlog, serve cached-or-default
        # knobs only — model evaluations are pure queue-delay under
        # overload, and the cache keeps previously seen shapes optimal
        brownout = (cfg.brownout_pending is not None
                    and self._pending >= cfg.brownout_pending)
        for be_name in chain:
            mode = "closed"
            # bisected halves bypass the gate: they are the diagnostic
            # subdivision of a rung that was ALREADY admitted — skipping
            # them would let the stack's own failures starve the very
            # isolation step that exonerates its healthy batchmates.
            # (Their outcomes still feed the window, so a genuinely dead
            # rung opens the breaker for the NEXT bucket's top level.)
            if ledger is not None and not bisected:
                mode = ledger.admit(be_name, op)
                if mode == "skip":
                    # budget exhausted: the rung has been failing all
                    # window — skip it outright (no attempts, no retries,
                    # no backoff sleeps) and let the ladder move on
                    with self._mutex:
                        self.stats.budget_skips += 1
                    if last_exc is None:
                        last_exc = ExecutionFailedError(
                            f"rung {be_name!r} skipped: error budget "
                            f"exhausted")
                    continue
                if mode == "probe":
                    with self._mutex:
                        self.stats.budget_probes += 1
            try:
                be = resolver(be_name)
            except Exception as e:       # noqa: BLE001 — rung unregistered
                last_exc = e
                continue
            if be.name != be_name:
                continue    # resolve-time fallback already left this rung;
                            # the chain's own later rungs cover the target
            try:
                default = be.default_knob(op)
            except Exception as e:       # noqa: BLE001
                last_exc = e
                continue
            # ONE knob decision for the whole stack, under the executed
            # backend's cache key (exactly what run_op would have selected)
            if brownout:
                knob = self.runtime.peek(op, dims, dtype_bytes,
                                         backend=be_name)
                if knob is None:
                    knob = default
                with self._mutex:
                    self.stats.brownout_batches += 1
            else:
                knob = self.runtime.select_or_default(
                    op, dims, dtype_bytes, default, backend=be_name)
            degraded = be_name != backend
            # a half-open probe gets exactly ONE attempt: the breaker is
            # asking "is it healed", not paying the full retry schedule
            attempts = 1 if mode == "probe" else cfg.exec_retries + 1
            for attempt in range(attempts):
                if attempt:
                    with self._mutex:
                        self.stats.retries += 1
                    sleep_s = cfg.retry_backoff_s * (1 << (attempt - 1))
                    if min_deadline is not None:
                        sleep_s = min(sleep_s,
                                      min_deadline - time.monotonic())
                    if sleep_s > 0:
                        time.sleep(sleep_s)
                try:
                    self._run_and_resolve(bucket, reqs, be_name, knob,
                                          attempt, degraded)
                    if ledger is not None:
                        ledger.record(be_name, op, True)
                    return
                except Exception as e:   # noqa: BLE001 — next attempt/rung
                    last_exc = e
                    if ledger is not None:
                        ledger.record(be_name, op, False)
            if knob != default and mode != "probe":
                # knob-specific-failure probe: the model's pick crashed
                # every attempt — if the backend's own default config runs
                # clean, the crash is pinned on the KNOB, so quarantine it
                # (TTL'd breaker; the cached decision is invalidated in the
                # same stroke) and serve the probe's result
                try:
                    self._run_and_resolve(bucket, reqs, be_name, default,
                                          cfg.exec_retries + 1, degraded)
                except Exception as e:   # noqa: BLE001 — backend-wide after
                    last_exc = e         # all: fall through to the next rung
                    if ledger is not None:
                        ledger.record(be_name, op, False)
                else:
                    if ledger is not None:
                        ledger.record(be_name, op, True)
                    self.runtime.quarantine_knob(
                        op, dtype_bytes, be_name, knob, fallback=default,
                        ttl_s=cfg.quarantine_ttl_s)
                    with self._mutex:
                        self.stats.quarantined_knobs += 1
                    return
        if cfg.bisect_failures and len(reqs) > 1:
            # the whole chain failed for the stack — a single poisoned
            # request (bad operand values, shape edge case) may be taking
            # its batchmates down with it: split and retry each half
            mid = (len(reqs) + 1) // 2
            self._execute_chain(bucket, reqs[:mid], bisected=True)
            self._execute_chain(bucket, reqs[mid:], bisected=True)
            return
        # requests whose deadline lapsed during the ladder report the
        # timeout, not the backend failure they never got to outlive
        now = time.monotonic()
        live, timed_out = [], []
        for r in reqs:
            (timed_out if r.deadline is not None and now >= r.deadline
             else live).append(r)
        n_exp = 0
        if timed_out:
            dexc = DeadlineExpiredError(
                "request deadline expired during the degradation ladder")
            dexc.__cause__ = last_exc
            n_exp = sum(_resolve_exc(r.future, dexc) for r in timed_out)
        exc = ExecutionFailedError(
            f"{op} bucket dims={dims} failed on every backend in {chain}")
        exc.__cause__ = last_exc
        n = sum(_resolve_exc(r.future, exc) for r in live)
        # futures resolve BEFORE the pending count drops: drain()/close()
        # promise that no request is in flight once they return
        with self._mutex:
            self.stats.failed += n
            self.stats.deadline_expired += n_exp
            self.stats.batches += 1
            self._pending -= n + n_exp
            self._done.notify_all()

    @staticmethod
    def _degrade_chain(backend: str) -> tuple[str, ...]:
        try:
            return _degrader()(backend)
        except Exception:        # noqa: BLE001 — backends package broken
            return (backend,)

    def _run_and_resolve(self, bucket: _Bucket, reqs: list, be_name: str,
                         knob, attempt: int, degraded: bool) -> None:
        """One stacked execution on one backend with one explicit knob;
        resolves futures and books stats on success, raises on failure
        (leaving every future untouched for the next rung)."""
        from repro.kernels.ops import run_op
        _backend, op, dtype_bytes, dims = bucket.key[:4]
        width = self._pad_width(len(reqs), be_name)
        # the stack build is accounted as queue time, not execution: only
        # the run_op span is "executing" — the retuner compares it against
        # the model's per-call predictions, and folding scheduler-side work
        # (queue wait, linger, stacking) into it would read as drift
        stacked = tuple(
            np.stack([r.operands[i] for r in reqs] +
                     [reqs[-1].operands[i]] * (width - len(reqs)))
            for i in range(len(reqs[0].operands)))
        if self._faults is not None:
            self._faults.fire("stacked_execute", backend=be_name, op=op,
                              dims=dims, attempt=attempt, n=len(reqs))
        t_exec = time.monotonic()
        out = np.asarray(run_op(op, stacked, backend=be_name, knob=knob,
                                runtime=self.runtime, stacked=True,
                                **reqs[0].kw))
        t_done = time.monotonic()
        exec_span = t_done - t_exec
        queue_span = sum(t_exec - r.t_submit for r in reqs)
        # telemetry is credited to the backend that EXECUTED (the retuner
        # compares execution time against that backend's predictions)
        self.runtime.record_batch(op, dims, dtype_bytes, be_name, len(reqs),
                                  exec_seconds=exec_span, exec_items=width,
                                  queue_seconds=queue_span)
        now = time.monotonic()
        resolved = 0
        latency = 0.0
        for i, r in enumerate(reqs):
            # copy: a view of out would pin the whole (possibly padded)
            # stack in memory for as long as any one result is referenced
            if _resolve_result(r.future, out[i].copy()):
                resolved += 1
                latency += now - r.t_submit
        # futures resolve BEFORE the pending count drops: drain()/close()
        # promise that no request is in flight once they return
        with self._mutex:
            self.stats.completed += resolved
            self.stats.batches += 1
            self.stats.max_batch = max(self.stats.max_batch, len(reqs))
            self.stats.padded_items += width - len(reqs)
            self.stats.latency_sum += latency
            self.stats.queue_sum += queue_span
            self.stats.exec_sum += exec_span * resolved
            if degraded:
                self.stats.fallback_executions += 1
            self._pending -= resolved
            self._done.notify_all()
