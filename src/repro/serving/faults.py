"""Deterministic, seeded fault injection for the serving/runtime stack.

The resilience layer (execution-time backend fallback, knob quarantine,
supervised workers, retuner error recovery) is only trustworthy if every
failure path can be driven *deterministically* — waiting for a real kernel
crash or a real dead thread makes the recovery code the least-tested code in
the repo.  This module is the test double for the world being hostile:

    plan = FaultPlan([FaultSpec(site="stacked_execute", times=2,
                                match=lambda ctx: ctx["backend"] == "pallas")])
    rt  = AdsalaRuntime(faults=plan)
    svc = BlasService(runtime=rt, faults=plan, ...)

Components that take a plan call ``plan.fire(site, **ctx)`` at named *sites*;
the plan decides — under its own lock, deterministically — whether that
occurrence raises an injected exception, sleeps an injected latency, or does
nothing.  A component constructed without a plan (the default everywhere)
holds ``None`` and guards every site with an attribute check, so the
disabled path costs one ``is not None`` test and allocates nothing.

Named sites (the contract between the chaos harness and the stack):

    ``stacked_execute``  BlasService bucket execution, per ladder attempt
                         (ctx: backend, op, dims, attempt, n = stack size)
    ``kernel_execute``   kernels.ops.run_op dispatch, after knob resolution
                         (ctx: backend, op, stacked, knob)
    ``predictor_eval``   AdsalaRuntime miss-path model evaluation
                         (ctx: backend, op, dtype_bytes, dims — and ``n``
                         for the batched select_many evaluation)
    ``cache_import``     AdsalaRuntime.import_cache (ctx: entries)
    ``artifact_load``    ModelRegistry per-artifact load (ctx: path)
    ``worker``           BlasService worker loop, after a bucket is claimed
                         but before it executes (ctx: worker, key) — an
                         injected raise here kills the worker thread with
                         the bucket claimed, exactly the death the
                         supervisor must recover from
    ``retuner_observe``  Retuner.observe entry (ctx: none)
    ``retuner_refit``    Retuner.retune, before the refit (ctx: sub_key)
    ``snapshot_write``   core.durable atomic snapshot writers, before the
                         temp file is created (ctx: path, size).  A plain
                         raise models a crash *before* the write (the old
                         snapshot survives untouched); an injected latency
                         holds the writer mid-write (the recovery bench
                         SIGKILLs the process inside this window); raising
                         :class:`TornWrite` persists a truncated payload at
                         the final path before propagating
    ``journal_append``   core.durable journal appends, before the write
                         (ctx: path, size); TornWrite tears the record at
                         a seeded fraction of its bytes

Matching is by site name, then an optional ``match(ctx) -> bool`` predicate
over the site's context dict, then the occurrence window (``after`` skipped
occurrences, then ``times`` firings — ``None`` = fire forever), then an
optional seeded Bernoulli ``p``.  Everything a spec decides is a function of
the plan's seed and the deterministic occurrence order, so a chaos scenario
replays bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Any, Callable, Optional

__all__ = ["FaultSpec", "FaultPlan", "InjectedFault", "TornWrite"]


def __getattr__(name: str):
    # lazy re-export: TornWrite lives in core.durable (the layer that has
    # to catch it), and importing it eagerly would drag the whole heavy
    # core package into this module's deliberately light import graph
    if name == "TornWrite":
        from repro.core.durable import TornWrite
        return TornWrite
    raise AttributeError(name)


class InjectedFault(RuntimeError):
    """Default exception raised at a firing site (chaos-only by design:
    nothing in the production stack raises or catches it specially, so an
    injected fault exercises exactly the generic failure paths)."""


@dataclasses.dataclass
class FaultSpec:
    """One injection rule: *where* (site + match), *when* (after/times/p),
    and *what* (an exception and/or added latency)."""
    site: str
    #: exception to raise: a class (instantiated per firing with a
    #: descriptive message) or an instance (raised as-is).  None = no raise
    #: (latency-only fault).
    exc: type[BaseException] | BaseException | None = InjectedFault
    #: seconds to sleep before raising (or returning, for latency-only)
    latency_s: float = 0.0
    #: predicate over the site's context dict; None matches every occurrence
    match: Optional[Callable[[dict], bool]] = None
    #: fire on at most this many matching occurrences (None = forever)
    times: Optional[int] = 1
    #: skip this many matching occurrences before the first firing
    after: int = 0
    #: Bernoulli firing probability, drawn from the plan's seeded stream
    p: float = 1.0

    # runtime counters (owned by the plan, mutated under its lock)
    seen: int = 0
    fired: int = 0

    def __post_init__(self) -> None:
        if self.times is not None and self.times < 0:
            raise ValueError("times must be >= 0 or None")
        if self.after < 0:
            raise ValueError("after must be >= 0")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError("p must be in [0, 1]")
        if self.exc is None and self.latency_s <= 0.0:
            raise ValueError("a spec must inject an exception or latency")


class FaultPlan:
    """A deterministic, thread-safe set of :class:`FaultSpec` rules.

    The decision of whether an occurrence fires is taken under the plan's
    lock (counters and the seeded RNG advance atomically), so concurrent
    workers hitting the same spec observe one global occurrence order; the
    injected latency sleep happens *outside* the lock so a slow fault never
    serialises unrelated sites.
    """

    def __init__(self, specs: tuple | list = (), *, seed: int = 0) -> None:
        self.seed = int(seed)
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()
        self._specs: list[FaultSpec] = []
        #: audit log of firings: (site, spec index, context summary)
        self.log: list[tuple[str, int, dict]] = []
        for s in specs:
            self.add(s)

    def add(self, spec: FaultSpec) -> FaultSpec:
        with self._lock:
            self._specs.append(spec)
        return spec

    def specs(self, site: str | None = None) -> list[FaultSpec]:
        with self._lock:
            return [s for s in self._specs
                    if site is None or s.site == site]

    def fired(self, site: str | None = None) -> int:
        """Total firings (optionally per site) — scenario assertions."""
        with self._lock:
            return sum(s.fired for s in self._specs
                       if site is None or s.site == site)

    def reset(self) -> None:
        """Rewind every counter and the RNG to the initial state."""
        with self._lock:
            self._rng = random.Random(self.seed)
            self.log.clear()
            for s in self._specs:
                s.seen = 0
                s.fired = 0

    # -- the hook -------------------------------------------------------------
    def fire(self, site: str, **ctx: Any) -> None:
        """Called by instrumented components at a named site.  Applies the
        first matching armed spec: sleeps its latency, then raises its
        exception (if any).  A non-matching occurrence returns immediately.
        """
        sleep_s = 0.0
        raise_exc: BaseException | None = None
        with self._lock:
            for i, s in enumerate(self._specs):
                if s.site != site:
                    continue
                if s.match is not None and not s.match(ctx):
                    continue
                s.seen += 1
                if s.seen <= s.after:
                    continue
                if s.times is not None and s.fired >= s.times:
                    continue
                if s.p < 1.0 and self._rng.random() >= s.p:
                    continue
                s.fired += 1
                self.log.append((site, i, {k: v for k, v in ctx.items()
                                           if isinstance(v, (str, int, float,
                                                             bool, tuple))}))
                sleep_s = s.latency_s
                if s.exc is not None:
                    raise_exc = s.exc if isinstance(s.exc, BaseException) \
                        else s.exc(f"injected fault at {site!r} "
                                   f"(spec {i}, firing {s.fired})")
                break
        if sleep_s > 0.0:
            time.sleep(sleep_s)
        if raise_exc is not None:
            raise raise_exc
