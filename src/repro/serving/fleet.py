"""Multi-process serving fleet: sharded executors behind one front-end.

The in-process :class:`~repro.serving.service.BlasService` is GIL-bound:
every stacked execution shares one interpreter, so batched throughput tops
out well below what the host's cores could do.  :class:`FleetService`
keeps the service's entire front half — ``submit``→Future, shape
bucketing, admission control, deadlines, backpressure, linger/steal
scheduling — and replaces only the execution transport: a flushed bucket
is shipped over a duplex pipe to one of N single-threaded **executor
processes**, each owning its own :class:`~repro.core.runtime.AdsalaRuntime`
and backend set, and the stacked result rides back as a pickled ndarray.

::

    submit() ─▶ buckets ─▶ ready queue ─▶ dispatcher thread i ═══ pipe ═══▶ executor process i
                 (front-end: one process)                             (runtime + backends + models)
                                                  ▲                        │
                                                  └── shared decision journal ◀┘  (flock appends,
                                                      mtime/offset polls)          every process)

Fleet-wide decision coherence is file-based, not socket-based: every
executor appends its miss-path decisions and quarantines to the ONE
decision journal of the shared :class:`~repro.core.registry.ModelRegistry`
(``flock``-guarded appends, see :func:`repro.core.durable.append_journal`)
and absorbs its peers' entries on a cheap size/offset poll
(:class:`~repro.core.durable.JournalFollower`) between requests.  A warm
member therefore pays **zero model evaluations** for any shape a peer has
already decided, and a knob one process quarantined is benched fleet-wide
within a poll interval.  Each executor resolves the artifact set for its
own **architecture fingerprint** (``ModelRegistry.resolve_fingerprint``:
exact → nearest → flat-root), so one registry directory serves a
heterogeneous fleet.

Supervision mirrors the in-process worker respawn machinery (PR 8): a
dead or hung executor process is killed and respawned by the dispatcher
that observed it, its claimed bucket is requeued, and a bucket that keeps
killing executors is failed after 3 recoveries instead of crash-looping
the fleet.
"""

from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import threading
import time
import traceback
from typing import Optional

import numpy as np

from repro.serving.service import (BlasService, ExecutionFailedError,
                                   ServeConfig, _resolve_exc,
                                   _resolve_result)

__all__ = ["FleetConfig", "FleetService", "ExecutorDiedError"]


class ExecutorDiedError(RuntimeError):
    """An executor process died (or hung past the request timeout) while
    holding a bucket; surfaced to callers only after respawn + requeue has
    been exhausted (as the ``__cause__`` of ExecutionFailedError)."""


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Topology/transport knobs of the multi-process fleet."""
    processes: int = 2            # executor processes (= dispatcher threads)
    registry_root: Optional[str] = None
                                  # shared ModelRegistry directory: artifact
                                  # sets (fingerprint-resolved) + the ONE
                                  # decision journal every member appends to
                                  # and absorbs from.  None = cold isolated
                                  # executors (no cross-process coherence)
    mp_context: str = "spawn"     # "spawn" (default, safe with the front
                                  # end's live threads) or "fork"/
                                  # "forkserver" where the caller knows
                                  # better
    cache_size: int = 256         # each executor runtime's decision LRU
    journal_poll_s: float = 0.05  # executor idle tick: absorb peers'
                                  # journal entries + heartbeat cadence
    start_timeout_s: float = 120.0    # executor ready handshake (includes
                                  # the child's jax import + artifact load)
    request_timeout_s: float = 120.0  # per-bucket round-trip bound; a
                                  # hung executor is killed + respawned
    fingerprint: Optional[dict] = None
                                  # architecture fingerprint override for
                                  # artifact resolution (None = each
                                  # executor probes its own host)
    membership: bool = True       # register executors in
                                  # <registry_root>/members/ (no-op
                                  # without a registry_root)

    def __post_init__(self) -> None:
        if self.processes < 1:
            raise ValueError("processes must be >= 1")
        if self.cache_size < 1:
            raise ValueError("cache_size must be >= 1")
        if self.journal_poll_s <= 0:
            raise ValueError("journal_poll_s must be > 0")
        if self.start_timeout_s <= 0:
            raise ValueError("start_timeout_s must be > 0")
        if self.request_timeout_s <= 0:
            raise ValueError("request_timeout_s must be > 0")
        if self.mp_context not in ("spawn", "fork", "forkserver"):
            raise ValueError("mp_context must be spawn/fork/forkserver")


# -- executor child ----------------------------------------------------------

def _executor_main(conn, spec: dict) -> None:
    """Executor process body: one runtime, one backend set, one pipe.

    Protocol (parent → child, all tuples):
      ("exec", seq, op, backend, columns, kw, width) → (seq, "ok", out, info)
                                                     | (seq, "err", msg, tb)
      ("stats", seq)                                 → (seq, "ok", dict)
      ("absorb", seq)                                → (seq, "ok", n_absorbed)
      ("close", seq)                                 → (seq, "ok", dict), exit

    The child announces ("ready", info) once its runtime is hydrated —
    fingerprint-resolved artifacts loaded, decision cache warm-started
    from the shared snapshot + journal — so the parent's measured window
    never includes jax import or model load time.
    """
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    from repro.core.runtime import AdsalaRuntime

    rt = AdsalaRuntime(cache_size=int(spec.get("cache_size", 256)))
    follower = None
    membership = None
    member = str(spec.get("member", f"executor-{os.getpid()}"))
    info: dict = {"pid": os.getpid(), "member": member, "loaded": 0,
                  "warm_started": 0, "resolution": {}}
    root = spec.get("registry_root")
    if root:
        from repro.core.registry import ModelRegistry, host_fingerprint
        base = ModelRegistry(root)
        fp = spec.get("fingerprint") or host_fingerprint()
        reg = base.resolve_fingerprint(fp)
        info["resolution"] = dict(base.last_fingerprint_resolution)
        info["loaded"] = reg.load_into(rt)
        try:
            info["warm_started"] = reg.load_decision_cache(rt)
        except Exception:        # noqa: BLE001 — cold start, never fatal
            info["warm_started"] = 0
        # journal every NEW decision/quarantine to the shared store, and
        # tail the same file for the peers' entries.  The follower starts
        # at offset 0: its first poll overlaps what load_decision_cache
        # already imported, which is harmless (idempotent) and closes the
        # window where a peer appends between the load and the first poll.
        rt.decision_journal = reg.journal_decision
        follower = reg.journal_follower()
        rt.absorb_journal(follower.poll())
        if spec.get("membership"):
            from repro.distributed.elastic import FleetMembership
            membership = FleetMembership(os.path.join(root, "members"))
            membership.register(member, slug=str(
                info["resolution"].get("slug", "")))

    def absorb() -> int:
        if follower is None or not follower.changed():
            return 0
        return rt.absorb_journal(follower.poll())

    def stats() -> dict:
        s = rt.stats
        return {"pid": os.getpid(), "member": member,
                "model_evals": s.model_evals, "cache_hits": s.cache_hits,
                "calls": s.calls, "default_calls": s.default_calls,
                "journal_absorbed": s.journal_absorbed,
                "quarantines": s.quarantines,
                "cache_len": rt.cache_len(),
                "loaded": info["loaded"],
                "warm_started": info["warm_started"],
                "resolution": info["resolution"]}

    conn.send(("ready", info))
    poll_s = float(spec.get("journal_poll_s", 0.05))
    try:
        while True:
            if not conn.poll(poll_s):
                absorb()                     # idle tick: fleet coherence
                if membership is not None:
                    try:
                        membership.heartbeat(member)
                    except OSError:
                        pass
                continue
            try:
                msg = conn.recv()
            except (EOFError, OSError):      # parent is gone
                return
            kind, seq = msg[0], msg[1]
            if kind == "close":
                conn.send((seq, "ok", stats()))
                return
            if kind == "stats":
                conn.send((seq, "ok", stats()))
                continue
            if kind == "absorb":
                conn.send((seq, "ok", absorb()))
                continue
            if kind != "exec":
                conn.send((seq, "err", f"unknown message {kind!r}", ""))
                continue
            _, _, op, backend, columns, kw, width = msg
            try:
                # absorb BEFORE selecting: a peer may have decided this
                # very shape — that is the zero-eval fleet warm path
                absorb()
                stacked = tuple(
                    np.stack(col + [col[-1]] * (width - len(col)))
                    for col in columns)
                from repro.kernels.ops import run_op
                t0 = time.monotonic()
                out = np.asarray(run_op(op, stacked, backend=backend,
                                        runtime=rt, stacked=True, **kw))
                exec_s = time.monotonic() - t0
                conn.send((seq, "ok", out, {"exec_s": exec_s}))
            except Exception as e:   # noqa: BLE001 — reply, don't die
                conn.send((seq, "err", f"{type(e).__name__}: {e}",
                           traceback.format_exc()))
    except (EOFError, OSError, BrokenPipeError):
        return


# -- parent-side executor handle ---------------------------------------------

class _Executor:
    """Parent handle for one executor process: owns the pipe, enforces the
    strict request/reply protocol (sequence-numbered), and serialises
    callers (the paired dispatcher thread vs. fleet_stats from the main
    thread) with a per-handle lock."""

    def __init__(self, ctx, spec: dict, name: str,
                 start_timeout_s: float) -> None:
        self.name = name
        self.conn, child_conn = mp.Pipe(duplex=True)
        self.proc = ctx.Process(target=_executor_main,
                                args=(child_conn, spec),
                                name=name, daemon=True)
        self.proc.start()
        child_conn.close()               # child's end lives in the child
        self._lock = threading.Lock()
        self._seq = 0
        self.ready_info: dict = {}
        if not self.conn.poll(start_timeout_s):
            self.kill()
            raise ExecutorDiedError(
                f"{name}: no ready handshake within {start_timeout_s}s")
        tag, payload = self.conn.recv()
        if tag != "ready":
            self.kill()
            raise ExecutorDiedError(f"{name}: bad handshake {tag!r}")
        self.ready_info = payload

    def alive(self) -> bool:
        return self.proc.is_alive()

    def request(self, kind: str, *payload, timeout: float):
        """One round-trip; returns the reply tuple tail (after the seq).
        Raises :class:`ExecutorDiedError` on a dead pipe or a timeout —
        the caller decides whether to respawn."""
        with self._lock:
            self._seq += 1
            seq = self._seq
            try:
                self.conn.send((kind, seq, *payload))
            except (OSError, ValueError, BrokenPipeError) as e:
                raise ExecutorDiedError(f"{self.name}: send failed") from e
            deadline = time.monotonic() + timeout
            while True:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise ExecutorDiedError(
                        f"{self.name}: no reply within {timeout}s")
                try:
                    if not self.conn.poll(min(remaining, 0.5)):
                        if not self.proc.is_alive():
                            raise ExecutorDiedError(
                                f"{self.name}: process died mid-request")
                        continue
                    reply = self.conn.recv()
                except (EOFError, OSError) as e:
                    raise ExecutorDiedError(
                        f"{self.name}: pipe closed mid-request") from e
                if reply[0] == seq:
                    return reply[1:]
                # stale reply from a timed-out predecessor: drop it

    def stop(self, timeout: float) -> None:
        """Graceful close → join → terminate → kill, in that order."""
        try:
            self.request("close", timeout=timeout)
        except ExecutorDiedError:
            pass
        self.proc.join(timeout=max(0.1, timeout))
        if self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(timeout=1.0)
        if self.proc.is_alive():
            self.proc.kill()
            self.proc.join(timeout=1.0)
        self.conn.close()

    def kill(self) -> None:
        try:
            self.proc.kill()
            self.proc.join(timeout=1.0)
        except Exception:        # noqa: BLE001 — already gone
            pass
        try:
            self.conn.close()
        except OSError:
            pass


# -- the fleet front-end ------------------------------------------------------

class FleetService(BlasService):
    """:class:`BlasService` front-end over N executor *processes*.

    Same ``submit``/``call``/``drain``/``close`` surface and the same
    bucketing/admission/backpressure semantics; only the execution
    transport differs (see the module docstring).  One dispatcher thread
    is paired 1:1 with each executor process, so ``config.workers`` is
    forced to ``fleet.processes``.

    The front end deliberately holds **no registry**: executors journal
    their own decisions into the shared store, and a parent-side
    ``save_decision_cache`` on close would snapshot the front end's
    (empty) cache and truncate the very journal the fleet's warm state
    lives in.
    """

    def __init__(self, *, fleet: Optional[FleetConfig] = None,
                 config: Optional[ServeConfig] = None,
                 runtime=None, faults=None) -> None:
        self.fleet = fleet if fleet is not None else FleetConfig()
        cfg = config if config is not None else ServeConfig()
        cfg = dataclasses.replace(cfg, workers=self.fleet.processes)
        self._executors: list[_Executor] = []
        self._spawned = 0
        self._ctx = mp.get_context(self.fleet.mp_context)
        try:
            for _ in range(self.fleet.processes):
                self._executors.append(self._spawn_executor())
        except BaseException:
            for ex in self._executors:
                ex.kill()
            raise
        super().__init__(runtime=runtime, config=cfg, registry=None,
                         retuner=None, faults=faults)

    # -- executor lifecycle ---------------------------------------------------
    def _spawn_executor(self) -> _Executor:
        f = self.fleet
        self._spawned += 1
        member = f"executor-{os.getpid()}-{self._spawned}"
        spec = {"registry_root": f.registry_root,
                "cache_size": f.cache_size,
                "journal_poll_s": f.journal_poll_s,
                "fingerprint": f.fingerprint,
                "membership": f.membership,
                "member": member}
        return _Executor(self._ctx, spec, member, f.start_timeout_s)

    def add_member(self) -> dict:
        """Grow the fleet by one executor (plus its paired dispatcher
        thread) at runtime — the warm-join path: the newcomer hydrates
        from the shared snapshot + journal before its ready handshake, so
        it serves previously-decided shapes with zero model evals.
        Returns the newcomer's ready info (warm_started, resolution...)."""
        ex = self._spawn_executor()
        with self._mutex:
            if self._closed:
                ex.kill()
                raise RuntimeError("cannot add a member to a closed fleet")
            idx = len(self._executors)
            self._executors.append(ex)
            self._claims.append(None)
            t = threading.Thread(target=self._worker_main, args=(idx,),
                                 name=f"blas-serve-worker-{idx}",
                                 daemon=True)
            self._workers.append(t)
        t.start()
        return dict(ex.ready_info)

    # -- transport ------------------------------------------------------------
    def _prewarm(self, buckets: list) -> None:
        # knob decisions happen inside the executors (each owns the models);
        # a parent-side select_many would be a modelless no-op at best
        return

    def _dispatch(self, bucket, reqs: list, worker_idx: int) -> None:
        ex = self._executors[worker_idx]
        _backend, op, dtype_bytes, dims = bucket.key[:4]
        width = self._pad_width(len(reqs), _backend)
        columns = [[r.operands[i] for r in reqs]
                   for i in range(len(reqs[0].operands))]
        t_exec = time.monotonic()
        try:
            reply = ex.request("exec", op, _backend, columns, reqs[0].kw,
                               width, timeout=self.fleet.request_timeout_s)
        except ExecutorDiedError as e:
            self._recover_executor(bucket, reqs, worker_idx, e)
            return
        t_done = time.monotonic()
        if reply[0] != "ok":
            # the executor survived and reported a typed failure (bad
            # operands, backend raise past the child's own resolution):
            # terminal for this bucket, with the remote traceback chained
            exc = ExecutionFailedError(
                f"fleet executor failed bucket {bucket.key[:4]}: "
                f"{reply[1]}\n--- remote traceback ---\n{reply[2]}")
            n = sum(_resolve_exc(r.future, exc) for r in reqs)
            with self._mutex:
                self.stats.failed += n
                self._pending -= n
                self._done.notify_all()
            return
        out, rinfo = reply[1], reply[2]
        exec_span = float(rinfo.get("exec_s", t_done - t_exec))
        queue_span = sum(t_exec - r.t_submit for r in reqs)
        # telemetry lands on the FRONT END's runtime: admission control's
        # deadline-feasibility estimates read the bucket's mean queue
        # delay from here
        self.runtime.record_batch(op, dims, dtype_bytes, _backend,
                                  len(reqs), exec_seconds=exec_span,
                                  exec_items=width,
                                  queue_seconds=queue_span)
        now = time.monotonic()
        resolved = 0
        latency = 0.0
        for i, r in enumerate(reqs):
            if _resolve_result(r.future, np.asarray(out[i])):
                resolved += 1
                latency += now - r.t_submit
        with self._mutex:
            self.stats.completed += resolved
            self.stats.batches += 1
            self.stats.max_batch = max(self.stats.max_batch, len(reqs))
            self.stats.padded_items += width - len(reqs)
            self.stats.latency_sum += latency
            self.stats.queue_sum += queue_span
            self.stats.exec_sum += exec_span * resolved
            self._pending -= resolved
            self._done.notify_all()

    def _recover_executor(self, bucket, reqs: list, worker_idx: int,
                          cause: ExecutorDiedError) -> None:
        """The process-level mirror of the thread-worker respawn machinery:
        kill the casualty, spawn a replacement into the same slot, requeue
        the bucket — and fail it (typed, with the cause chained) once it
        has burned through 3 recoveries."""
        self._executors[worker_idx].kill()
        bucket.requests = [r for r in reqs if not r.future.done()]
        bucket.recovered += 1
        respawned = False
        if not self._closed:
            try:
                self._executors[worker_idx] = self._spawn_executor()
                respawned = True
            except ExecutorDiedError:
                pass                     # fail the bucket below
        with self._mutex:
            self.stats.worker_respawns += 1
        if not bucket.requests:
            return
        if respawned and bucket.recovered <= 3 and not self._closed:
            self._ready.put(bucket)
            return
        exc = ExecutionFailedError(
            f"bucket {bucket.key[:4]} lost its executor "
            f"{bucket.recovered} time(s); not requeueing again")
        exc.__cause__ = cause
        n = sum(_resolve_exc(r.future, exc) for r in bucket.requests)
        with self._mutex:
            self.stats.failed += n
            self._pending -= n
            self._done.notify_all()

    # -- observability --------------------------------------------------------
    def fleet_stats(self, timeout: float = 10.0) -> list[dict]:
        """One stats dict per live executor (model_evals, cache_len,
        journal_absorbed, warm_started, fingerprint resolution...); a dead
        executor contributes ``{"alive": False}``."""
        out = []
        for ex in list(self._executors):
            try:
                reply = ex.request("stats", timeout=timeout)
                d = dict(reply[1])
                d["alive"] = True
            except ExecutorDiedError:
                d = {"alive": False, "member": ex.name}
            out.append(d)
        return out

    def absorb_now(self, timeout: float = 10.0) -> int:
        """Force every executor to poll the shared journal immediately;
        returns the total records absorbed (deterministic tests' hook —
        production members absorb on their idle tick)."""
        total = 0
        for ex in list(self._executors):
            try:
                reply = ex.request("absorb", timeout=timeout)
                total += int(reply[1])
            except ExecutorDiedError:
                pass
        return total

    # -- lifecycle ------------------------------------------------------------
    def close(self, timeout: float = 30.0) -> None:
        with self._mutex:
            already = self._closed
        super().close(timeout=timeout)
        if already:
            return
        per_exec = max(0.5, timeout / max(1, len(self._executors)))
        for ex in self._executors:
            ex.stop(timeout=per_exec)
