"""Per-(backend, op) error budgets for the degradation ladder.

PR 8's ladder treats every rung as healthy until proven otherwise *per
bucket*: a backend that has been crashing for minutes still gets its full
retry schedule (attempts × exponential backoff sleeps) from every new
bucket before the ladder moves on.  Under sustained rung failure that cost
is pure waste — the outcome is already known.

An :class:`ErrorBudgetLedger` gives each ``(backend, op)`` rung a rolling
failure-rate window and a three-state breaker:

    closed     rate within budget → the rung runs its normal ladder step
    open       budget exhausted → the rung is SKIPPED outright (no
               attempts, no retries, no backoff sleeps) until the probe
               interval elapses
    half-open  probe due → exactly ONE single-attempt execution is let
               through; success closes the breaker (window cleared),
               failure re-opens it for another interval

The ledger is deliberately ignorant of the service: callers ask
:meth:`admit` before a rung and :meth:`record` after every real attempt.
State transitions happen lazily inside those two calls under one lock, and
``now`` is injectable everywhere, so chaos scenarios replay bit-for-bit.

Budget state survives restarts by riding the decision cache:
``AdsalaRuntime.attach_budgets`` hooks a ledger into ``export_cache`` /
``import_cache`` as ``{"budget": 1, ...}`` records with the open-breaker
probe timing rebased to remaining seconds — a rung that was burning its
budget when the process died stays skipped across the restart instead of
getting a free storm of retries.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time

__all__ = ["BudgetConfig", "ErrorBudgetLedger"]


@dataclasses.dataclass(frozen=True)
class BudgetConfig:
    """Budget policy shared by every rung the ledger tracks."""
    window: int = 16              # rolling outcome window per (backend, op)
    threshold: float = 0.5        # failure rate that exhausts the budget
    min_count: int = 4            # outcomes required before skipping at all
    probe_interval_s: float = 5.0  # open → half-open probe cadence

    def __post_init__(self) -> None:
        if self.window < 1:
            raise ValueError("window must be >= 1")
        if not 0.0 < self.threshold <= 1.0:
            raise ValueError("threshold must be in (0, 1]")
        if self.min_count < 1:
            raise ValueError("min_count must be >= 1")
        if self.probe_interval_s <= 0:
            raise ValueError("probe_interval_s must be > 0")


class _Rung:
    __slots__ = ("outcomes", "state", "probe_due", "probe_started",
                 "opens", "skips", "probes")

    def __init__(self, window: int) -> None:
        self.outcomes: collections.deque[bool] = \
            collections.deque(maxlen=window)
        self.state = "closed"
        self.probe_due = 0.0        # monotonic; meaningful while open
        self.probe_started = 0.0    # monotonic; meaningful while half-open
        self.opens = 0
        self.skips = 0
        self.probes = 0

    def failure_rate(self) -> float:
        n = len(self.outcomes)
        return (n - sum(self.outcomes)) / n if n else 0.0


class ErrorBudgetLedger:
    """Thread-safe rolling failure budgets keyed ``(backend, op)``."""

    def __init__(self, config: BudgetConfig | None = None) -> None:
        self.config = config if config is not None else BudgetConfig()
        self._lock = threading.Lock()
        self._rungs: dict[tuple[str, str], _Rung] = {}

    def _rung(self, backend: str, op: str) -> _Rung:
        key = (backend, op)
        rung = self._rungs.get(key)
        if rung is None:
            rung = self._rungs[key] = _Rung(self.config.window)
        return rung

    # -- the two calls the ladder makes ---------------------------------------
    def admit(self, backend: str, op: str, *,
              now: float | None = None) -> str:
        """Gate one ladder rung: ``"closed"`` (run the normal step),
        ``"probe"`` (run exactly one attempt, no retries), or ``"skip"``
        (do not execute at all)."""
        if now is None:
            now = time.monotonic()
        cfg = self.config
        with self._lock:
            rung = self._rungs.get((backend, op))
            if rung is None:
                return "closed"           # no history: innocent
            if rung.state == "closed":
                if len(rung.outcomes) >= cfg.min_count and \
                        rung.failure_rate() > cfg.threshold:
                    rung.state = "open"
                    rung.probe_due = now + cfg.probe_interval_s
                    rung.opens += 1
                    rung.skips += 1
                    return "skip"
                return "closed"
            if rung.state == "open":
                if now >= rung.probe_due:
                    rung.state = "half_open"
                    rung.probe_started = now
                    rung.probes += 1
                    return "probe"
                rung.skips += 1
                return "skip"
            # half-open: one probe is already in flight.  If its owner died
            # without recording (worker crash), reclaim after a full
            # interval instead of wedging the rung open forever.
            if now - rung.probe_started >= cfg.probe_interval_s:
                rung.probe_started = now
                rung.probes += 1
                return "probe"
            rung.skips += 1
            return "skip"

    def record(self, backend: str, op: str, ok: bool, *,
               now: float | None = None) -> None:
        """Book the outcome of one real execution attempt on a rung."""
        if now is None:
            now = time.monotonic()
        with self._lock:
            rung = self._rung(backend, op)
            if rung.state == "half_open":
                if ok:
                    # probe succeeded: close and forgive the window — the
                    # rung starts its next budget from a clean slate
                    rung.state = "closed"
                    rung.outcomes.clear()
                    rung.outcomes.append(True)
                else:
                    rung.state = "open"
                    rung.probe_due = now + self.config.probe_interval_s
                return
            rung.outcomes.append(bool(ok))

    # -- introspection / persistence ------------------------------------------
    def snapshot(self) -> dict[tuple[str, str], dict]:
        """Per-rung view for stats surfaces: state, rolling failure rate,
        window fill, and the skip/probe/open counters."""
        with self._lock:
            return {key: {"state": r.state,
                          "failure_rate": round(r.failure_rate(), 4),
                          "window": len(r.outcomes),
                          "skips": r.skips, "probes": r.probes,
                          "opens": r.opens}
                    for key, r in sorted(self._rungs.items())}

    def export(self, *, now: float | None = None) -> list[dict]:
        """JSON-safe ``{"budget": 1, ...}`` records (export_cache shape).
        Open breakers carry ``probe_in_s`` — remaining seconds until the
        next probe — so the skip survives a restart without pinning the
        dead process's monotonic clock."""
        if now is None:
            now = time.monotonic()
        with self._lock:
            out = []
            for (backend, op), r in sorted(self._rungs.items()):
                if not r.outcomes and r.state == "closed":
                    continue               # nothing worth persisting
                rec = {"budget": 1, "backend": backend, "op": op,
                       "outcomes": [int(o) for o in r.outcomes],
                       "state": "open" if r.state == "half_open"
                       else r.state}
                if rec["state"] == "open":
                    # a half-open breaker (probe in flight at export time)
                    # restarts with its probe due immediately
                    rec["probe_in_s"] = max(0.0, r.probe_due - now) \
                        if r.state == "open" else 0.0
                out.append(rec)
            return out

    def import_records(self, records: list[dict], *,
                       now: float | None = None) -> int:
        """Restore rungs from :meth:`export` records; malformed records are
        skipped (returns how many imported).  A restored open breaker's
        probe comes due ``probe_in_s`` seconds from *now*."""
        if now is None:
            now = time.monotonic()
        n = 0
        with self._lock:
            for rec in records:
                try:
                    if not rec.get("budget"):
                        continue
                    backend, op = str(rec["backend"]), str(rec["op"])
                    outcomes = [bool(int(o)) for o in rec.get("outcomes", [])]
                    state = str(rec.get("state", "closed"))
                    if state not in ("closed", "open"):
                        raise ValueError(state)
                    rung = self._rung(backend, op)
                    rung.outcomes.clear()
                    rung.outcomes.extend(outcomes[-self.config.window:])
                    rung.state = state
                    if state == "open":
                        rung.probe_due = now + float(
                            rec.get("probe_in_s", 0.0))
                    n += 1
                except Exception:        # noqa: BLE001 — tolerate garbage
                    continue
        return n

    def reset(self) -> None:
        with self._lock:
            self._rungs.clear()
