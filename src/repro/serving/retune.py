"""Online feedback loop: drift-aware retuning from serving telemetry.

The install-time pipeline (paper Fig. 1a) freezes its models against a
calibration sweep taken once, on one machine state.  A serving process sees
traffic and machine conditions *drift* away from that sweep — co-tenancy,
thermal throttling, allocator fragmentation, a traffic mix the Halton
samples never covered — and the paper's own premise ("predictions are only
as good as the measurements behind them", after Xia & Barnard's GEMM
feedback loop) then cuts against the frozen artifact.  Serving already
measures ground truth: every stacked bucket execution records its
execution-only span in :class:`~repro.core.runtime.BucketStats`.  This
module closes the loop::

    BucketStats deltas ──► (dims, chosen knob, measured s/item) samples
         │                        │
         │ per (backend, op, dtype) shard
         ▼                        ▼
    EWMA of |measured − predicted| / predicted      (drift signal)
         │ > drift_threshold for ≥ min_samples
         ▼
    blended install ∪ telemetry dataset ──► refit (same install pipeline)
         ▼
    ModelRegistry.save (version bump) ──► AdsalaRuntime.swap (atomic)

Drift signal
    Each telemetry sample compares the measured per-item execution time of
    a bucket against the *registered predictor's* prediction for the knob
    that was actually chosen (the decision cache's knob for that key).
    The relative error feeds an exponentially weighted moving average per
    ``(backend, op, dtype_bytes)`` subroutine; crossing
    ``drift_threshold`` with at least ``min_samples`` observations triggers
    a retune of that subroutine only.

Blending
    Serving telemetry is exploitation-only — it measures the *chosen* knob
    at the *served* dims, never the alternatives.  The blend therefore
    builds full candidate rows: for each telemetry sample, the predicted
    times of every knob with the measured knob's column overwritten by the
    measurement (replicated ``telemetry_repeat``× so traffic outweighs the
    stale sweep where they conflict).  With ``correct_install`` (default),
    the install rows' columns for measured knobs are additionally rescaled
    by the EWMA measured/predicted ratio — the drift observed on served
    dims extends to the rest of the knob's calibration column, which is
    what lets a *global* timing shift (the common case: the whole backend
    got slower for one block shape) flip decisions outside the served
    region too.  LOF outlier removal is OFF during refits: drifted
    measurements are exactly the points LOF would discard.

Swap semantics
    The refit subroutine is recompiled through the same
    :func:`~repro.core.fastpath.compile_predictor` used at artifact load,
    persisted through the registry (stamping the next monotonically
    increasing ``artifact_version``), and hot-swapped with
    :meth:`AdsalaRuntime.swap`: in-flight selects finish on the old
    predictor, new selects see the new one, and the subroutine's
    decision-cache entries are invalidated in the same critical section —
    post-swap decisions are bit-identical to a fresh process loading the
    new artifact.

Reproducibility
    The loop is opt-in.  A reproduction run that must serve the paper's
    frozen artifacts simply never constructs a :class:`Retuner` (or passes
    ``retuner=None`` to :class:`~repro.serving.BlasService`, the default);
    ``Retuner.stop()`` also halts a live loop at any point.
"""

from __future__ import annotations

import collections
import dataclasses
import random
import threading
import time
from typing import Optional

import numpy as np

from repro.core.dataset import TimingDataset
from repro.core.runtime import AdsalaRuntime
from repro.core.tuner import install_subroutine

__all__ = ["Retuner", "RetuneConfig", "RetuneStats"]


@dataclasses.dataclass(frozen=True)
class RetuneConfig:
    """Knobs of the online feedback loop."""
    ewma_alpha: float = 0.25       # weight of the newest relative error
    drift_threshold: float = 0.5   # EWMA rel. error that triggers a retune
    min_samples: int = 8           # per-subroutine floor before triggering
    telemetry_cap: int = 512       # ring-buffer cap per subroutine
    telemetry_repeat: int = 4      # replication of telemetry rows in blend
    correct_install: bool = True   # rescale install rows of measured knobs
    interval_s: float = 2.0        # background poll period
    #: model families to refit over (None = the artifact's own family —
    #: keeps the refit cheap and the decision surface comparable)
    candidates: Optional[tuple] = None
    tune_trials: int = 2           # hyper-parameter trials per refit
    use_lof: bool = False          # see module docstring: LOF eats drift
    seed: int = 0                  # deterministic refits
    #: per-step probability of overriding ONE served bucket's cached
    #: decision with a random non-argmin, non-quarantined knob for a single
    #: step — serving telemetry is exploitation-only, so without occasional
    #: exploration a refit blend never gets a *measured* row for the
    #: columns the argmin policy skips, and ``correct_install`` has nothing
    #: to anchor them on.  0 (the default) disables exploration — the
    #: reproducibility posture, like the retuner itself.
    explore_epsilon: float = 0.0

    def __post_init__(self) -> None:
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if self.drift_threshold <= 0:
            raise ValueError("drift_threshold must be > 0")
        if self.min_samples < 1:
            raise ValueError("min_samples must be >= 1")
        if self.telemetry_cap < 1 or self.telemetry_repeat < 1:
            raise ValueError("telemetry_cap/telemetry_repeat must be >= 1")
        if not 0.0 <= self.explore_epsilon < 1.0:
            raise ValueError("explore_epsilon must be in [0, 1)")


@dataclasses.dataclass
class RetuneStats:
    samples: int = 0            # telemetry samples ingested
    skipped: int = 0            # bucket deltas with no usable signal
    drift_events: int = 0       # threshold crossings observed by step()
    retunes: int = 0            # successful refit + swap cycles
    swap_invalidations: int = 0  # decision-cache entries invalidated
    errors: int = 0
    last_error: Optional[str] = None
    observe_failures: int = 0   # telemetry-ingestion raises (survived)
    refit_failures: int = 0     # retune raises (survived; backoff applied)
    explorations: int = 0       # epsilon decision-cache overrides served
    abandoned_stops: int = 0    # stop() joins that timed out mid-refit
                                # (thread kept halted, never nulled alive)


class _SubState:
    """Per-``(backend, op, dtype_bytes)`` drift/telemetry accumulator."""
    __slots__ = ("ewma", "n", "knob_ratio", "samples", "cap")

    def __init__(self, cap: int) -> None:
        self.ewma: float | None = None
        self.n = 0
        #: knob index -> EWMA of measured/predicted (the per-knob drift
        #: correction the blend applies to install rows)
        self.knob_ratio: dict[int, float] = {}
        #: (dims, knob index) -> latest measured seconds/item, newest last.
        #: Keyed, not appended: a re-measured bucket REPLACES its old
        #: sample — after a drift, the pre-drift measurement of the same
        #: bucket is exactly the contradictory supervision that would pull
        #: the refit halfway back to the stale surface.
        self.samples: collections.OrderedDict = collections.OrderedDict()
        self.cap = cap

    def put(self, dims: tuple, idx: int, measured: float) -> None:
        k = (dims, idx)
        self.samples.pop(k, None)           # re-insert at the fresh end
        self.samples[k] = measured
        while len(self.samples) > self.cap:
            self.samples.popitem(last=False)


class Retuner:
    """Background retrainer closing the serving→install feedback loop.

    Drive it manually (``observe()`` / ``step()`` — deterministic, used by
    tests and the bench) or as a thread (``start()`` / ``stop()`` — what
    :class:`~repro.serving.BlasService` does when given a retuner).

    The loop only ever *reads* public runtime state (``stats.buckets``,
    ``peek``, ``predictor``, ``subroutine``) and mutates it through the
    atomic :meth:`AdsalaRuntime.swap` seam, so it is safe next to live
    serving traffic by construction.
    """

    def __init__(self, runtime: AdsalaRuntime, *, registry=None,
                 config: Optional[RetuneConfig] = None,
                 faults=None) -> None:
        self.runtime = runtime
        self.registry = registry
        self.config = config if config is not None else RetuneConfig()
        self.stats = RetuneStats()
        #: optional repro.serving.faults.FaultPlan (chaos harness)
        self._faults = faults
        #: retune audit log: one dict per applied swap
        self.events: list[dict] = []
        self._state: dict[tuple, _SubState] = {}
        #: bucket key -> (exec_seconds, exec_items) already consumed
        self._seen: dict[tuple, tuple[float, int]] = {}
        #: active exploration overrides: bucket key -> served non-argmin knob
        self._exploring: dict[tuple, object] = {}
        self._explore_rng = random.Random(self.config.seed)
        self._lock = threading.Lock()       # observe/step vs stop
        self._thread: Optional[threading.Thread] = None
        self._halt = threading.Event()

    # -- telemetry ingestion --------------------------------------------------
    def observe(self) -> int:
        """Ingest new ``BucketStats`` execution deltas as telemetry samples;
        returns how many samples were added.

        A sample needs three things: a positive execution delta, the knob
        the decision cache currently holds for the bucket (``peek`` — a
        just-invalidated key contributes nothing until it is re-decided),
        and a finite positive prediction from the registered predictor."""
        if self._faults is not None:
            self._faults.fire("retuner_observe")
        added = 0
        snapshot = self.runtime.stats.buckets
        with self._lock:
            for key, b in snapshot.items():
                prev_s, prev_i = self._seen.get(key, (0.0, 0))
                d_secs = b.exec_seconds - prev_s
                d_items = b.exec_items - prev_i
                if d_items <= 0 or d_secs <= 0.0:
                    continue
                self._seen[key] = (b.exec_seconds, b.exec_items)
                backend, op, dtype_bytes, dims = key
                sample = self._ingest(backend, op, dtype_bytes, dims,
                                      d_secs / d_items)
                if sample:
                    added += 1
                else:
                    self.stats.skipped += 1
        return added

    def _ingest(self, backend: str, op: str, dtype_bytes: int, dims: tuple,
                measured: float) -> bool:
        rt = self.runtime
        if not rt.has(op, dtype_bytes, backend):
            return False
        knob = rt.peek(op, dims, dtype_bytes, backend)
        if knob is None:
            return False
        sub = rt.subroutine(op, dtype_bytes, backend)
        space = getattr(sub, "knob_space", None)
        if space is None:
            return False
        try:
            idx = space.index(knob)
        except (KeyError, ValueError):
            return False            # knob from a space that no longer exists
        cp = rt.predictor(op, dtype_bytes, backend)
        try:
            times = cp.predict_times(dims) if cp is not None \
                else sub.predict_times(dims)
            predicted = float(times[idx])
        except Exception:           # noqa: BLE001 — stub/uncompilable model
            return False
        if not np.isfinite(predicted) or predicted <= 0.0:
            return False
        sub_key = (backend, op, dtype_bytes)
        st = self._state.get(sub_key)
        if st is None:
            st = self._state[sub_key] = _SubState(self.config.telemetry_cap)
        a = self.config.ewma_alpha
        rel_err = abs(measured - predicted) / predicted
        st.ewma = rel_err if st.ewma is None \
            else a * rel_err + (1.0 - a) * st.ewma
        ratio = measured / predicted
        prev = st.knob_ratio.get(idx)
        st.knob_ratio[idx] = ratio if prev is None \
            else a * ratio + (1.0 - a) * prev
        st.put(tuple(int(d) for d in dims), idx, float(measured))
        st.n += 1
        self.stats.samples += 1
        return True

    def drift(self, op: str, dtype_bytes: int = 4,
              backend: str = "pallas") -> tuple[Optional[float], int]:
        """(EWMA relative error, sample count) for one subroutine."""
        st = self._state.get((backend, op, dtype_bytes))
        return (None, 0) if st is None else (st.ewma, st.n)

    def drifted(self) -> list[tuple]:
        """Subroutine keys whose drift signal is over the trigger."""
        cfg = self.config
        return [k for k, st in self._state.items()
                if st.n >= cfg.min_samples and st.ewma is not None
                and st.ewma > cfg.drift_threshold]

    # -- the retune cycle -----------------------------------------------------
    def step(self) -> list[tuple]:
        """One feedback-loop iteration: ingest telemetry, retune every
        drifted subroutine, run the epsilon-exploration pass; returns the
        list of swapped subroutine keys.  Deterministic given the runtime's
        bucket state — the bench and the tests drive this directly.

        Every phase is individually fault-isolated: an observe raise leaves
        the drift state stale but the step alive (``observe_failures``), a
        refit raise is counted (``errors``/``refit_failures``) and the loop
        keeps serving the old model."""
        try:
            self.observe()
        except Exception as e:          # noqa: BLE001 — stale but alive
            self.stats.observe_failures += 1
            self.stats.last_error = f"{type(e).__name__}: {e}"
        swapped = []
        for sub_key in self.drifted():
            self.stats.drift_events += 1
            try:
                self.retune(sub_key)
                swapped.append(sub_key)
            except Exception as e:      # noqa: BLE001 — keep serving
                self.stats.errors += 1
                self.stats.refit_failures += 1
                self.stats.last_error = f"{type(e).__name__}: {e}"
        try:
            self._explore()
        except Exception as e:          # noqa: BLE001 — strictly optional
            self.stats.last_error = f"{type(e).__name__}: {e}"
        return swapped

    # -- bounded-epsilon exploration ------------------------------------------
    def _explore(self) -> int:
        """With probability ``explore_epsilon``, override ONE served
        bucket's cached decision with a random non-argmin knob for the
        coming step (restored — invalidated back to the model's choice — at
        the next call, after :meth:`observe` has ingested its measurement).

        Serving telemetry is exploitation-only: without this, a refit blend
        never sees a measured row for a column the argmin policy skips, and
        ``correct_install`` extrapolates those columns from nothing.
        Quarantined knobs are excluded — exploration must never re-serve a
        config that is currently benched for crashing."""
        eps = self.config.explore_epsilon
        if not eps:
            return 0
        rt = self.runtime
        # restore first: the observe() that preceded this call has already
        # ingested the explored knob's measurement
        for (backend, op, dtype_bytes, dims) in list(self._exploring):
            rt.invalidate_decision(op, dims, dtype_bytes, backend)
        self._exploring.clear()
        if self._explore_rng.random() >= eps:
            return 0
        served = sorted(k for k in rt.stats.buckets
                        if rt.has(k[1], k[2], k[0])
                        and rt.peek(k[1], k[3], k[2], k[0]) is not None)
        if not served:
            return 0
        key = served[self._explore_rng.randrange(len(served))]
        backend, op, dtype_bytes, dims = key
        space = getattr(rt.subroutine(op, dtype_bytes, backend),
                        "knob_space", None)
        if space is None:
            return 0
        current = rt.peek(op, dims, dtype_bytes, backend)
        cands = [c for c in space.candidates
                 if c != current
                 and not rt.is_quarantined(op, dtype_bytes, backend, c)]
        if not cands:
            return 0
        knob = cands[self._explore_rng.randrange(len(cands))]
        if rt.override_decision(op, dims, dtype_bytes, backend, knob):
            self._exploring[key] = knob
            self.stats.explorations += 1
            return 1
        return 0

    def retune(self, sub_key: tuple) -> "object":
        """Refit one subroutine on the blended install+telemetry dataset and
        hot-swap it into the runtime; returns the new subroutine."""
        backend, op, dtype_bytes = sub_key
        rt = self.runtime
        if self._faults is not None:
            self._faults.fire("retuner_refit", sub_key=sub_key)
        sub = rt.subroutine(op, dtype_bytes, backend)
        with self._lock:
            st = self._state.get(sub_key)
            if st is None or not st.samples:
                raise RuntimeError(f"no telemetry for {sub_key}")
            blended = self._blend(sub, st)
        cfg = self.config
        candidates = cfg.candidates if cfg.candidates is not None \
            else (sub.model_name,)
        new_sub = install_subroutine(
            op, sub.knob_space, lambda dims, knob: 0.0, dataset=blended,
            dtype_bytes=dtype_bytes, candidates=candidates,
            log_target=sub.log_target, use_lof=cfg.use_lof,
            tune_trials=cfg.tune_trials, seed=cfg.seed, keep_dataset=True,
            backend=getattr(sub, "backend", backend))
        if self.registry is not None:
            # stamps the next monotonically increasing artifact_version and
            # persists, so a restarted process loads THIS generation and a
            # pre-swap decision cache is rejected at import
            self.registry.save(new_sub)
        else:
            new_sub.artifact_version = \
                int(getattr(sub, "artifact_version", 0) or 0) + 1
        invalidated = rt.swap(new_sub, backend=backend)
        with self._lock:
            self._state.pop(sub_key, None)   # fresh signal vs the new model
        self.stats.retunes += 1
        self.stats.swap_invalidations += invalidated
        self.events.append({
            "sub_key": sub_key, "model": new_sub.model_name,
            "artifact_version": int(new_sub.artifact_version),
            "invalidated": invalidated,
            "telemetry_rows": len(st.samples)})
        return new_sub

    @staticmethod
    def _equiv_groups(space, dims_arr: np.ndarray) -> list[list[int]]:
        """Feature-equivalence classes of the knob space over ``dims_arr``.

        The Table-III features see a knob only through its parallelism
        measure ``nt`` — two knobs whose nt agrees on every dims row (the
        bk-twins of a GEMM block space, for example) are ONE point in
        feature space.  Supervision must treat them identically: correcting
        or overriding just one of them hands the model contradictory
        targets for the same feature vector, and the uncorrected twin's
        stale cheap time wins the argmin right back."""
        P = np.stack([space.parallelism_vec(tuple(int(v) for v in d))
                      for d in dims_arr])            # (S, K)
        sig: dict[bytes, list[int]] = {}
        for j in range(P.shape[1]):
            sig.setdefault(np.ascontiguousarray(P[:, j]).tobytes(),
                           []).append(j)
        groups = [None] * P.shape[1]
        for members in sig.values():
            for j in members:
                groups[j] = members
        return groups

    def _blend(self, sub, st: _SubState) -> TimingDataset:
        """Install ∪ telemetry dataset (see module docstring, "Blending")."""
        space = sub.knob_space
        K = len(space)
        cp = sub.compiled() if hasattr(sub, "compiled") else None
        samples = [(d, idx, v) for (d, idx), v in st.samples.items()]
        dims_t = np.asarray([d for d, _, _ in samples], dtype=np.int64)
        ds = getattr(sub, "dataset", None)
        have_install = ds is not None and ds.n_samples
        probe_dims = np.concatenate(
            [np.asarray(ds.dims, dtype=np.int64), dims_t]) \
            if have_install else dims_t
        groups = self._equiv_groups(space, probe_dims)
        if cp is not None:
            rows = np.asarray(cp.predict_times_batch(
                [tuple(d) for d, _, _ in samples]), dtype=np.float64)
        else:
            rows = np.stack([np.asarray(sub.predict_times(tuple(d)),
                                        dtype=np.float64)
                             for d, _, _ in samples])
        for r, (_d, idx, measured) in zip(rows, samples):
            r[groups[idx]] = measured   # ground truth beats prediction
        rep = self.config.telemetry_repeat
        dims_t = np.tile(dims_t, (rep, 1))
        rows = np.tile(rows, (rep, 1))
        if have_install:
            inst_times = np.array(ds.times, dtype=np.float64, copy=True)
            if self.config.correct_install:
                # one factor per column; measured twins in one equivalence
                # group share their ratio (geometric mean on collision)
                log_f = np.zeros(K)
                votes = np.zeros(K, dtype=np.int64)
                for idx, ratio in st.knob_ratio.items():
                    for j in groups[idx]:
                        log_f[j] += np.log(ratio)
                        votes[j] += 1
                nz = votes > 0
                inst_times[:, nz] *= np.exp(log_f[nz] / votes[nz])
            dims_all = np.concatenate([np.asarray(ds.dims, dtype=np.int64),
                                       dims_t])
            times_all = np.concatenate([inst_times, rows])
        else:                           # telemetry-only refit
            dims_all, times_all = dims_t, rows
        assert times_all.shape[1] == K
        return TimingDataset(op=sub.op, dims=dims_all, times=times_all,
                             knob_space=space, dtype_bytes=sub.dtype_bytes)

    # -- background thread ----------------------------------------------------
    def start(self) -> None:
        """Run the loop on a daemon thread every ``interval_s``.  Idempotent
        while running."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._halt.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="adsala-retuner", daemon=True)
        self._thread.start()

    def stop(self, timeout: float = 10.0) -> bool:
        """Halt the loop; idempotent.  Returns True when the thread fully
        stopped (no swap runs after a True return).  A join that times out
        — the thread is mid-refit and a refit can outlast any reasonable
        close budget — returns False and counts an abandoned stop; the
        thread reference is *kept* (not leaked silently, not nulled while
        alive) so a later stop() can finish the join, and the halted loop
        exits on its own once the in-flight step completes."""
        self._halt.set()
        t = self._thread
        if t is None:
            return True
        t.join(timeout=timeout)
        if t.is_alive():
            self.stats.abandoned_stops += 1
            return False
        self._thread = None
        return True

    def _loop(self) -> None:
        # consecutive failing steps back the poll off exponentially (capped
        # at 8× interval): a persistently crashing refit or observe must
        # neither kill the daemon nor spin it at full rate against the
        # same error
        failures = 0
        while not self._halt.wait(
                self.config.interval_s * min(1 << failures, 8)):
            before = (self.stats.errors + self.stats.observe_failures)
            t0 = time.perf_counter()
            try:
                self.step()
            except Exception as e:      # noqa: BLE001 — never kill serving
                self.stats.errors += 1
                self.stats.last_error = f"{type(e).__name__}: {e}"
            failed = (self.stats.errors
                      + self.stats.observe_failures) > before
            failures = failures + 1 if failed else 0
            # a pathological refit storm must not starve the stop signal
            if time.perf_counter() - t0 > 10 * self.config.interval_s:
                continue
