"""Data substrate: deterministic, shardable, resumable pipelines."""
from .pipeline import ByteCorpusDataset, SyntheticLMDataset, make_global_batch
__all__ = ["ByteCorpusDataset", "SyntheticLMDataset", "make_global_batch"]
