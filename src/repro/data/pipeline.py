"""Data pipeline: deterministic, shardable, resumable.

``SyntheticLMDataset`` synthesises reproducible LM batches *statelessly from
the step index* — resume-after-preemption needs no iterator state, only the
restored step counter (the checkpoint carries it).  The generator is a
counter-mode hash (threefry via jax.random with a per-step key), so any host
can materialise exactly its shard of any batch: elastic re-sharding after a
topology change is a pure function of (step, new mesh).

``ByteCorpusDataset`` is the "real data" path for the examples: a byte-level
tokenizer over a text file with the same stateless step→batch indexing.

``make_global_batch`` places per-shard data onto the mesh as one global
jax.Array (multi-host ready; single-process here).
"""

from __future__ import annotations

import dataclasses
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLMDataset", "ByteCorpusDataset", "make_global_batch"]


@dataclasses.dataclass
class SyntheticLMDataset:
    """Markov-ish synthetic token stream: next token depends on the previous
    token plus per-step noise — gives a learnable but non-trivial signal so
    training-loss decrease is a meaningful smoke check."""
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed * 1_000_003 + step) & 0x7FFFFFFF)
        B, S, V = self.global_batch, self.seq_len, self.vocab
        base = rng.integers(0, V, size=(B, 1))
        steps = rng.integers(1, 7, size=(B, S))
        toks = (base + np.cumsum(steps, axis=1)) % V
        tokens = toks.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = -1                      # no target for final position
        return {"tokens": tokens, "labels": labels}


@dataclasses.dataclass
class ByteCorpusDataset:
    """Byte-level LM over a text corpus, stateless step→batch indexing."""
    path: str | Path
    seq_len: int
    global_batch: int
    seed: int = 0

    def __post_init__(self):
        raw = Path(self.path).read_bytes()
        if len(raw) < (self.seq_len + 1) * 2:
            raw = raw * ((self.seq_len + 1) * 2 // max(len(raw), 1) + 1)
        self.data = np.frombuffer(raw, dtype=np.uint8)

    @property
    def vocab(self) -> int:
        return 256

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed * 9_999_991 + step) & 0x7FFFFFFF)
        B, S = self.global_batch, self.seq_len
        starts = rng.integers(0, len(self.data) - S - 1, size=B)
        tokens = np.stack([self.data[s:s + S] for s in starts]).astype(np.int32)
        labels = np.stack([self.data[s + 1:s + S + 1] for s in starts]
                          ).astype(np.int32)
        return {"tokens": tokens, "labels": labels}


def make_global_batch(batch: dict[str, np.ndarray], mesh,
                      batch_axes=("data",)) -> dict[str, jax.Array]:
    """Place host arrays on the mesh, batch dim sharded over ``batch_axes``."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    out = {}
    for k, v in batch.items():
        spec = P(batch_axes, *([None] * (v.ndim - 1)))
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
