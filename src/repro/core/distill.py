"""Beyond-paper: ensemble → single-tree distillation for fast runtime eval.

Paper Table VI shows the accuracy/eval-latency trade-off killing the best
models (RandomForest: best RMSE, 983 µs eval → loses on estimated speedup).
We attack t_eval directly: fit the strongest ensemble, then distill it into
ONE array-tree by fitting the ensemble's *predictions* on an augmented
sample of the feature space.  Eval cost drops to a single tree descent
(~DecisionTree latency) while keeping most of the ensemble's shape.

``distill()`` returns an Estimator usable anywhere a candidate model is —
the selection machinery (estimated speedup) decides per-subroutine whether
the distilled model wins, exactly in the paper's spirit.
"""

from __future__ import annotations

import numpy as np

from .ml import make_model, register
from .ml.base import Estimator
from .ml.tree import ArrayTree


@register
class DistilledTree(Estimator):
    """Single tree fit to a teacher ensemble's predictions."""
    NAME = "DistilledTree"
    PARAM_GRID = {"max_depth": [10, 14], "augment": [3]}

    def __init__(self, teacher: str = "XGBoost", max_depth: int = 12,
                 augment: int = 3, seed: int = 0) -> None:
        self.teacher = teacher
        self.max_depth = max_depth
        self.augment = augment
        self.seed = seed
        self.tree_ = ArrayTree()

    @property
    def trees_(self) -> tuple:
        """Uniform tree-model interface: a distilled model is a single-tree
        ensemble, so the compiled decision engine's predicated lowering
        (see :mod:`repro.core.fastpath`) applies unchanged."""
        return (self.tree_,)

    def fit(self, X, y):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        teacher = make_model(self.teacher).fit(X, y)
        rng = np.random.default_rng(self.seed)
        # augment: jitter real samples so the tree sees the teacher's
        # interpolation behaviour, not just the training points
        Xs = [X]
        scale = X.std(axis=0, keepdims=True) * 0.05 + 1e-12
        for _ in range(self.augment):
            Xs.append(X + rng.normal(scale=scale, size=X.shape))
        Xa = np.concatenate(Xs, axis=0)
        ya = teacher.predict(Xa)
        self.tree_.build(Xa, ya, np.ones(len(ya)), max_depth=self.max_depth,
                         min_samples_leaf=2, max_features=None, rng=rng)
        return self

    def predict(self, X):
        return self.tree_.predict(np.asarray(X, dtype=np.float64))

    def get_state(self):
        return {"tree": self.tree_.get_state(), "max_depth": self.max_depth,
                "teacher": self.teacher, "augment": self.augment}

    def set_state(self, s):
        self.tree_.set_state(s["tree"])
        self.max_depth = int(s["max_depth"])
        self.teacher = str(s["teacher"])
        self.augment = int(s["augment"])
