"""AdsalaRuntime — the runtime library (paper Fig. 1b).

Loads persisted :class:`TunedSubroutine` artifacts and, per BLAS call,
predicts the runtime of every knob candidate and applies the argmin.  The
paper memoizes the *last* call's dims→decision; we keep that behaviour and
additionally offer a bounded LRU cache (beyond-paper, DESIGN.md §7.2) —
transformer workloads emit a small set of distinct GEMM shapes, so the hit
rate is near 1 after the first step.
"""

from __future__ import annotations

import collections
import dataclasses
import time

from .knobs import Knob
from .tuner import TunedSubroutine

__all__ = ["AdsalaRuntime", "RuntimeStats"]


@dataclasses.dataclass
class RuntimeStats:
    calls: int = 0
    cache_hits: int = 0
    eval_seconds: float = 0.0

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / self.calls if self.calls else 0.0


class AdsalaRuntime:
    """Per-process decision engine for all tuned subroutines."""

    def __init__(self, *, cache_size: int = 256) -> None:
        # paper's behaviour = cache_size 1 (last call only)
        self._subs: dict[tuple[str, int], TunedSubroutine] = {}
        self._cache: collections.OrderedDict[tuple, Knob] = \
            collections.OrderedDict()
        self._cache_size = max(1, cache_size)
        self.stats = RuntimeStats()

    # -- registration --------------------------------------------------------
    def register(self, sub: TunedSubroutine) -> None:
        self._subs[(sub.op, sub.dtype_bytes)] = sub

    def has(self, op: str, dtype_bytes: int) -> bool:
        return (op, dtype_bytes) in self._subs

    def subroutine(self, op: str, dtype_bytes: int) -> TunedSubroutine:
        return self._subs[(op, dtype_bytes)]

    # -- the runtime decision -------------------------------------------------
    def select(self, op: str, dims: tuple[int, ...],
               dtype_bytes: int = 4) -> Knob:
        key = (op, dtype_bytes, tuple(int(d) for d in dims))
        self.stats.calls += 1
        hit = self._cache.get(key)
        if hit is not None:
            self.stats.cache_hits += 1
            self._cache.move_to_end(key)
            return hit
        sub = self._subs[(op, dtype_bytes)]
        t0 = time.perf_counter()
        knob = sub.select(key[2])
        self.stats.eval_seconds += time.perf_counter() - t0
        self._cache[key] = knob
        while len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)
        return knob

    def select_or_default(self, op: str, dims: tuple[int, ...],
                          dtype_bytes: int, default: Knob) -> Knob:
        """Graceful degradation: untuned subroutines run the default config
        (a node that lost its model files keeps serving — fault tolerance)."""
        if (op, dtype_bytes) in self._subs:
            return self.select(op, dims, dtype_bytes)
        return default

    def clear_cache(self) -> None:
        self._cache.clear()


#: process-global runtime used by kernels.ops when none is passed explicitly
_GLOBAL: AdsalaRuntime | None = None


def global_runtime() -> AdsalaRuntime:
    global _GLOBAL
    if _GLOBAL is None:
        _GLOBAL = AdsalaRuntime()
    return _GLOBAL
