"""AdsalaRuntime — the runtime library (paper Fig. 1b), backend-keyed.

Loads persisted :class:`TunedSubroutine` artifacts and, per BLAS call,
predicts the runtime of every knob candidate and applies the argmin.  The
paper memoizes the *last* call's dims→decision; we keep that behaviour and
additionally offer a bounded LRU cache (beyond-paper, DESIGN.md §7.2) —
transformer workloads emit a small set of distinct GEMM shapes, so the hit
rate is near 1 after the first step.

Beyond the paper's single-library setting, one runtime instance holds tuned
model sets for several execution backends side by side: the subroutine table
and the decision cache are keyed by ``(backend, op, dtype_bytes)``, and
:class:`RuntimeStats` reports hit-rate per backend.  All mutation is guarded
by a lock — the batched serving path issues concurrent selections.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time

from .knobs import Knob
from .tuner import TunedSubroutine

__all__ = ["AdsalaRuntime", "BackendStats", "BucketStats", "RuntimeStats",
           "global_runtime", "DEFAULT_BACKEND"]

#: backend assumed when a caller or a legacy (v1) artifact names none
DEFAULT_BACKEND = "pallas"


@dataclasses.dataclass
class BackendStats:
    calls: int = 0
    cache_hits: int = 0
    default_calls: int = 0      # select_or_default served the fallback knob
    model_evals: int = 0        # knob decisions that ran the ML model
    eval_seconds: float = 0.0

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / self.calls if self.calls else 0.0


@dataclasses.dataclass
class BucketStats:
    """Serving-layer accounting for one shape bucket (= one decision-cache
    key): how many stacked executions it saw and how well they amortised."""
    batches: int = 0
    requests: int = 0
    max_batch: int = 0

    @property
    def mean_batch(self) -> float:
        return self.requests / self.batches if self.batches else 0.0


@dataclasses.dataclass
class RuntimeStats:
    calls: int = 0
    cache_hits: int = 0
    default_calls: int = 0
    model_evals: int = 0
    eval_seconds: float = 0.0
    backends: dict[str, BackendStats] = dataclasses.field(
        default_factory=dict)
    #: per shape-bucket serving stats, keyed (backend, op, dtype_bytes, dims)
    buckets: dict[tuple, BucketStats] = dataclasses.field(
        default_factory=dict)

    def for_backend(self, name: str) -> BackendStats:
        return self.backends.setdefault(name, BackendStats())

    def for_bucket(self, key: tuple) -> BucketStats:
        return self.buckets.setdefault(key, BucketStats())

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / self.calls if self.calls else 0.0

    @property
    def backend_hit_rates(self) -> dict[str, float]:
        return {name: b.hit_rate for name, b in sorted(self.backends.items())}


class AdsalaRuntime:
    """Per-process decision engine for all tuned (backend, subroutine) pairs."""

    def __init__(self, *, cache_size: int = 256) -> None:
        # paper's behaviour = cache_size 1 (last call only)
        self._subs: dict[tuple[str, str, int], TunedSubroutine] = {}
        self._cache: collections.OrderedDict[tuple, Knob] = \
            collections.OrderedDict()
        self._cache_size = max(1, cache_size)
        self._lock = threading.RLock()
        self.stats = RuntimeStats()

    # -- registration --------------------------------------------------------
    def register(self, sub: TunedSubroutine, *,
                 backend: str | None = None) -> None:
        name = backend or getattr(sub, "backend", None) or DEFAULT_BACKEND
        with self._lock:
            self._subs[(name, sub.op, sub.dtype_bytes)] = sub

    def has(self, op: str, dtype_bytes: int,
            backend: str = DEFAULT_BACKEND) -> bool:
        with self._lock:
            return (backend, op, dtype_bytes) in self._subs

    def subroutine(self, op: str, dtype_bytes: int,
                   backend: str = DEFAULT_BACKEND) -> TunedSubroutine:
        with self._lock:
            return self._subs[(backend, op, dtype_bytes)]

    def backends(self) -> tuple[str, ...]:
        """Backend names with at least one registered subroutine."""
        with self._lock:
            return tuple(sorted({k[0] for k in self._subs}))

    # -- the runtime decision -------------------------------------------------
    def select(self, op: str, dims: tuple[int, ...], dtype_bytes: int = 4,
               backend: str = DEFAULT_BACKEND) -> Knob:
        key = (backend, op, dtype_bytes, tuple(int(d) for d in dims))
        with self._lock:
            self.stats.calls += 1
            bstats = self.stats.for_backend(backend)
            bstats.calls += 1
            hit = self._cache.get(key)
            if hit is not None:
                self.stats.cache_hits += 1
                bstats.cache_hits += 1
                self._cache.move_to_end(key)
                return hit
            sub = self._subs[(backend, op, dtype_bytes)]
        # model evaluation runs unlocked (pure numpy, deterministic) so
        # concurrent distinct-shape selections don't serialise; a racing
        # duplicate computes the same knob and the second store is a no-op
        t0 = time.perf_counter()
        knob = sub.select(key[3])
        dt = time.perf_counter() - t0
        with self._lock:
            self.stats.model_evals += 1
            self.stats.eval_seconds += dt
            bstats = self.stats.for_backend(backend)
            bstats.model_evals += 1
            bstats.eval_seconds += dt
            self._cache[key] = knob
            self._cache.move_to_end(key)
            while len(self._cache) > self._cache_size:
                self._cache.popitem(last=False)
        return knob

    def select_or_default(self, op: str, dims: tuple[int, ...],
                          dtype_bytes: int, default: Knob, *,
                          backend: str = DEFAULT_BACKEND) -> Knob:
        """Graceful degradation: untuned subroutines run the default config
        (a node that lost its model files keeps serving — fault tolerance).
        Default-path calls are recorded so `RuntimeStats` sees all traffic."""
        with self._lock:
            if (backend, op, dtype_bytes) not in self._subs:
                self.stats.calls += 1
                self.stats.default_calls += 1
                bstats = self.stats.for_backend(backend)
                bstats.calls += 1
                bstats.default_calls += 1
                return default
        return self.select(op, dims, dtype_bytes, backend=backend)

    # -- serving accounting ---------------------------------------------------
    def record_batch(self, op: str, dims: tuple[int, ...], dtype_bytes: int,
                     backend: str, batch_size: int) -> None:
        """Credit one stacked execution of ``batch_size`` requests to the
        shape bucket keyed like the decision cache (serving layer hook)."""
        key = (backend, op, dtype_bytes, tuple(int(d) for d in dims))
        with self._lock:
            b = self.stats.for_bucket(key)
            b.batches += 1
            b.requests += int(batch_size)
            b.max_batch = max(b.max_batch, int(batch_size))

    # -- warm-start persistence ----------------------------------------------
    def export_cache(self) -> list[dict]:
        """Decision-cache contents as JSON-safe records, LRU-oldest first,
        so a restarted server can skip the cold-start model evaluations."""
        with self._lock:
            return [{"backend": k[0], "op": k[1], "dtype_bytes": k[2],
                     "dims": list(k[3]), "knob": knob.dict}
                    for k, knob in self._cache.items()]

    def import_cache(self, entries: list[dict]) -> int:
        """Warm-start the decision cache from :meth:`export_cache` records;
        returns how many entries were imported.

        Imported decisions count as neither calls nor hits; subsequent
        ``select`` calls on these shapes are cache hits and run no model.
        Entries beyond ``cache_size`` evict in the usual LRU order.  Note
        that ``select_or_default`` still serves its default for subroutines
        with no registered model, warm cache or not.

        A persisted cache can outlive a recalibration: entries whose knob no
        longer exists in the *registered* subroutine's candidate space are
        dropped (stale artifacts must not dictate impossible configs).
        Entries for unregistered subroutines import as-is — there is no
        space to validate against yet.
        """
        n = 0
        with self._lock:
            for e in entries:
                key = (str(e["backend"]), str(e["op"]), int(e["dtype_bytes"]),
                       tuple(int(d) for d in e["dims"]))
                knob = Knob(tuple(sorted(e["knob"].items())))
                sub = self._subs.get(key[:3])
                space = getattr(sub, "knob_space", None)
                if space is not None and knob not in space.candidates:
                    continue
                self._cache[key] = knob
                self._cache.move_to_end(key)
                n += 1
            while len(self._cache) > self._cache_size:
                self._cache.popitem(last=False)
        return n

    def clear_cache(self) -> None:
        with self._lock:
            self._cache.clear()

    def cache_len(self) -> int:
        with self._lock:
            return len(self._cache)


#: process-global runtime used by kernels.ops when none is passed explicitly
_GLOBAL: AdsalaRuntime | None = None
_GLOBAL_LOCK = threading.Lock()


def global_runtime() -> AdsalaRuntime:
    global _GLOBAL
    with _GLOBAL_LOCK:
        if _GLOBAL is None:
            _GLOBAL = AdsalaRuntime()
        return _GLOBAL
