"""AdsalaRuntime — the runtime library (paper Fig. 1b), backend-keyed.

Loads persisted :class:`TunedSubroutine` artifacts and, per BLAS call,
predicts the runtime of every knob candidate and applies the argmin.  The
paper memoizes the *last* call's dims→decision; we keep that behaviour and
additionally offer a bounded LRU cache (beyond-paper, DESIGN.md §7.2) —
transformer workloads emit a small set of distinct GEMM shapes, so the hit
rate is near 1 after the first step.

Beyond the paper's single-library setting, one runtime instance holds tuned
model sets for several execution backends side by side: the subroutine table
and the decision cache are keyed by ``(backend, op, dtype_bytes)``, and
:class:`RuntimeStats` reports hit-rate per backend.

Hot-path design (this is the most-called code in the serving stack):

* **Cache hits are lock-free.**  The decision cache is a plain dict whose
  reads are GIL-atomic; the authoritative LRU order lives in a mirrored
  ``OrderedDict`` that is only touched under the lock.  A hit records its
  key in a lock-free touch log which is folded into the LRU order on the
  next locked operation (miss, export, import) — "relaxed LRU": recency is
  applied in batches, eviction decisions still honour it.
* **Hit statistics are relaxed striped counters.**  Each thread owns a
  private hit-count dict (no lost updates, no lock, no contention); the
  ``stats`` property aggregates base counters + stripes under the lock.
* **Misses are sharded per ``(backend, op)``.**  Each shard owns a lock, an
  in-flight table, and its eval counters: concurrent misses on *different*
  subroutines never touch the same lock, and concurrent misses on the
  *same* key coalesce — one thread evaluates, the rest wait on the shard's
  in-flight entry and count as hits (the knob they got was served from a
  computation already paid for).  Evaluation itself runs with NO lock held,
  through the :class:`~repro.core.fastpath.CompiledPredictor` built at
  ``register()`` time (falling back to the artifact's reference ``select``
  when compilation isn't possible).  The single remaining global-lock
  section is the LRU store — a dict insert plus occasional eviction; the
  relaxed-LRU touch fold now runs only when an eviction is actually due,
  not on every miss.
* **select_many** batches the misses of several pending decisions sharing a
  subroutine into ONE fused feature-build + model-predict call — the
  serving layer routes bucket flushes through it.
* **Models can be hot-swapped while serving.**  :meth:`AdsalaRuntime.swap`
  replaces a subroutine's model, bumps its swap epoch, and invalidates its
  decision-cache entries in one critical section; miss-path evaluations
  snapshot the epoch and refuse to store a decision computed against a
  superseded model.  In-flight selects finish on the old predictor, every
  select that starts after the swap returns sees the new one.  The online
  retuner (:mod:`repro.serving.retune`) drives this seam.  Decision-cache
  exports carry each subroutine's registry-stamped ``artifact_version`` so
  a warm restart rejects entries from a different model generation.
"""

from __future__ import annotations

import collections
import dataclasses
import sys
import threading
import time

from .fastpath import compile_predictor
from .knobs import Knob
from .tuner import TunedSubroutine

__all__ = ["AdsalaRuntime", "BackendStats", "BucketStats", "RuntimeStats",
           "global_runtime", "DEFAULT_BACKEND"]

#: backend assumed when a caller or a legacy (v1) artifact names none
DEFAULT_BACKEND = "pallas"

#: fold the lock-free touch log into the LRU order at this size even if no
#: miss comes along (bounds memory on hit-only workloads)
_TOUCH_FOLD_LIMIT = 1024


class _Inflight:
    """One in-progress model evaluation: followers wait on ``event`` and
    read ``knob`` (None means the leader failed — fall back to a local
    evaluation).  ``event`` may be shared: ``select_many`` backs all the
    keys of one fused evaluation with a single Event (they resolve
    together, and per-key Event allocation is measurable on the batched
    path).  ``epoch`` is the subroutine's swap epoch at the leader's
    snapshot: a follower whose own snapshot is newer must NOT ride this
    evaluation — the leader is computing against a predecessor model."""
    __slots__ = ("event", "knob", "epoch")

    def __init__(self, event: threading.Event | None = None,
                 epoch: int = 0) -> None:
        self.event = event if event is not None else threading.Event()
        self.knob: Knob | None = None
        self.epoch = epoch


class _Shard:
    """Per-``(backend, op)`` miss-path state: its own lock, the in-flight
    evaluation table (duplicate-key coalescing), and relaxed eval counters
    (folded into :class:`RuntimeStats` by the ``stats`` aggregator)."""
    __slots__ = ("lock", "inflight", "model_evals", "eval_seconds")

    def __init__(self) -> None:
        self.lock = threading.Lock()
        self.inflight: dict[tuple, _Inflight] = {}
        self.model_evals = 0
        self.eval_seconds = 0.0

    def count_eval(self, dt: float, n: int = 1) -> None:
        with self.lock:
            self.model_evals += n
            self.eval_seconds += dt

    def snapshot(self) -> tuple[int, float]:
        """(model_evals, eval_seconds) read together under the shard lock.
        A lock-free reader racing ``count_eval`` could observe the
        incremented count without the added seconds — the pair must be
        taken in one critical section to stay mutually consistent."""
        with self.lock:
            return self.model_evals, self.eval_seconds


class _HitStripe:
    """Per-thread relaxed hit counter: a run-length count for the backend
    currently being hit (the overwhelmingly common case is a long run of one
    backend) plus a dict of folded totals.  Only the owning thread writes;
    the stats aggregator reads both parts under the runtime lock, and folds
    the stripe away once its owner thread has exited."""
    __slots__ = ("owner", "backend", "n", "counts")

    def __init__(self) -> None:
        self.owner = threading.current_thread()
        self.backend: str | None = None
        self.n = 0
        self.counts: dict[str, int] = {}

    def switch(self, backend: str) -> None:
        # zero the run BEFORE folding it: a stats read racing this switch
        # then transiently undercounts the run instead of double-counting it
        prev = self.backend
        n = self.n
        self.n = 0
        if prev is not None and n:
            self.counts[prev] = self.counts.get(prev, 0) + n
        self.backend = backend

    def pairs(self) -> list[tuple[str, int]]:
        out = list(self.counts.items())
        run_backend, run_n = self.backend, self.n
        if run_backend is not None and run_n:
            out.append((run_backend, run_n))
        return out


@dataclasses.dataclass
class BackendStats:
    calls: int = 0
    cache_hits: int = 0
    default_calls: int = 0      # select_or_default served the fallback knob
    model_evals: int = 0        # knob decisions that ran the ML model
    eval_seconds: float = 0.0

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / self.calls if self.calls else 0.0


@dataclasses.dataclass
class BucketStats:
    """Serving-layer accounting for one shape bucket (= one decision-cache
    key): how many stacked executions it saw, how well they amortised, and
    where its requests' time went.  ``exec_seconds`` covers ONLY the
    stacked ``run_op`` span; scheduler-side queue/linger wait is accounted
    separately in ``queue_seconds`` — mixing the two would poison the
    online retrainer's telemetry with batching-policy artifacts."""
    batches: int = 0
    requests: int = 0
    max_batch: int = 0
    exec_seconds: float = 0.0     # sum of stacked-execution spans
    exec_items: int = 0           # stacked rows executed (incl. pad filler)
    queue_seconds: float = 0.0    # sum over requests of submit→exec-start

    @property
    def mean_batch(self) -> float:
        return self.requests / self.batches if self.batches else 0.0

    @property
    def mean_exec_per_item(self) -> float:
        """Mean measured execution seconds per stacked row — the telemetry
        signal the drift detector compares against the install-time
        predictor's per-call prediction."""
        return self.exec_seconds / self.exec_items if self.exec_items else 0.0

    @property
    def mean_queue(self) -> float:
        return self.queue_seconds / self.requests if self.requests else 0.0


@dataclasses.dataclass
class RuntimeStats:
    calls: int = 0
    cache_hits: int = 0
    default_calls: int = 0
    model_evals: int = 0
    eval_seconds: float = 0.0
    #: import_cache entries rejected because they were decided by a
    #: different artifact generation (stale persisted cache)
    import_drops_version: int = 0
    #: import_cache entries rejected because their knob left the registered
    #: candidate space (recalibration changed the space)
    import_drops_knob: int = 0
    #: hot swaps applied (online retune / reinstall) and the decision-cache
    #: entries they invalidated
    swaps: int = 0
    swap_invalidations: int = 0
    #: knob quarantines opened (TTL'd circuit breakers on crashing knobs)
    quarantines: int = 0
    #: selections that re-chose a quarantined knob and were forced onto the
    #: quarantine's fallback config instead
    quarantine_forced: int = 0
    #: import_cache entries rejected because their knob is under an active
    #: quarantine (a crashing selection must not be resurrected by warm start)
    import_drops_quarantine: int = 0
    #: miss-path model evaluations that raised; select_or_default served the
    #: caller's default config instead of failing the BLAS call
    eval_failures: int = 0
    #: import_cache entries dropped as structurally malformed (missing
    #: fields, wrong types — a payload that passed the durable checksums or
    #: came from a legacy file but does not parse as a record)
    import_drops_corrupt: int = 0
    #: decision-journal appends that raised (persistence is best-effort on
    #: the hot path — a full disk must cost durability, not availability)
    journal_failures: int = 0
    #: decisions/quarantines absorbed from a shared fleet journal (peer
    #: processes' entries imported via :meth:`AdsalaRuntime.absorb_journal`)
    journal_absorbed: int = 0
    #: process-global resolve-time backend fallbacks, per
    #: (requested, resolved) pair (from repro.backends.registry) — how often
    #: dispatch silently degraded, e.g. pallas→ref when pallas is absent
    resolve_fallbacks: dict[tuple, int] = dataclasses.field(
        default_factory=dict)
    backends: dict[str, BackendStats] = dataclasses.field(
        default_factory=dict)
    #: per shape-bucket serving stats, keyed (backend, op, dtype_bytes, dims)
    buckets: dict[tuple, BucketStats] = dataclasses.field(
        default_factory=dict)

    def for_backend(self, name: str) -> BackendStats:
        return self.backends.setdefault(name, BackendStats())

    def for_bucket(self, key: tuple) -> BucketStats:
        return self.buckets.setdefault(key, BucketStats())

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / self.calls if self.calls else 0.0

    @property
    def backend_hit_rates(self) -> dict[str, float]:
        return {name: b.hit_rate for name, b in sorted(self.backends.items())}


class AdsalaRuntime:
    """Per-process decision engine for all tuned (backend, subroutine) pairs.

    ``fast_prune=True`` opts registered artifacts into dominated-candidate
    pruning (see :mod:`~repro.core.fastpath`): the compiled fast path then
    evaluates only the knobs the install-time dataset ever argmin-selected,
    falling back to the full candidate set outside the dataset's dims
    range.  ``fast_prune="band"`` uses the confidence-band live set instead
    (every knob whose prediction ever came within the persisted band of the
    winner — a robust superset).  ``fast_knn_coreset=True`` opts KNN
    artifacts into their persisted inexact subsample.
    """

    def __init__(self, *, cache_size: int = 256, fast_prune=False,
                 touch_sample: int = 16,
                 fast_knn_coreset: bool = False, faults=None) -> None:
        # paper's behaviour = cache_size 1 (last call only)
        #: optional repro.serving.faults.FaultPlan; every site is guarded by
        #: an `is not None` check so the disabled (default) path is free
        self._faults = faults
        self._subs: dict[tuple[str, str, int], TunedSubroutine] = {}
        self._fast: dict[tuple[str, str, int], object] = {}
        self._shards: dict[tuple[str, str], _Shard] = {}
        # per-subroutine swap epoch: bumped (under the lock) whenever the
        # registered model for a key is replaced.  Miss-path evaluations
        # snapshot it before reading the model and refuse to STORE a knob
        # computed against a superseded epoch — an in-flight select may
        # still RETURN the old decision (it was in flight when the swap
        # landed), but it can never repollute the invalidated cache
        self._swap_epochs: dict[tuple[str, str, int], int] = {}
        # TTL'd knob circuit breakers: (backend, op, dtype_bytes, knob) ->
        # (monotonic expiry deadline, forced fallback knob).  The cache
        # never holds a quarantined knob (quarantine_knob invalidates, the
        # miss path refuses to store one), so the lock-free HIT path needs
        # no quarantine check at all — only miss-path evaluations consult
        # this dict, and only when it is non-empty.
        self._quarantined: dict[tuple, tuple[float, Knob]] = {}
        self._cache: collections.OrderedDict[tuple, Knob] = \
            collections.OrderedDict()      # authoritative LRU, lock-guarded
        self._cache_mirror: dict[tuple, Knob] = {}   # lock-free read mirror
        self._cache_size = max(1, cache_size)
        self._fast_prune = fast_prune
        self._fast_knn_coreset = bool(fast_knn_coreset)
        self._lock = threading.RLock()
        self._touches: list[tuple] = []    # lock-free hit log (relaxed LRU)
        # hits log a recency touch every `touch_sample`-th hit of a thread's
        # run (power of two; 1 = every hit, for deterministic LRU tests)
        if touch_sample < 1 or touch_sample & (touch_sample - 1):
            raise ValueError("touch_sample must be a power of two")
        self._touch_mask = touch_sample - 1
        self._hits_local = threading.local()
        self._hit_stripes: list[_HitStripe] = []
        self._base = RuntimeStats()        # mutated only under the lock
        #: optional incremental persistence hook (e.g. bound to
        #: ``ModelRegistry.journal_decision``): called best-effort, outside
        #: the lock, with one export_cache-shaped record per NEW cached
        #: decision and per quarantine opened.  Failures are counted
        #: (``stats.journal_failures``), never raised.
        self.decision_journal = None
        # error-budget ledger riding export/import (attach_budgets); budget
        # records imported before a ledger is attached are parked here
        self._budgets = None
        self._pending_budget_records: list[dict] = []
        # prebound lock-free readers (the dicts/lists are mutated in place,
        # never replaced, so these stay valid for the runtime's life)
        self._cache_get = self._cache_mirror.get
        self._subs_get = self._subs.get
        self._fast_get = self._fast.get
        self._shards_get = self._shards.get
        self._epoch_get = self._swap_epochs.get

    # -- statistics -----------------------------------------------------------
    @staticmethod
    def _add_hits(stats: RuntimeStats, name: str, hits: int) -> None:
        stats.calls += hits
        stats.cache_hits += hits
        b = stats.for_backend(name)
        b.calls += hits
        b.cache_hits += hits

    @property
    def stats(self) -> RuntimeStats:
        """Aggregate snapshot: locked base counters plus the per-thread
        relaxed hit stripes.  Exact whenever the hitting threads are
        quiescent (e.g. after join); a read racing a live hit may lag it by
        a moment.  Stripes of exited threads are folded into the base here,
        so thread churn cannot grow the stripe list unboundedly."""
        with self._lock:
            base = self._base
            self._prune_stripes_locked()
            merged = RuntimeStats(
                calls=base.calls, cache_hits=base.cache_hits,
                default_calls=base.default_calls,
                model_evals=base.model_evals,
                eval_seconds=base.eval_seconds,
                import_drops_version=base.import_drops_version,
                import_drops_knob=base.import_drops_knob,
                swaps=base.swaps,
                swap_invalidations=base.swap_invalidations,
                quarantines=base.quarantines,
                quarantine_forced=base.quarantine_forced,
                import_drops_quarantine=base.import_drops_quarantine,
                eval_failures=base.eval_failures,
                import_drops_corrupt=base.import_drops_corrupt,
                journal_failures=base.journal_failures,
                journal_absorbed=base.journal_absorbed,
                backends={n: dataclasses.replace(b)
                          for n, b in base.backends.items()},
                buckets={k: dataclasses.replace(b)
                         for k, b in base.buckets.items()})
            for stripe in self._hit_stripes:
                for name, hits in stripe.pairs():
                    self._add_hits(merged, name, hits)
            for (backend, _op), shard in self._shards.items():
                # snapshot BOTH counters under the shard lock: an unlocked
                # pair of reads racing count_eval on another thread could
                # see the incremented count without the added seconds
                evals, secs = shard.snapshot()
                if evals or secs:
                    merged.calls += evals
                    merged.model_evals += evals
                    merged.eval_seconds += secs
                    b = merged.for_backend(backend)
                    b.calls += evals
                    b.model_evals += evals
                    b.eval_seconds += secs
        # process-global resolve-time fallback counts (silent dispatch
        # degradation, e.g. pallas→ref): read through sys.modules so the
        # core package never *imports* the backends package — the counts
        # simply stay empty until someone else has loaded it
        reg = sys.modules.get("repro.backends.registry")
        if reg is not None:
            merged.resolve_fallbacks = reg.fallback_counts()
        return merged

    def _stripe(self) -> _HitStripe:
        """This thread's hit stripe (registered for aggregation on first
        use).  Registration also folds away stripes of exited threads, so
        thread churn can't leak stripes even if nobody ever reads stats."""
        stripe = _HitStripe()
        self._hits_local.stripe = stripe
        with self._lock:
            self._prune_stripes_locked()
            self._hit_stripes.append(stripe)
        return stripe

    def _prune_stripes_locked(self) -> None:
        """Fold exited threads' (final, immutable) counters into the base."""
        live: list[_HitStripe] = []
        for stripe in self._hit_stripes:
            if stripe.owner.is_alive():
                live.append(stripe)
            else:
                for name, hits in stripe.pairs():
                    self._add_hits(self._base, name, hits)
        self._hit_stripes[:] = live

    def _record_hit(self, backend: str, key: tuple, n: int = 1) -> None:
        """Lock-free hit accounting: thread-owned stripe + sampled touch
        log.  select() inlines an n=1 copy of this logic on its hot path —
        keep the two in step."""
        try:
            s = self._hits_local.stripe
        except AttributeError:
            s = self._stripe()
        if backend is not s.backend and backend != s.backend:
            s.switch(backend)
        s.n += n
        if not (s.n & self._touch_mask):
            touches = self._touches
            touches.append(key)
            if len(touches) >= _TOUCH_FOLD_LIMIT:
                with self._lock:
                    self._fold_touches_locked()

    def _fold_touches_locked(self) -> None:
        """Apply the pending lock-free hit log to the LRU order.  Drains the
        touch list in place (the list object is never replaced): appends
        racing the drain land at the tail and survive for the next fold."""
        touches = self._touches
        if not touches:
            return
        pending = touches[:]
        del touches[:len(pending)]
        cache = self._cache
        for key in pending:
            if key in cache:
                cache.move_to_end(key)

    # -- registration --------------------------------------------------------
    def register(self, sub: TunedSubroutine, *,
                 backend: str | None = None) -> None:
        name = backend or getattr(sub, "backend", None) or DEFAULT_BACKEND
        # compile the fast path up front (None for stubs/uncompilable subs:
        # select() then falls back to the artifact's reference path)
        compiled = compile_predictor(sub, prune=self._fast_prune,
                                     coreset=self._fast_knn_coreset)
        sub_key = (name, sub.op, sub.dtype_bytes)
        with self._lock:
            if sub_key in self._subs:
                # replacing a live model: in-flight evaluations against the
                # old one must not store their (stale) decisions
                self._swap_epochs[sub_key] = \
                    self._swap_epochs.get(sub_key, 0) + 1
            self._subs[sub_key] = sub
            self._fast[sub_key] = compiled

    def swap(self, sub: TunedSubroutine, *,
             backend: str | None = None) -> int:
        """Atomically hot-swap the registered model for ``sub``'s key and
        invalidate its decision-cache entries; returns how many cached
        decisions were invalidated.

        The replacement, the epoch bump, and the cache invalidation happen
        in ONE critical section: a ``select`` that starts after ``swap``
        returns can neither hit a cached decision of the old model nor ride
        an in-flight evaluation the old model is still computing (the
        epoch stamp on the in-flight entry no longer matches).  Calls
        already past the cache probe finish on the old predictor — they
        were in flight when the swap landed — but their results are never
        stored.  This is the online-retune seam: the fast-path predictor is
        compiled *before* the lock is taken, so the critical section is a
        few dict operations regardless of model family."""
        name = backend or getattr(sub, "backend", None) or DEFAULT_BACKEND
        compiled = compile_predictor(sub, prune=self._fast_prune,
                                     coreset=self._fast_knn_coreset)
        sub_key = (name, sub.op, sub.dtype_bytes)
        with self._lock:
            self._swap_epochs[sub_key] = self._swap_epochs.get(sub_key, 0) + 1
            self._subs[sub_key] = sub
            self._fast[sub_key] = compiled
            self._fold_touches_locked()
            stale = [k for k in self._cache if k[:3] == sub_key]
            for k in stale:
                del self._cache[k]
                self._cache_mirror.pop(k, None)
            self._base.swaps += 1
            self._base.swap_invalidations += len(stale)
        return len(stale)

    # -- error budgets / incremental persistence seams ------------------------
    def attach_budgets(self, ledger) -> None:
        """Hook an :class:`~repro.serving.budget.ErrorBudgetLedger` into
        warm-state persistence: its records ride :meth:`export_cache`, and
        ``{"budget": 1}`` records seen by :meth:`import_cache` (including
        any imported *before* this attach) are restored into it."""
        with self._lock:
            self._budgets = ledger
            pending = self._pending_budget_records
            self._pending_budget_records = []
        if pending:
            ledger.import_records(pending)

    def attached_budgets(self):
        """The attached error-budget ledger, or None."""
        return self._budgets

    def _decision_record(self, key: tuple, knob: Knob) -> dict:
        return {"backend": key[0], "op": key[1], "dtype_bytes": int(key[2]),
                "dims": [int(d) for d in key[3]], "knob": knob.dict,
                "artifact_version": self._version_of(key[:3])}

    def _notify_journal(self, record: dict) -> None:
        """Best-effort incremental persistence: runs OUTSIDE the runtime
        lock (it does file I/O), never raises into the decision path."""
        fn = self.decision_journal
        if fn is None:
            return
        try:
            fn(record)
        except Exception:        # noqa: BLE001 — durability, not availability
            with self._lock:
                self._base.journal_failures += 1

    # -- knob quarantine (TTL'd circuit breakers) -----------------------------
    def quarantine_knob(self, op: str, dtype_bytes: int, backend: str,
                        knob: Knob, *, fallback: Knob,
                        ttl_s: float = 30.0) -> int:
        """Open a TTL'd circuit breaker on one ``(backend, op, dtype, knob)``:
        until the breaker half-opens (``ttl_s`` seconds of monotonic time),
        every miss-path selection that re-chooses ``knob`` is forced onto
        ``fallback`` instead, and the forced decision is never cached.  The
        serving layer opens breakers on knob-specific kernel crashes — a
        selection that takes the kernel down must not be served again the
        moment the request is retried.

        Cached decisions equal to ``knob`` are invalidated in the same
        critical section that opens the breaker (returns how many), which is
        what keeps the lock-free hit path free of quarantine checks: the
        cache simply never contains a quarantined knob."""
        fallback_knob = fallback
        if fallback_knob == knob:
            raise ValueError("quarantine fallback must differ from the "
                             "quarantined knob")
        sub_key = (backend, op, int(dtype_bytes))
        with self._lock:
            self._fold_touches_locked()
            self._quarantined[sub_key + (knob,)] = \
                (time.monotonic() + float(ttl_s), fallback_knob)
            self._base.quarantines += 1
            stale = [k for k, v in self._cache.items()
                     if k[:3] == sub_key and v == knob]
            for k in stale:
                del self._cache[k]
                self._cache_mirror.pop(k, None)
        if self.decision_journal is not None:
            # an opened breaker must survive a crash before the next full
            # snapshot — a crashing knob coming back on restart is exactly
            # the failure mode quarantines exist to prevent
            self._notify_journal(
                {"quarantine": 1, "backend": backend, "op": op,
                 "dtype_bytes": int(dtype_bytes), "knob": knob.dict,
                 "fallback_knob": fallback_knob.dict, "ttl_s": float(ttl_s)})
        return len(stale)

    def unquarantine(self, op: str, dtype_bytes: int, backend: str,
                     knob: Knob) -> bool:
        with self._lock:
            return self._quarantined.pop(
                (backend, op, int(dtype_bytes), knob), None) is not None

    def is_quarantined(self, op: str, dtype_bytes: int, backend: str,
                       knob: Knob) -> bool:
        """True while the breaker is open; an elapsed TTL expires lazily
        here (the probe itself half-opens the breaker)."""
        qkey = (backend, op, int(dtype_bytes), knob)
        with self._lock:
            ent = self._quarantined.get(qkey)
            if ent is None:
                return False
            if time.monotonic() >= ent[0]:
                del self._quarantined[qkey]
                return False
            return True

    def quarantined_knobs(self) -> dict[tuple, float]:
        """Active breakers: (backend, op, dtype_bytes, knob) → remaining TTL
        seconds.  Expired entries are reaped as a side effect."""
        now = time.monotonic()
        with self._lock:
            for k in [k for k, (dl, _) in self._quarantined.items()
                      if now >= dl]:
                del self._quarantined[k]
            return {k: dl - now for k, (dl, _) in self._quarantined.items()}

    def _apply_quarantine(self, sub_key: tuple,
                          knob: Knob) -> tuple[Knob, bool]:
        """Miss-path filter: map a freshly evaluated knob through any active
        breaker → ``(knob_to_serve, ok_to_store)``.  The no-breakers case
        (always, in a healthy process) is one GIL-atomic emptiness check."""
        if not self._quarantined:
            return knob, True
        qkey = sub_key + (knob,)
        with self._lock:
            ent = self._quarantined.get(qkey)
            if ent is None:
                return knob, True
            if time.monotonic() >= ent[0]:
                # TTL elapsed: half-open — serve the model's choice again
                # (and cache it; a recurrence re-opens the breaker)
                del self._quarantined[qkey]
                return knob, True
            self._base.quarantine_forced += 1
            # the forced fallback is NOT stored: the cache must keep tempting
            # the miss path to re-ask the model, so expiry is actually seen
            return ent[1], False

    # -- retuner exploration seam ---------------------------------------------
    def override_decision(self, op: str, dims: tuple[int, ...],
                          dtype_bytes: int, backend: str,
                          knob: Knob) -> bool:
        """Force the decision cache to serve ``knob`` for one shape key (the
        retuner's bounded-epsilon exploration).  Refuses actively
        quarantined knobs — exploration must never re-serve a crashing
        config; returns False when refused."""
        if type(dims) is not tuple:
            dims = tuple(dims)
        sub_key = (backend, op, int(dtype_bytes))
        with self._lock:
            ent = self._quarantined.get(sub_key + (knob,))
            if ent is not None:
                if time.monotonic() < ent[0]:
                    return False
                del self._quarantined[sub_key + (knob,)]
            self._store_locked(sub_key + (dims,), knob)
        return True

    def invalidate_decision(self, op: str, dims: tuple[int, ...],
                            dtype_bytes: int, backend: str) -> bool:
        """Drop one cached decision so the next selection re-runs the model
        (exploration restore / targeted invalidation).  Returns whether an
        entry existed."""
        if type(dims) is not tuple:
            dims = tuple(dims)
        key = (backend, op, int(dtype_bytes), dims)
        with self._lock:
            self._fold_touches_locked()
            if key not in self._cache:
                return False
            del self._cache[key]
            self._cache_mirror.pop(key, None)
        return True

    def _version_of(self, sub_key: tuple) -> int:
        """Artifact generation of the registered subroutine (0 when the
        subroutine is unregistered or was never registry-stamped)."""
        sub = self._subs_get(sub_key)
        return int(getattr(sub, "artifact_version", 0) or 0)

    def has(self, op: str, dtype_bytes: int,
            backend: str = DEFAULT_BACKEND) -> bool:
        return self._subs_get((backend, op, dtype_bytes)) is not None

    def subroutine(self, op: str, dtype_bytes: int,
                   backend: str = DEFAULT_BACKEND) -> TunedSubroutine:
        return self._subs[(backend, op, dtype_bytes)]

    def predictor(self, op: str, dtype_bytes: int,
                  backend: str = DEFAULT_BACKEND):
        """The compiled fast-path predictor, or None if uncompilable."""
        return self._fast_get((backend, op, dtype_bytes))

    def peek(self, op: str, dims: tuple[int, ...], dtype_bytes: int = 4,
             backend: str = DEFAULT_BACKEND) -> Knob | None:
        """Lock-free cache probe: the cached knob, or None on a miss.
        Records no statistics and no LRU recency — callers that act on the
        result should go through :meth:`select` (the trace-time batcher
        uses this to route only true misses into a combining window)."""
        if type(dims) is not tuple:
            dims = tuple(dims)
        return self._cache_get((backend, op, dtype_bytes, dims))

    def bucket_stats_peek(self, key: tuple) -> BucketStats | None:
        """Lock-free probe of one shape bucket's LIVE stats object, keyed
        ``(backend, op, dtype_bytes, dims)`` — or None before its first
        recorded batch.  Relaxed by design (a racing ``record_batch`` may
        be mid-update): the serving admission controller reads
        ``mean_queue`` from it as an *estimate* on every submit, which must
        not take the runtime lock."""
        return self._base.buckets.get(key)

    def backends(self) -> tuple[str, ...]:
        """Backend names with at least one registered subroutine."""
        with self._lock:
            return tuple(sorted({k[0] for k in self._subs}))

    # -- the runtime decision -------------------------------------------------
    def select(self, op: str, dims: tuple[int, ...], dtype_bytes: int = 4,
               backend: str = DEFAULT_BACKEND) -> Knob:
        if type(dims) is not tuple:
            dims = tuple(dims)
        key = (backend, op, dtype_bytes, dims)
        knob = self._cache_get(key)          # lock-free GIL-atomic read
        if knob is not None:
            # hot hit path, accounting inlined and lock-free: run-length
            # stripe increment + sampled LRU touch (folded on the next miss)
            try:
                s = self._hits_local.stripe
            except AttributeError:
                s = self._stripe()
            if backend is not s.backend and backend != s.backend:
                s.switch(backend)
            s.n += 1
            if not (s.n & self._touch_mask):
                touches = self._touches
                touches.append(key)
                if len(touches) >= _TOUCH_FOLD_LIMIT:
                    with self._lock:
                        self._fold_touches_locked()
            return knob
        return self._select_miss(key)

    def _shard(self, bk_op: tuple[str, str]) -> _Shard:
        shard = self._shards_get(bk_op)
        if shard is None:
            with self._lock:
                shard = self._shards.setdefault(bk_op, _Shard())
        return shard

    def _select_miss(self, key: tuple) -> Knob:
        backend, op, dtype_bytes, dims = key
        sub_key = (backend, op, dtype_bytes)
        if self._subs_get(sub_key) is None:
            raise KeyError(sub_key)
        epoch = self._epoch_get(sub_key, 0)   # before joining the in-flight
        shard = self._shard((backend, op))
        with shard.lock:
            ent = shard.inflight.get(key)
            leader = ent is None
            if leader:
                ent = shard.inflight[key] = _Inflight(epoch=epoch)
        if not leader:
            # same-key coalescing: ride the evaluation already in flight
            # (a knob served from someone else's paid-for computation is a
            # hit for accounting purposes) — unless that evaluation began
            # before a hot swap we have already observed: its result is the
            # superseded model's decision and must not be served to a call
            # that started after the swap completed
            if ent.epoch == epoch and ent.event.wait(timeout=60.0) \
                    and ent.knob is not None:
                self._record_hit(backend, key)
                return ent.knob
            return self._evaluate_and_store(key, sub_key, shard, epoch)
        try:
            # re-probe after winning leadership: a thread descheduled
            # between the lock-free cache check and here may find the key
            # already stored by a previous leader — serving the cached
            # knob keeps "one eval per key" exact instead of best-effort
            knob = self._cache_get(key)
            if knob is not None:
                ent.knob = knob
                self._record_hit(backend, key)
                return knob
            knob = ent.knob = self._evaluate_and_store(key, sub_key, shard,
                                                       epoch)
            return knob
        finally:
            ent.event.set()
            with shard.lock:
                shard.inflight.pop(key, None)

    def _evaluate_and_store(self, key: tuple, sub_key: tuple,
                            shard: _Shard, epoch: int) -> Knob:
        # model evaluation runs with NO lock held (pure numpy,
        # deterministic) so concurrent distinct-shape selections never
        # serialise; eval statistics live on the (backend, op) shard
        sub = self._subs_get(sub_key)
        fast = self._fast_get(sub_key)
        if self._faults is not None:
            self._faults.fire("predictor_eval", backend=key[0], op=key[1],
                              dtype_bytes=key[2], dims=key[3])
        t0 = time.perf_counter()
        knob = fast.select(key[3]) if fast is not None else sub.select(key[3])
        shard.count_eval(time.perf_counter() - t0)
        knob, store_ok = self._apply_quarantine(sub_key, knob)
        stored = False
        with self._lock:
            # a hot swap invalidated this subroutine's cache entries while
            # we were evaluating: our knob may be the OLD model's decision —
            # return it (this call was in flight) but never store it
            if store_ok and self._swap_epochs.get(sub_key, 0) == epoch:
                self._store_locked(key, knob)
                stored = True
        if stored and self.decision_journal is not None:
            self._notify_journal(self._decision_record(key, knob))
        return knob

    def _store_locked(self, key: tuple, knob: Knob) -> None:
        cache = self._cache
        if len(cache) >= self._cache_size and key not in cache:
            # an eviction is due: honour pending hit recency first.  (The
            # fold used to run on every miss; eviction time is the only
            # point the relaxed LRU order is actually consulted.)
            self._fold_touches_locked()
        cache[key] = knob
        cache.move_to_end(key)
        self._cache_mirror[key] = knob
        while len(cache) > self._cache_size:
            old, _ = cache.popitem(last=False)
            self._cache_mirror.pop(old, None)

    def select_or_default(self, op: str, dims: tuple[int, ...],
                          dtype_bytes: int, default: Knob, *,
                          backend: str = DEFAULT_BACKEND) -> Knob:
        """Graceful degradation: untuned subroutines run the default config
        (a node that lost its model files keeps serving — fault tolerance).
        Default-path calls are recorded so `RuntimeStats` sees all traffic.

        A miss-path model evaluation that *raises* degrades the same way —
        the caller gets the default config instead of a failed BLAS call,
        and the failure is counted in ``stats.eval_failures`` (a broken
        predictor must cost performance, never availability).

        The registered-subroutine check is a lock-free read, so the common
        cases cost one lock acquisition (default, miss) or zero (hit)
        instead of the old check-release-reacquire round trip."""
        if self._subs_get((backend, op, dtype_bytes)) is None:
            with self._lock:
                base = self._base
                base.calls += 1
                base.default_calls += 1
                b = base.for_backend(backend)
                b.calls += 1
                b.default_calls += 1
            return default
        try:
            return self.select(op, dims, dtype_bytes, backend=backend)
        except Exception:
            with self._lock:
                base = self._base
                base.calls += 1
                base.default_calls += 1
                base.eval_failures += 1
                b = base.for_backend(backend)
                b.calls += 1
                b.default_calls += 1
            return default

    # -- batched decisions ----------------------------------------------------
    def select_many(self, requests, *,
                    record_hits: bool = True) -> list[Knob | None]:
        """Batched knob selection.

        ``requests`` is a sequence of ``(op, dims, dtype_bytes, backend)``
        tuples; returns one Knob per request (``None`` where no subroutine
        is registered — callers treat those like the select_or_default
        fallback).  Hits resolve lock-free exactly like :meth:`select`.
        All missing keys that share one subroutine are evaluated in a
        single fused feature-build + model-predict call, then stored under
        one lock acquisition.  Decisions and statistics match N individual
        ``select`` calls (duplicate keys beyond the first count as hits).

        ``record_hits=False`` keeps cache hits out of the statistics (model
        evaluations are always recorded — they really ran).  The serving
        prewarm uses this so speculative decision lookups don't inflate the
        hit rate the executors' own selections report.
        """
        out: list[Knob | None] = [None] * len(requests)
        misses: dict[tuple, list[int]] = {}
        for i, (op, dims, dtype_bytes, backend) in enumerate(requests):
            if type(dims) is not tuple:
                dims = tuple(dims)
            key = (backend, op, dtype_bytes, dims)
            knob = self._cache_get(key)
            if knob is not None:
                if record_hits:
                    self._record_hit(backend, key)
                out[i] = knob
            else:
                misses.setdefault(key, []).append(i)
        if not misses:
            return out

        # missing keys join the same per-shard in-flight protocol as the
        # one-at-a-time miss path, so a select_many racing a concurrent
        # select (or another select_many) on the same key still costs ONE
        # model evaluation total — the serving prewarm races the workers'
        # own selections by design, and without this the loser of the race
        # double-counted (and double-paid) the evaluation
        shard_groups: dict = {}               # shard -> [keys]
        epochs: dict[tuple, int] = {}         # sub_key -> swap epoch snapshot
        for key in misses:
            if self._subs_get(key[:3]) is None:
                continue                      # unregistered: stays None
            if key[:3] not in epochs:         # before joining the in-flight
                epochs[key[:3]] = self._epoch_get(key[:3], 0)
            shard_groups.setdefault(self._shard(key[:2]), []).append(key)
        by_sub: dict[tuple, list[tuple]] = {}
        owned: dict[tuple, tuple] = {}        # key -> (_Inflight, shard)
        followers: dict[tuple, object] = {}   # key -> someone else's entry
        resolved: dict[tuple, Knob] = {}
        # one shared Event backs every key this call leads (they resolve
        # together in the fused evaluation), and registration takes each
        # shard's lock once for its whole key group — per-key locking and
        # Event allocation were measurable on the 64-key batched path
        batch_event = threading.Event()
        for shard, keys in shard_groups.items():
            with shard.lock:
                for key in keys:
                    ent = shard.inflight.get(key)
                    if ent is None:
                        ent = shard.inflight[key] = _Inflight(
                            batch_event, epoch=epochs[key[:3]])
                        owned[key] = (ent, shard)
                    else:
                        followers[key] = ent
        for key in list(owned):
            # we lead these keys — re-probe after winning leadership (a
            # previous leader may have stored one between our lock-free
            # miss and here), keeping "one eval per key" exact; the entry
            # stays registered until the shared release below
            knob = self._cache_get(key)
            if knob is not None:
                resolved[key] = knob
                if record_hits:
                    self._record_hit(key[0], key)
                continue
            by_sub.setdefault(key[:3], []).append(key)
        no_store: set[tuple] = set()          # quarantine-forced decisions
        stored_keys: list[tuple] = []         # journaled after the release
        try:
            for sub_key, keys in by_sub.items():
                sub = self._subs_get(sub_key)
                fast = self._fast_get(sub_key)
                try:
                    if self._faults is not None:
                        self._faults.fire(
                            "predictor_eval", backend=sub_key[0],
                            op=sub_key[1], dtype_bytes=sub_key[2],
                            n=len(keys))
                    t0 = time.perf_counter()
                    if fast is not None:
                        knobs = fast.select_many([k[3] for k in keys])
                    else:
                        knobs = [sub.select(k[3]) for k in keys]
                except Exception:
                    # a failed fused evaluation degrades only its own group:
                    # the keys stay unresolved (callers treat None like the
                    # untuned default) instead of poisoning the whole batch
                    with self._lock:
                        self._base.eval_failures += len(keys)
                    continue
                # eval statistics live on the (backend, op) shard, like
                # the one-at-a-time miss path
                self._shard(sub_key[:2]).count_eval(
                    time.perf_counter() - t0, n=len(keys))
                for key, knob in zip(keys, knobs):
                    knob, store_ok = self._apply_quarantine(sub_key, knob)
                    resolved[key] = knob
                    if not store_ok:
                        no_store.add(key)
            if owned:
                with self._lock:
                    for key in owned:
                        knob = resolved.get(key)
                        # skip keys whose subroutine was hot-swapped while
                        # we evaluated: the knob is the old model's decision
                        # (returned to this in-flight caller, never stored) —
                        # and quarantine-forced fallbacks, which must never
                        # shadow the model's real choice in the cache
                        if knob is not None and key not in no_store \
                                and self._swap_epochs.get(
                                    key[:3], 0) == epochs[key[:3]]:
                            self._store_locked(key, knob)
                            stored_keys.append(key)
        finally:
            # release owned entries BEFORE waiting on anyone else's (no
            # wait cycles possible); a failed evaluation releases with
            # knob=None so racers fall back to their own eval.  Knobs are
            # published before the single shared-event set, and the
            # removals take each shard's lock once.
            for key, (ent, _shard) in owned.items():
                ent.knob = resolved.get(key)
            batch_event.set()
            for shard, keys in shard_groups.items():
                with shard.lock:
                    for key in keys:
                        if key in owned:
                            shard.inflight.pop(key, None)
        # incremental persistence AFTER the in-flight release: journal file
        # I/O must never hold followers on the shared event
        if stored_keys and self.decision_journal is not None:
            for key in stored_keys:
                self._notify_journal(self._decision_record(key,
                                                           resolved[key]))
        # absorb keys someone else was already evaluating — their eval,
        # their eval-count; recorded as a hit only when hits are recorded.
        # An entry whose epoch predates our snapshot is a pre-swap leader
        # still computing on the superseded model: evaluate fresh instead.
        for key, ent in followers.items():
            if ent.epoch == epochs[key[:3]] and ent.event.wait(timeout=60.0) \
                    and ent.knob is not None:
                resolved[key] = ent.knob
                if record_hits:
                    self._record_hit(key[0], key)
            else:                 # timed out / leader failed / stale epoch
                try:
                    resolved[key] = self.select(key[1], key[3], key[2],
                                                backend=key[0])
                except Exception:
                    with self._lock:       # leave None: caller runs default
                        self._base.eval_failures += 1
        for key, slots in misses.items():
            knob = resolved.get(key)
            if knob is None:
                continue            # unregistered subroutine: leave None
            for i in slots:
                out[i] = knob
            if record_hits and len(slots) > 1:   # duplicate keys = hits
                self._record_hit(key[0], key, len(slots) - 1)
        return out

    # -- serving accounting ---------------------------------------------------
    def record_batch(self, op: str, dims: tuple[int, ...], dtype_bytes: int,
                     backend: str, batch_size: int, *,
                     exec_seconds: float = 0.0, exec_items: int = 0,
                     queue_seconds: float = 0.0) -> None:
        """Credit one stacked execution of ``batch_size`` requests to the
        shape bucket keyed like the decision cache (serving layer hook).

        ``exec_seconds`` must cover ONLY the stacked execution span (the
        ``run_op`` call) over ``exec_items`` stacked rows; queue/linger wait
        accumulated before execution goes into ``queue_seconds``.  The
        execution-only split is what the online retuner samples — a span
        that included scheduler wait would read as model drift every time
        the batching policy lingered."""
        key = (backend, op, dtype_bytes, tuple(int(d) for d in dims))
        with self._lock:
            b = self._base.for_bucket(key)
            b.batches += 1
            b.requests += int(batch_size)
            b.max_batch = max(b.max_batch, int(batch_size))
            b.exec_seconds += float(exec_seconds)
            b.exec_items += int(exec_items)
            b.queue_seconds += float(queue_seconds)

    # -- warm-start persistence ----------------------------------------------
    def export_cache(self) -> list[dict]:
        """Decision-cache contents as JSON-safe records, LRU-oldest first,
        so a restarted server can skip the cold-start model evaluations.

        Each record carries the ``artifact_version`` of the subroutine that
        is registered for its key *now* — which is also the one that made
        the decision, because :meth:`swap` invalidates a subroutine's
        entries in the same critical section that replaces it.

        Active knob quarantines are exported too (``{"quarantine": 1, ...}``
        records, prepended, TTL rebased to *remaining* seconds): a crashing
        knob must stay benched across a warm restart, not get a fresh shot
        because the process recycled.  An attached error-budget ledger's
        rungs (``{"budget": 1, ...}`` records, first) ride along the same
        way — a rung that exhausted its budget stays skipped after a
        restart."""
        led = self._budgets
        budget_records = led.export() if led is not None else []
        with self._lock:
            self._fold_touches_locked()
            now = time.monotonic()
            out: list[dict] = budget_records + [
                {"quarantine": 1, "backend": qk[0], "op": qk[1],
                 "dtype_bytes": int(qk[2]), "knob": qk[3].dict,
                 "fallback_knob": fb.dict, "ttl_s": deadline - now}
                for qk, (deadline, fb) in self._quarantined.items()
                if deadline > now]
            out.extend(
                {"backend": k[0], "op": k[1], "dtype_bytes": int(k[2]),
                 "dims": [int(d) for d in k[3]], "knob": knob.dict,
                 "artifact_version": self._version_of(k[:3])}
                for k, knob in self._cache.items())
            return out

    def import_cache(self, entries: list[dict]) -> int:
        """Warm-start the decision cache from :meth:`export_cache` records;
        returns how many entries were imported.

        Imported decisions count as neither calls nor hits; subsequent
        ``select`` calls on these shapes are cache hits and run no model.
        Entries beyond ``cache_size`` evict in the usual LRU order.  Note
        that ``select_or_default`` still serves its default for subroutines
        with no registered model, warm cache or not.

        A persisted cache can outlive the model that produced it, two ways —
        both are dropped with a counted stat instead of replayed:

        * **generation mismatch** (``stats.import_drops_version``): the
          entry's ``artifact_version`` differs from the registered
          subroutine's — a reinstall/retune happened between persist and
          warm start, so the cached knob is the predecessor model's
          decision.  Entries with no version field (pre-versioning caches)
          are treated as version 0 and only match never-stamped artifacts.
        * **knob left the space** (``stats.import_drops_knob``): a
          recalibration changed the candidate space and the cached knob no
          longer exists in it (stale artifacts must not dictate impossible
          configs).
        * **knob under quarantine** (``stats.import_drops_quarantine``):
          quarantine records are reinstated *first* (their remaining TTL
          resumes from now; any of *our* cached decisions for the benched
          knob are evicted in the same step, preserving the
          cache-never-holds-a-quarantined-knob invariant fleet-wide), and
          any decision entry whose knob is actively quarantined is then
          dropped — a warm start must not resurrect the selection that was
          crashing when the cache was persisted.

        Entries for unregistered subroutines import as-is — there is no
        model or space to validate against yet.

        Malformed entries — wrong types, missing fields, non-dict garbage
        (a corrupted persisted payload) — are dropped and counted
        (``stats.import_drops_corrupt``), never raised: recovery from a
        damaged cache file must cost warm starts, not availability.
        ``{"budget": 1}`` records restore the attached error-budget ledger
        (parked until :meth:`attach_budgets` when none is attached yet) and
        are not counted as imported decisions.
        """
        if self._faults is not None:
            self._faults.fire("cache_import", entries=len(entries))
        budget_records = [e for e in entries
                          if isinstance(e, dict) and e.get("budget")]
        if budget_records:
            led = self._budgets
            if led is not None:
                led.import_records(budget_records)
            else:
                with self._lock:
                    self._pending_budget_records.extend(budget_records)
        n = 0
        with self._lock:
            self._fold_touches_locked()
            now = time.monotonic()
            for e in entries:
                if not isinstance(e, dict) or not e.get("quarantine"):
                    continue
                try:
                    qkey = (str(e["backend"]), str(e["op"]),
                            int(e["dtype_bytes"]),
                            Knob(tuple(sorted(e["knob"].items()))))
                    fb = Knob(tuple(sorted(e["fallback_knob"].items())))
                    self._quarantined[qkey] = (now + float(e["ttl_s"]), fb)
                    # same invariant quarantine_knob keeps: the cache never
                    # contains a quarantined knob (the hit path has no
                    # breaker check), so a peer's breaker must evict OUR
                    # cached decisions for the knob, not just gate imports
                    stale = [k for k, v in self._cache.items()
                             if k[:3] == qkey[:3] and v == qkey[3]]
                    for k in stale:
                        del self._cache[k]
                        self._cache_mirror.pop(k, None)
                except Exception:    # noqa: BLE001 — corrupt record
                    self._base.import_drops_corrupt += 1
            for e in entries:
                if not isinstance(e, dict):
                    self._base.import_drops_corrupt += 1
                    continue
                if e.get("quarantine") or e.get("budget"):
                    continue
                try:
                    key = (str(e["backend"]), str(e["op"]),
                           int(e["dtype_bytes"]),
                           tuple(int(d) for d in e["dims"]))
                    knob = Knob(tuple(sorted(e["knob"].items())))
                    version = int(e.get("artifact_version", 0))
                except Exception:    # noqa: BLE001 — corrupt record
                    self._base.import_drops_corrupt += 1
                    continue
                sub = self._subs.get(key[:3])
                if sub is not None and version != self._version_of(key[:3]):
                    self._base.import_drops_version += 1
                    continue
                space = getattr(sub, "knob_space", None)
                if space is not None and knob not in space.candidates:
                    self._base.import_drops_knob += 1
                    continue
                q = self._quarantined.get(key[:3] + (knob,))
                if q is not None and q[0] > now:
                    self._base.import_drops_quarantine += 1
                    continue
                self._cache[key] = knob
                self._cache.move_to_end(key)
                self._cache_mirror[key] = knob
                n += 1
            while len(self._cache) > self._cache_size:
                old, _ = self._cache.popitem(last=False)
                self._cache_mirror.pop(old, None)
        return n

    def absorb_journal(self, records: list[dict]) -> int:
        """Absorb a batch of shared-journal records appended by *peer*
        processes (see :class:`repro.core.durable.JournalFollower`): the
        fleet-coherence path.  Semantically this is :meth:`import_cache`
        — the same version/space/quarantine drop rules apply, so a peer on
        a different artifact generation cannot pollute this cache — with
        the imports additionally counted in ``stats.journal_absorbed``.
        Idempotent: re-absorbing a record this process itself journaled
        (its own entries come back around the shared file) is a same-key
        same-knob overwrite.  Returns the number of records imported."""
        if not records:
            return 0
        n = self.import_cache(records)
        with self._lock:
            self._base.journal_absorbed += n
        return n

    def clear_cache(self) -> None:
        with self._lock:
            del self._touches[:]         # in place: hitters hold this list
            self._cache.clear()
            self._cache_mirror.clear()   # in place: readers keep their view

    def cache_len(self) -> int:
        with self._lock:
            return len(self._cache)


#: process-global runtime used by kernels.ops when none is passed explicitly
_GLOBAL: AdsalaRuntime | None = None
_GLOBAL_LOCK = threading.Lock()


def global_runtime() -> AdsalaRuntime:
    global _GLOBAL
    with _GLOBAL_LOCK:
        if _GLOBAL is None:
            _GLOBAL = AdsalaRuntime()
        return _GLOBAL
