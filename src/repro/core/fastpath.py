"""Compiled fast path for the runtime decision engine.

The paper's model-selection metric is ``s = t_orig / (t_ADSALA + t_eval)``
(§IV-D): every microsecond of knob-decision latency is charged against the
speedup of every uncached BLAS call.  The reference decision path
(:meth:`~repro.core.tuner.TunedSubroutine.select`) rebuilds a ``(K, F)``
feature matrix with ``np.tile``, walks the knob candidates in a Python loop
for the parallelism feature, and runs a three-stage pipeline *object* per
call.  :func:`compile_predictor` folds all of that into a
:class:`CompiledPredictor` once, at ``register()``/artifact-load time:

* the feature matrix is written straight into a preallocated per-thread
  buffer, computing ONLY the Table-III columns that survive the pipeline's
  correlation prune — pruned columns are never materialised;
* the Yeo-Johnson lambdas, standardizer mean/scale, and prune mask are fused
  into one vectorized pass over that buffer (add, power, subtract, divide —
  all in place, no pipeline-object hops or intermediate allocations);
* the parallelism ("nt") feature is vectorised: block knob spaces use the
  closed-form grid formula over precomputed ``(bm, bn)`` arrays, thread-count
  spaces are detected as dims-independent and their nt vector is computed
  once at compile time;
* the model is evaluated in a single ``predict`` call and the argmin mapped
  back through the candidate list.

Correctness bar: for any dims, :meth:`CompiledPredictor.select` returns the
bit-identical argmin knob of the reference path — every arithmetic step
reproduces the reference's elementwise operations (same ufuncs, same
association order, float64 throughout) restricted to the surviving columns.
``tests/test_fastpath.py`` asserts exact equality of the predicted-time
vectors on every persisted artifact.

An optional dominated-candidate prune (``prune=True``) additionally drops
candidates the tuned model never argmin-selects over the install-time
dataset's dims (persisted on the artifact as ``fast_live_idx``).  Dims
outside the dataset's bounding box fall back to full-K evaluation —
extrapolated predictions are the disagreement-prone ones — so pruning only
shortcuts the interpolation regime it was validated on.
"""

from __future__ import annotations

import threading
import warnings

import numpy as np

from . import features as F
from .knobs import _grid_parallelism

__all__ = ["CompiledPredictor", "compile_predictor"]

#: probe dims used to detect a dims-independent parallelism measure
_PROBE_A = (64, 96, 128)
_PROBE_B = (320, 192, 256)

_LEAF = -1


class _StackedForest:
    """Every tree of an ensemble, concatenated into one flat node table and
    descended level-synchronously: one set of numpy calls per depth level
    for ALL trees x rows, instead of a per-tree Python loop of per-level
    calls.  Bit-exact — tree inference is comparisons and table lookups,
    no floating-point reassociation — so folded ensembles predict the same
    values as the reference per-tree loop."""

    def __init__(self, trees) -> None:
        offsets = np.cumsum([0] + [t.feature.size for t in trees[:-1]])
        self.roots = offsets.astype(np.int64)
        self.feature = np.concatenate([t.feature for t in trees])
        self.threshold = np.concatenate([t.threshold for t in trees])
        # leaf nodes keep child = _LEAF; the shifted garbage index is never
        # *used* (is_split masks it out), matching ArrayTree.predict
        self.left = np.concatenate(
            [t.left + o for t, o in zip(trees, offsets)])
        self.right = np.concatenate(
            [t.right + o for t, o in zip(trees, offsets)])
        self.value = np.concatenate([t.value for t in trees])
        self.depth = max(t.depth for t in trees)

    def descend(self, X: np.ndarray) -> np.ndarray:
        """(T, N) per-tree predictions for the (N, F) feature matrix."""
        N = X.shape[0]
        node = np.repeat(self.roots[:, None], N, axis=1)
        rows = np.arange(N)[None, :]
        for _ in range(self.depth + 1):
            f = self.feature[node]
            is_split = f != _LEAF
            if not is_split.any():
                break
            fx = X[rows, np.maximum(f, 0)]
            go_left = fx <= self.threshold[node]
            nxt = np.where(go_left, self.left[node], self.right[node])
            node = np.where(is_split, nxt, node)
        return self.value[node]


def _fold_model(model):
    """The model's predict, with tree ensembles folded into a stacked
    single-pass evaluation.  Combination rules replicate the reference
    predicts operation for operation, so outputs are bit-identical."""
    trees = getattr(model, "trees_", None)
    if not trees or not all(hasattr(t, "feature") and hasattr(t, "depth")
                            for t in trees):
        return model.predict
    name = getattr(model, "NAME", None)
    forest = _StackedForest(trees)
    if name == "RandomForest":
        return lambda Z: np.mean(forest.descend(Z), axis=0)
    if name == "XGBoost":
        base = float(model.base_)
        lr = float(model.learning_rate)

        def xgb_predict(Z):
            P = forest.descend(Z)
            out = np.full(Z.shape[0], base)
            for i in range(P.shape[0]):        # same add order as reference
                out += lr * P[i]
            return out
        return xgb_predict
    if name == "AdaBoost":
        logw = np.log(1.0 / np.maximum(model.betas_, 1e-300))
        half = 0.5 * logw.sum()

        def ada_predict(Z):
            preds = np.ascontiguousarray(forest.descend(Z).T)      # (N, T)
            order = np.argsort(preds, axis=1)
            sorted_preds = np.take_along_axis(preds, order, axis=1)
            cum = np.cumsum(logw[order], axis=1)
            pick = (cum >= half).argmax(axis=1)
            return sorted_preds[np.arange(preds.shape[0]), pick]
        return ada_predict
    return model.predict


class CompiledPredictor:
    """One tuned (subroutine, pipeline, model) folded into a flat predict.

    Thread-safe: the preallocated feature/transform buffers are per-thread
    (the runtime evaluates models outside its lock), and all compiled
    parameters are read-only after construction.
    """

    def __init__(self, op: str, knob_space, pipeline, model,
                 log_target: bool, *, live_idx=None, dims_lo=None,
                 dims_hi=None, prune: bool = False) -> None:
        self.op = op
        self.knob_space = knob_space
        self.model = model
        self._predict = _fold_model(model)
        self.log_target = bool(log_target)
        self.candidates = list(knob_space.candidates)
        self.K = len(self.candidates)
        self.ndims = F.SUBROUTINE_NDIMS[op]

        # -- fused preprocess parameters (surviving columns only) ------------
        keep, lam, mean, scale = pipeline.fused_params()
        self.keep = keep
        self.C = int(keep.size)
        self.use_yj = lam is not None
        if self.use_yj:
            self._lam = lam.reshape(1, -1)
            self._lam_safe = np.where(np.abs(self._lam) > 1e-6,
                                      self._lam, 1.0)
            self._log_cols = np.flatnonzero(np.abs(lam) <= 1e-6)
        self._mean = mean.reshape(1, -1)
        self._scale = scale.reshape(1, -1)

        # -- vectorised parallelism ("nt") -----------------------------------
        self._nt_mode = "generic"
        self._nt_const = None
        if getattr(knob_space, "_parallelism_fn", None) is _grid_parallelism:
            dicts = [c.dict for c in self.candidates]
            self._bm = np.array([c["bm"] for c in dicts], dtype=np.float64)
            self._bn = np.array([c["bn"] for c in dicts], dtype=np.float64)
            self._nt_mode = "grid"
        else:
            try:
                va = knob_space.parallelism_vec(_PROBE_A[: self.ndims])
                vb = knob_space.parallelism_vec(_PROBE_B[: self.ndims])
                if np.array_equal(va, vb) and all(
                        "nt" in c.dict for c in self.candidates):
                    # thread-count-style space: nt never depends on dims, so
                    # this feature column is computed once, here
                    self._nt_const = np.asarray(va, dtype=np.float64)
                    self._nt_mode = "const"
            except Exception:
                pass        # exotic space: per-call parallelism_vec fallback

        # -- optional dominated-candidate prune ------------------------------
        self._live = None
        if prune and live_idx is not None and dims_lo is not None \
                and dims_hi is not None:
            live = np.unique(np.asarray(live_idx, dtype=np.int64))
            if 0 < live.size < self.K \
                    and live[0] >= 0 and live[-1] < self.K:
                self._live = live
                self._dims_lo = np.asarray(dims_lo).reshape(-1)
                self._dims_hi = np.asarray(dims_hi).reshape(-1)
                if self._nt_mode == "grid":
                    self._bm_live = self._bm[live]
                    self._bn_live = self._bn[live]
                elif self._nt_mode == "const":
                    self._nt_const_live = self._nt_const[live]

        self._tls = threading.local()

    # -- buffers --------------------------------------------------------------
    def _buffers(self, rows: int) -> tuple:
        """(X, T, nt) preallocated for this thread at ``rows`` candidates."""
        bufs = getattr(self._tls, "bufs", None)
        if bufs is None:
            bufs = self._tls.bufs = {}
        b = bufs.get(rows)
        if b is None:
            # F-order matches the reference pipeline's layout (its prune is
            # a fancy index, which numpy returns column-major), so even the
            # models' layout-sensitive low-order float bits reproduce
            b = bufs[rows] = (np.empty((rows, self.C), order="F"),
                              np.empty((rows, self.C), order="F"),
                              np.empty(rows))
        return b

    # -- feature building -----------------------------------------------------
    def _nt_into(self, dims: tuple, out: np.ndarray, bm: np.ndarray,
                 bn: np.ndarray) -> np.ndarray:
        if self._nt_mode == "grid":
            # == float(ceil(m/bm) * ceil(n/bn)) per candidate, vectorised
            np.divide(dims[0], bm, out=out)
            np.ceil(out, out=out)
            out *= np.ceil(dims[-1] / bn)
            return out
        return np.asarray(self.knob_space.parallelism_vec(dims),
                          dtype=np.float64)

    # -- the fused pass -------------------------------------------------------
    def _transform(self, X: np.ndarray, T: np.ndarray) -> np.ndarray:
        """Yeo-Johnson + standardize over the already-pruned columns, fused.

        Reproduces ``pipeline.transform`` bit-for-bit on the kept columns:
        Table-III features are non-negative, so only the reference's
        positive YJ branch — ``(power(x+1, λ) - 1)/λ`` or ``log1p(x)`` at
        λ≈0 — is ever taken.
        """
        if self.use_yj:
            np.add(X, 1.0, out=T)
            np.power(T, self._lam, out=T)
            np.subtract(T, 1.0, out=T)
            np.divide(T, self._lam_safe, out=T)
            for j in self._log_cols:
                np.log1p(X[:, j], out=T[:, j])
            Z = T
        else:
            Z = X
        np.subtract(Z, self._mean, out=Z)
        np.divide(Z, self._scale, out=Z)
        return Z

    def _times(self, dims: tuple, rows_idx: np.ndarray | None) -> np.ndarray:
        """Predicted time per candidate (all K, or the live subset)."""
        if rows_idx is None:
            rows = self.K
            bm = getattr(self, "_bm", None)
            bn = getattr(self, "_bn", None)
            nt_const = self._nt_const
        else:
            rows = int(rows_idx.size)
            bm = getattr(self, "_bm_live", None)
            bn = getattr(self, "_bn_live", None)
            nt_const = getattr(self, "_nt_const_live", None)
        X, T, ntb = self._buffers(rows)
        if self._nt_mode == "const":
            nt = nt_const
        else:
            nt = self._nt_into(dims, ntb, bm, bn)
            if rows_idx is not None and self._nt_mode == "generic":
                nt = nt[rows_idx]
        F.fill_features_into(self.op, dims, nt, self.keep, X)
        pred = self._predict(self._transform(X, T))
        return np.exp(pred) if self.log_target else pred

    # -- public API -----------------------------------------------------------
    def predict_times(self, dims: tuple) -> np.ndarray:
        """Predicted runtime for every knob candidate (= reference
        ``TunedSubroutine.predict_times``, bit-identical)."""
        return self._times(tuple(dims), None)

    def select_index(self, dims: tuple) -> int:
        dims = tuple(dims)
        live = self._live
        if live is not None and self._in_bounds(dims):
            return int(live[int(np.argmin(self._times(dims, live)))])
        return int(np.argmin(self._times(dims, None)))

    def select(self, dims: tuple):
        return self.candidates[self.select_index(dims)]

    def _in_bounds(self, dims: tuple) -> bool:
        lo, hi = self._dims_lo, self._dims_hi
        for i, d in enumerate(dims):
            if d < lo[i] or d > hi[i]:
                return False
        return True

    # -- batched API ----------------------------------------------------------
    def predict_times_batch(self, dims_list) -> np.ndarray:
        """(B, K) predicted times for B dims in ONE feature-build + predict.

        Row ``b`` is bit-identical to ``predict_times(dims_list[b])`` — all
        feature/transform arithmetic is elementwise and the models predict
        row-wise, so batching cannot change any decision.
        """
        B = len(dims_list)
        dims_arr = np.asarray(dims_list, dtype=np.float64)
        if self._nt_mode == "grid":
            nt = (np.ceil(dims_arr[:, :1] / self._bm) *
                  np.ceil(dims_arr[:, -1:] / self._bn))
        elif self._nt_mode == "const":
            nt = np.broadcast_to(self._nt_const, (B, self.K))
        else:
            nt = np.stack([np.asarray(self.knob_space.parallelism_vec(
                tuple(int(v) for v in d)), dtype=np.float64)
                for d in dims_list])
        # (B, K, C) view over an F-ordered (B*K, C) buffer, so the matrix
        # handed to the model has the same layout class as the single-call
        # path's F-ordered buffers (bit-stable tie-breaking either way:
        # identical feature rows within one matrix predict identical values)
        X3 = np.empty((self.C, B, self.K))
        Xv = X3.transpose(1, 2, 0)
        F.fill_features_batch(self.op, dims_arr, nt, self.keep, Xv)
        Xf = Xv.reshape(B * self.K, self.C)
        T = np.empty((B * self.K, self.C), order="F")
        pred = self._predict(self._transform(Xf, T))
        t = np.exp(pred) if self.log_target else pred
        return t.reshape(B, self.K)

    def select_many(self, dims_list) -> list:
        """Argmin knob per dims, vectorised across the whole batch.

        Applies the same dominated-candidate restriction as :meth:`select`
        (per item, honouring the bounds fallback), so batched and
        one-at-a-time decisions agree."""
        t = self.predict_times_batch(dims_list)
        live = self._live
        out = []
        for b, dims in enumerate(dims_list):
            if live is not None and self._in_bounds(tuple(dims)):
                i = int(live[int(np.argmin(t[b, live]))])
            else:
                i = int(np.argmin(t[b]))
            out.append(self.candidates[i])
        return out


def compile_predictor(sub, *, prune: bool = False) -> CompiledPredictor | None:
    """Fold a :class:`~repro.core.tuner.TunedSubroutine`-like artifact into a
    :class:`CompiledPredictor`.

    Returns ``None`` when the artifact lacks the required pieces (stub
    subroutines in tests, partially constructed objects) or compilation
    fails — callers fall back to the reference ``sub.select`` path, which is
    always correct, just slower.
    """
    pipeline = getattr(sub, "pipeline", None)
    model = getattr(sub, "model", None)
    space = getattr(sub, "knob_space", None)
    op = getattr(sub, "op", None)
    if pipeline is None or model is None or space is None \
            or op not in F.SUBROUTINE_NDIMS:
        return None
    try:
        return CompiledPredictor(
            op, space, pipeline, model,
            getattr(sub, "log_target", False),
            live_idx=getattr(sub, "fast_live_idx", None),
            dims_lo=getattr(sub, "fast_dims_lo", None),
            dims_hi=getattr(sub, "fast_dims_hi", None),
            prune=prune)
    except Exception as e:                       # noqa: BLE001
        warnings.warn(f"fast-path compile failed for {op!r} "
                      f"({type(e).__name__}: {e}); using reference path",
                      RuntimeWarning, stacklevel=2)
        return None
