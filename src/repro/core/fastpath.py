"""Compiled fast path for the runtime decision engine.

The paper's model-selection metric is ``s = t_orig / (t_ADSALA + t_eval)``
(§IV-D): every microsecond of knob-decision latency is charged against the
speedup of every uncached BLAS call.  The reference decision path
(:meth:`~repro.core.tuner.TunedSubroutine.select`) rebuilds a ``(K, F)``
feature matrix with ``np.tile``, walks the knob candidates in a Python loop
for the parallelism feature, and runs a three-stage pipeline *object* per
call.  :func:`compile_predictor` folds all of that into a
:class:`CompiledPredictor` once, at ``register()``/artifact-load time:

* the feature matrix is written straight into a preallocated per-thread
  buffer, computing ONLY the Table-III columns that survive the pipeline's
  correlation prune — pruned columns are never materialised;
* the Yeo-Johnson lambdas, standardizer mean/scale, and prune mask are fused
  into one vectorized pass over that buffer (add, power, subtract, divide —
  all in place, no pipeline-object hops or intermediate allocations);
* the parallelism ("nt") feature is vectorised: block knob spaces use the
  closed-form grid formula over precomputed ``(bm, bn)`` arrays, thread-count
  spaces are detected as dims-independent and their nt vector is computed
  once at compile time;
* **every model family lowers to a uniform branchless, table-driven
  representation** (the v2 engine):

  ========================  ==============================================
  family                    lowering
  ========================  ==============================================
  linear (LR/EN/BR)         deterministic einsum matvec
  DecisionTree/Distilled    :class:`_PredicatedTree` — slot-layout
                            fixed-depth descent, pure index arithmetic
  RF / AdaBoost / XGBoost   :class:`_StackedForest` — all trees in one
                            flat predicated table, level-synchronous
  KNN                       :class:`_ScreenedKNN` — exact lookup built
                            at compile time: BLAS-speed screen with a
                            certified margin + exact canonical rescore
                            (opt-in coreset subsample for the
                            inexact-but-faster mode)
  ========================  ==============================================

Correctness bar: for any dims, :meth:`CompiledPredictor.select` returns the
bit-identical argmin knob of the reference path — every arithmetic step
reproduces the reference's elementwise operations (same ufuncs, same
association order, float64 throughout) restricted to the surviving columns.
Tree descent and k-NN lookup are comparisons plus table gathers, so the
re-layouts cannot perturb a single bit.  ``tests/test_fastpath.py`` asserts
exact equality of the predicted-time vectors on every persisted artifact.

Two opt-in, install-analysis-backed shortcuts ride on the artifact:

* dominated-candidate prune (``prune=True``) drops candidates the tuned
  model never argmin-selects over the install-time dataset's dims
  (persisted as ``fast_live_idx``); ``prune="band"`` instead keeps every
  candidate whose predicted time ever comes within ``fast_band_pct`` % of
  the winner (a superset — robust to interpolation wobble).  Dims outside
  the dataset's bounding box fall back to full-K evaluation.
* KNN coreset (``coreset=True``) serves the k-NN lookup from a persisted
  subsample (``fast_knn_coreset``) — faster, deliberately *not* bit-exact,
  and never enabled by default.
"""

from __future__ import annotations

import threading
import warnings

import numpy as np

from . import features as F
from .knobs import _grid_parallelism

__all__ = ["CompiledPredictor", "compile_predictor"]

#: probe dims used to detect a dims-independent parallelism measure
_PROBE_A = (64, 96, 128)
_PROBE_B = (320, 192, 256)

_LEAF = -1


# ---------------------------------------------------------------------------
# predicated single-tree descent (DecisionTree / DistilledTree)
# ---------------------------------------------------------------------------

class _PredicatedTree:
    """One regression tree in a *slot* layout: slot ``p = node*R + row`` for
    a fixed row count ``R``, with leaves as self-loops (+inf thresholds, see
    :meth:`ArrayTree.predicated_arrays`).  Descent is a fixed ``depth``
    iterations of pure index arithmetic — gather, compare, fused-multiply-
    add of the comparison bit, gather — with no per-node numpy calls, no
    leaf predication, and no early-exit checks:

        fx = Xf[featS[p]]          # feature value of this row's node
        le = fx <= thrS[p]         # the reference's go_left comparison
        p  = childS2[2*p + le]     # le=1 -> left child slot, 0 -> right

    ``childS2`` interleaves ``[right, left]`` so the indexing bit IS the
    comparison result — the same ``<=`` the reference computes, hence
    identical routing for every input including ``inf``/NaN.  Bit-exact:
    comparisons and table lookups only.

    Layouts are materialised per row count (the compiled K, the pruned
    live-K) and capped — oversized requests (large batches) fall through to
    the shared :class:`_StackedForest` path, which is equally exact.
    """

    #: largest node*rows slot table materialised (memory bound per layout)
    CAP = 1 << 18
    #: total slot budget across all cached row-count layouts (the deduped
    #: row count varies per dims, so several small layouts accumulate)
    CAP_TOTAL = 1 << 20

    def __init__(self, tree) -> None:
        self.tree = tree
        self.feat, self.thr, self.left, self.right = tree.predicated_arrays()
        self.value = tree.value
        self.depth = int(tree.depth)
        self.N = int(self.feat.size)
        self._layouts: dict[int, tuple] = {}
        self._slots_used = 0
        self._generic: _StackedForest | None = None

    def _layout(self, R: int):
        lay = self._layouts.get(R)
        if lay is None:
            rows = np.arange(R, dtype=np.int64)
            featS = (self.feat[:, None] * R + rows).ravel()
            thrS = np.repeat(self.thr, R)
            child = np.empty((self.N, R, 2), dtype=np.int64)
            child[:, :, 0] = self.right[:, None] * R + rows   # le == 0
            child[:, :, 1] = self.left[:, None] * R + rows    # le == 1
            childS2 = child.reshape(-1)
            valueS = np.repeat(self.value, R)
            lay = self._layouts[R] = (featS, thrS, childS2, valueS, rows)
            self._slots_used += self.N * R
        return lay

    def warm(self, R: int) -> None:
        """Materialise the layout for ``R`` rows at compile time."""
        if self.N * R <= self.CAP:
            self._layout(R)

    def _fallback(self) -> "_StackedForest":
        if self._generic is None:
            # built from THIS engine's (possibly threshold-folded) arrays,
            # not the original tree — both paths must agree on the feature
            # space they descend in
            shim = type("_Shim", (), {
                "predicated_arrays":
                    lambda _s: (self.feat, self.thr, self.left, self.right),
                "value": self.value, "depth": self.depth})()
            self._generic = _StackedForest([shim])
        return self._generic

    def predict(self, X: np.ndarray) -> np.ndarray:
        R = X.shape[0]
        if R not in self._layouts and (
                self.N * R > self.CAP
                or self._slots_used + self.N * R > self.CAP_TOTAL):
            return self._fallback().descend(X)[0]
        featS, thrS, childS2, valueS, _rows = self._layout(R)
        Xf = X.ravel(order="F")          # zero-copy for the F-ordered buffers
        p = np.arange(R, dtype=np.int64)            # root slots
        for _ in range(self.depth):
            fx = Xf[featS[p]]
            le = fx <= thrS[p]
            np.add(p, p, out=p)
            np.add(p, le, out=p)
            p = childS2[p]
        return valueS[p]


# ---------------------------------------------------------------------------
# stacked predicated ensembles (RF / AdaBoost / XGBoost, and the tree
# fallback for oversized batches)
# ---------------------------------------------------------------------------

class _StackedForest:
    """Every tree of an ensemble, concatenated into one flat predicated node
    table and descended level-synchronously: one short set of numpy calls
    per depth level for ALL trees x rows, instead of a per-tree Python loop
    of per-level calls.  Leaves are self-loops (+inf thresholds), so the
    descent is branchless — a fixed ``depth`` iterations with no "all rows
    done?" scans.  Bit-exact: tree inference is comparisons and table
    lookups, no floating-point reassociation, so folded ensembles predict
    the same values as the reference per-tree loop."""

    def __init__(self, trees) -> None:
        preds = [t.predicated_arrays() for t in trees]
        sizes = [p[0].size for p in preds]
        offsets = np.cumsum([0] + sizes[:-1]).astype(np.int64)
        self.roots = offsets
        self.feat = np.concatenate([p[0] for p in preds])
        self.thr = np.concatenate([p[1] for p in preds])
        left = np.concatenate([p[2] + o for p, o in zip(preds, offsets)])
        right = np.concatenate([p[3] + o for p, o in zip(preds, offsets)])
        # childS2[2*node + le]: le=1 -> left (the reference's go_left)
        child = np.empty((self.feat.size, 2), dtype=np.int64)
        child[:, 0] = right
        child[:, 1] = left
        self.child2 = child.reshape(-1)
        self.value = np.concatenate([t.value for t in trees])
        self.depth = max(int(t.depth) for t in trees)
        self.T = len(trees)
        self._per_rows: dict[int, tuple] = {}   # N -> (featN, rowsT)

    def _rows_layout(self, N: int):
        lay = self._per_rows.get(N)
        if lay is None:
            featN = self.feat * N
            rowsT = np.tile(np.arange(N, dtype=np.int64), self.T)
            lay = self._per_rows[N] = (featN, rowsT)
        return lay

    def descend(self, X: np.ndarray) -> np.ndarray:
        """(T, N) per-tree predictions for the (N, F) feature matrix."""
        N = X.shape[0]
        featN, rowsT = self._rows_layout(N)
        Xf = X.ravel(order="F")
        node = np.repeat(self.roots, N)
        for _ in range(self.depth):
            f = featN[node]
            np.add(f, rowsT, out=f)
            fx = Xf[f]
            le = fx <= self.thr[node]
            np.add(node, node, out=node)
            np.add(node, le, out=node)
            node = self.child2[node]
        return self.value[node].reshape(self.T, N)


# ---------------------------------------------------------------------------
# exact screened k-NN lookup
# ---------------------------------------------------------------------------

class _ScreenedKNN:
    """Exact k-nearest-neighbour lookup: a BLAS-speed distance *screen*
    with a certified error margin, then an exact canonical rescore of the
    few survivors.

    (KD-tree and ball-partition bounds were prototyped first and measured:
    in the 6-17D standardized Table-III feature space the balls overlap so
    heavily that 30-60% of all points survive radius/box pruning — the
    classic curse of dimensionality.  The norm-expansion screen below
    prunes to within a few points of the true k-NN union at a fraction of
    the cost, while keeping the same exactness contract.)

    At compile time the training matrix is laid out contiguously with its
    row norms.  A query batch then:

    1. screens with the norm expansion ``d2a = |p|^2 - 2 z.p`` (the
       ``|z|^2`` term is constant per query row, so it cancels out of the
       k-th-smallest comparison) — one float32 sgemm plus two cheap passes
       over ``(Q, n)`` — and keeps, per query, every point within
       ``kth + margin`` of its k-th smallest screened distance, where
       ``margin`` (relative 1e-4) generously covers the float32 precision,
       the expansion's cancellation error, and any BLAS summation-order
       wobble (all ~1e-6 relative or below: a point can only be missed if
       the screen were off by two orders of magnitude more than its
       worst-case bound);
    2. computes EXACT distances for the surviving columns with the
       reference's elementwise expression (broadcast subtract, square,
       pairwise-sum) — identical bits to the brute-force matrix;
    3. selects the k nearest by the canonical ``(distance^2, index)`` order
       and combines them with the very ufunc sequence of
       :meth:`repro.core.ml.knn.KNN.predict` — bit-identical output.

    Non-finite queries (feature overflow at extreme dims) skip the screen
    and rescore against every point — still exact, just slower.

    ``coreset`` mode runs the same lookup over a persisted subsample —
    equivalent to a KNN *fit on that subsample* (deliberately inexact
    w.r.t. the full model; opt-in only).
    """

    def __init__(self, model, *, coreset_idx=None) -> None:
        X, y = model.X_, model.y_
        if coreset_idx is not None:
            sel = np.asarray(coreset_idx, dtype=np.int64)
            X, y = X[sel], y[sel]
        self.model = model
        self.k = int(model.k)
        self.weights = str(model.weights)
        self.P = np.ascontiguousarray(np.asarray(X, dtype=np.float64))
        self.y = np.asarray(y, dtype=np.float64)
        self.n = self.P.shape[0]
        # the screen runs in float32 (sgemm + cheap partition) — its only
        # job is a candidate superset, and the margin covers the precision
        # drop with ~100x headroom
        self.Pt32 = np.ascontiguousarray(self.P.T.astype(np.float32))
        self.pn32 = np.einsum("ij,ij->i", self.Pt32.T, self.Pt32.T)
        # persistent per-thread screen workspace (same pattern as the
        # CompiledPredictor feature buffers): batched select_many flushes
        # repeat the same query-row counts, so the float32 query copy and
        # the (Q, n) sgemm output are reused instead of rebuilt per call
        self._tls = threading.local()

    def _screen_buffers(self, q: int, c: int) -> tuple:
        """(Z32, d2a) preallocated for this thread at ``q`` query rows."""
        bufs = getattr(self._tls, "bufs", None)
        if bufs is None:
            bufs = self._tls.bufs = {}
        b = bufs.get(q)
        if b is None:
            b = bufs[q] = (np.empty((q, c), dtype=np.float32),
                           np.empty((q, self.n), dtype=np.float32))
        return b

    def _exact_d2(self, Z: np.ndarray, cols: np.ndarray) -> np.ndarray:
        # the reference's expression verbatim: broadcast subtract, square,
        # pairwise-sum over the contiguous feature axis -> identical bits
        return ((Z[:, None, :] - self.P[cols][None, :, :]) ** 2).sum(-1)

    #: extra screened candidates beyond k, absorbing boundary-tie clusters
    PAD = 8

    def predict(self, Z: np.ndarray) -> np.ndarray:
        n = self.n
        kk = min(self.k, n)
        # C-contiguous queries, matching the reference predict's own
        # canonicalisation: every distance reduction then associates
        # identically whether computed against the full matrix or a
        # gathered candidate subset
        Z = np.ascontiguousarray(Z)
        zn = np.einsum("ij,ij->i", Z, Z)
        Z32, d2a = self._screen_buffers(Z.shape[0], Z.shape[1])
        np.copyto(Z32, Z)                     # downcast == Z.astype(f32)
        if n <= 4 * kk or not np.isfinite(zn).all() \
                or not np.isfinite(Z32).all():
            return self._rescore(Z, np.arange(n))
        # -- screen: norm expansion at BLAS speed ------------------------
        # (|z|^2 is constant per row, so it shifts every entry AND the
        # k-th threshold equally — leave it out of the screen matrix)
        np.matmul(Z32, self.Pt32, out=d2a)
        d2a *= np.float32(-2.0)
        d2a += self.pn32
        M = min(kk + self.PAD, n)
        idx = np.argpartition(d2a, M - 1, axis=1)[:, :M]    # top-M per query
        screened = np.take_along_axis(d2a, idx, axis=1)
        kth = np.partition(screened, kk - 1, axis=1)[:, kk - 1] \
            .astype(np.float64)
        # margin scale = the true distance magnitudes at the k-th boundary
        # (kth is |z|^2-shifted, so add zn back); 1e-4 relative dwarfs the
        # float32 representation + sgemm accumulation error (~3e-6)
        margin = (zn + np.maximum(kth + zn, 0.0)) * 1e-4 + 1e-10
        thr = (kth + margin).astype(np.float32)
        counts = (d2a <= thr[:, None]).sum(axis=1)
        if int(counts.max()) <= M:
            # every possible top-k member of every query sits in its top-M
            # (if any point outside the top-M were within thr, the count
            # would exceed M) — rescore per query, no cross-query union
            o = np.sort(idx, axis=1)          # ascending original index
            d2 = ((Z[:, None, :] - self.P[o]) ** 2).sum(-1)
            nn = np.argsort(d2, axis=1, kind="stable")[:, :kk]
            ny = np.take_along_axis(self.y[o], nn, axis=1)
            nd = np.sqrt(np.take_along_axis(d2, nn, axis=1)) \
                if self.weights == "distance" else None
            return self.model._combine(ny, nd)
        # boundary-tie cluster wider than the pad: fall back to the union
        # of every query's thr-survivors (rare, still far below n)
        return self._rescore(Z, np.flatnonzero((d2a <= thr[:, None]).any(0)))

    def _rescore(self, Z: np.ndarray, cols: np.ndarray) -> np.ndarray:
        """Exact rescore + canonical selection over shared candidate
        columns (``cols`` ascend in original index, so stable-sort ties are
        already broken canonically)."""
        kk = min(self.k, self.n)
        d2 = self._exact_d2(Z, cols)
        if d2.shape[1] > 16 * kk:
            kv = np.partition(d2, kk - 1, axis=1)[:, kk - 1]
            sub = np.flatnonzero((d2 <= kv[:, None]).any(0))
            d2 = d2[:, sub]
            cols = cols[sub]
        nn = np.argsort(d2, axis=1, kind="stable")[:, :kk]
        ny = self.y[cols][nn]
        nd = np.sqrt(np.take_along_axis(d2, nn, axis=1)) \
            if self.weights == "distance" else None
        return self.model._combine(ny, nd)


# ---------------------------------------------------------------------------
# monotone-threshold folding (tree descents on RAW features)
# ---------------------------------------------------------------------------

def _invert_monotone_thresholds(tfun, thr: np.ndarray,
                                saturates: np.ndarray | None = None
                                ) -> np.ndarray:
    """Per-node raw-space thresholds: the largest finite float ``x >= 0``
    with ``tfun(x) <= thr``, found by bisection over the IEEE-754 bit
    representation (monotone for non-negative doubles), vectorised over all
    nodes at once.

    ``tfun`` must evaluate each node's per-column preprocess transform with
    the exact ufunc sequence of :meth:`CompiledPredictor._transform`; since
    the float transform is monotone non-decreasing (Yeo-Johnson with any
    lambda, then an affine map with positive scale), the comparison
    ``raw_x <= inverted_thr`` is then EXACTLY equivalent to
    ``tfun(raw_x) <= thr`` for every representable non-negative input,
    including ``+inf`` — the whole preprocess pass disappears from tree
    descents with zero effect on any decision.  Non-finite thresholds (the
    +inf leaf self-loops) pass through untouched.

    ``saturates`` marks nodes whose transform approaches a FINITE limit as
    ``x -> inf`` (Yeo-Johnson with negative lambda): when such a node's
    threshold clears the entire finite range, ``tfun(inf) <= thr`` is still
    True, so the inverted threshold must be ``+inf`` rather than the
    largest finite double (an ``x = +inf`` query would otherwise flip from
    left to right).  Non-saturating transforms diverge at infinity and need
    no special case.
    """
    n = thr.size
    lo = np.zeros(n, dtype=np.int64)                  # bits of +0.0
    hi = np.full(n, np.float64(np.finfo(np.float64).max).view(np.int64))
    with np.errstate(over="ignore", invalid="ignore", divide="ignore"):
        for _ in range(63):                           # spans all finite bits
            mid = lo + ((hi - lo + 1) >> 1)
            ok = tfun(mid.view(np.float64)) <= thr
            lo = np.where(ok, mid, lo)
            hi = np.where(ok, hi, mid - 1)
        raw = lo.view(np.float64).copy()
        if saturates is not None:
            raw[saturates & (raw == np.finfo(np.float64).max)] = np.inf
        # thresholds below the entire non-negative range: always go right
        raw[~(tfun(np.zeros(n)) <= thr)] = -np.inf
    raw[~np.isfinite(thr)] = thr[~np.isfinite(thr)]
    return raw


# ---------------------------------------------------------------------------
# model folding
# ---------------------------------------------------------------------------

def _fold_model(model, knn_coreset=None):
    """``(predict, lowering, engine)``: the model's predict lowered to the
    uniform table-driven form, a short name of the lowering used (for
    introspection and the decision bench), and the table engine behind it
    (None for plain ``model.predict``).  Combination rules replicate the
    reference predicts operation for operation, so outputs are
    bit-identical (except the opt-in KNN coreset mode, which is documented
    as inexact)."""
    single = getattr(model, "tree_", None)
    if single is not None and hasattr(single, "predicated_arrays") \
            and hasattr(single, "depth"):
        tree = _PredicatedTree(single)
        return tree.predict, "predicated-tree", tree
    if getattr(model, "NAME", None) == "KNN" and model.X_ is not None:
        mode = "screened-knn" if knn_coreset is None \
            else "screened-knn-coreset"
        knn = _ScreenedKNN(model, coreset_idx=knn_coreset)
        return knn.predict, mode, knn
    trees = getattr(model, "trees_", None)
    if not trees or not all(hasattr(t, "predicated_arrays")
                            and hasattr(t, "depth") for t in trees):
        return model.predict, "reference-predict", None
    name = getattr(model, "NAME", None)
    forest = _StackedForest(trees)
    if name == "RandomForest":
        return (lambda Z: np.mean(forest.descend(Z), axis=0),
                "stacked-forest", forest)
    if name == "XGBoost":
        base = float(model.base_)
        lr = float(model.learning_rate)

        def xgb_predict(Z):
            P = forest.descend(Z)
            out = np.full(Z.shape[0], base)
            for i in range(P.shape[0]):        # same add order as reference
                out += lr * P[i]
            return out
        return xgb_predict, "stacked-forest", forest
    if name == "AdaBoost":
        logw = np.log(1.0 / np.maximum(model.betas_, 1e-300))
        half = 0.5 * logw.sum()

        def ada_predict(Z):
            preds = np.ascontiguousarray(forest.descend(Z).T)      # (N, T)
            order = np.argsort(preds, axis=1)
            sorted_preds = np.take_along_axis(preds, order, axis=1)
            cum = np.cumsum(logw[order], axis=1)
            pick = (cum >= half).argmax(axis=1)
            return sorted_preds[np.arange(preds.shape[0]), pick]
        return ada_predict, "stacked-forest", forest
    return model.predict, "reference-predict", None


class CompiledPredictor:
    """One tuned (subroutine, pipeline, model) folded into a flat predict.

    Thread-safe: the preallocated feature/transform buffers are per-thread
    (the runtime evaluates models outside its lock), and all compiled
    parameters are read-only after construction.
    """

    def __init__(self, op: str, knob_space, pipeline, model,
                 log_target: bool, *, live_idx=None, dims_lo=None,
                 dims_hi=None, prune=False, band_idx=None,
                 knn_coreset=None, coreset: bool = False) -> None:
        self.op = op
        self.knob_space = knob_space
        self.model = model
        self.artifact_version = 0       # stamped by compile_predictor
        self.coreset = bool(coreset) and knn_coreset is not None \
            and getattr(model, "NAME", None) == "KNN"
        self._predict, self.lowering, self._engine = _fold_model(
            model, knn_coreset=knn_coreset if self.coreset else None)
        self.log_target = bool(log_target)
        self.candidates = list(knob_space.candidates)
        self.K = len(self.candidates)
        self.ndims = F.SUBROUTINE_NDIMS[op]

        # -- fused preprocess parameters (surviving columns only) ------------
        keep, lam, mean, scale = pipeline.fused_params()
        self.keep = keep
        self.C = int(keep.size)
        self.use_yj = lam is not None
        if self.use_yj:
            self._lam = lam.reshape(1, -1)
            self._lam_safe = np.where(np.abs(self._lam) > 1e-6,
                                      self._lam, 1.0)
            self._log_cols = np.flatnonzero(np.abs(lam) <= 1e-6)
        self._mean = mean.reshape(1, -1)
        self._scale = scale.reshape(1, -1)

        # -- vectorised parallelism ("nt") -----------------------------------
        self._nt_mode = "generic"
        self._nt_const = None
        if getattr(knob_space, "_parallelism_fn", None) is _grid_parallelism:
            dicts = [c.dict for c in self.candidates]
            self._bm = np.array([c["bm"] for c in dicts], dtype=np.float64)
            self._bn = np.array([c["bn"] for c in dicts], dtype=np.float64)
            # tri_packed launches the packed triangle: (cm+1)/2 live row
            # blocks per column (see knobs._grid_parallelism) — all values
            # are small exact integers in f64, so any evaluation order
            # reproduces the reference bit-for-bit
            self._packed = np.array(
                [c.get("variant") == "tri_packed" for c in dicts],
                dtype=bool)
            # folded at compile time: spaces without tri_packed candidates
            # (gemm, symm, trsm, every legacy artifact) skip the packed
            # branch entirely — a runtime .any() costs real microseconds on
            # the K~8 cold path
            self._has_packed = bool(self._packed.any())
            self._nt_mode = "grid"
        else:
            try:
                va = knob_space.parallelism_vec(_PROBE_A[: self.ndims])
                vb = knob_space.parallelism_vec(_PROBE_B[: self.ndims])
                if np.array_equal(va, vb) and all(
                        "nt" in c.dict for c in self.candidates):
                    # thread-count-style space: nt never depends on dims, so
                    # this feature column is computed once, here
                    self._nt_const = np.asarray(va, dtype=np.float64)
                    self._nt_mode = "const"
            except Exception:
                pass        # exotic space: per-call parallelism_vec fallback

        # -- optional dominated-candidate prune ------------------------------
        # prune=True: the argmin live set; prune="band": every candidate
        # whose prediction ever came within the persisted band of the winner
        self._live = None
        pick = band_idx if prune == "band" else live_idx
        if prune and pick is not None and dims_lo is not None \
                and dims_hi is not None:
            live = np.unique(np.asarray(pick, dtype=np.int64))
            if 0 < live.size < self.K \
                    and live[0] >= 0 and live[-1] < self.K:
                self._live = live
                self._dims_lo = np.asarray(dims_lo).reshape(-1)
                self._dims_hi = np.asarray(dims_hi).reshape(-1)
                if self._nt_mode == "grid":
                    self._bm_live = self._bm[live]
                    self._bn_live = self._bn[live]
                    self._packed_live = self._packed[live]
                    self._has_packed_live = bool(self._packed_live.any())
                elif self._nt_mode == "const":
                    self._nt_const_live = self._nt_const[live]

        # element-bound lowerings get the duplicate-row fold: candidates
        # whose nt coincides produce byte-identical feature rows, and every
        # lowered predict is row-pure, so each distinct row is evaluated
        # once and scattered back (bit-exact, typically a 2-3x row cut).
        # Call-overhead-bound lowerings (predicated tree descent, linear
        # matvec) are excluded — fewer rows there saves nothing and the
        # unique() would be pure overhead.
        self._dedup = self.lowering in (
            "screened-knn", "screened-knn-coreset", "stacked-forest")
        if self._dedup and self._nt_mode == "const":
            self._const_fold = np.unique(self._nt_const, return_inverse=True)
            if self._live is not None:
                self._const_fold_live = np.unique(self._nt_const_live,
                                                  return_inverse=True)

        # tree lowerings get their thresholds inverted through the (per
        # column strictly monotone) preprocess at compile time, so descents
        # compare RAW Table-III features and the whole YJ+standardize pass
        # vanishes from the decision — bit-exactly (see
        # _invert_monotone_thresholds).  Bounded by node count: the
        # bisection is a compile-time cost paid once per artifact.
        self._skip_transform = False
        eng = self._engine
        if self.lowering in ("predicated-tree", "stacked-forest") \
                and eng is not None and eng.feat.size <= (1 << 16):
            tfun, saturates = self._node_transform(eng.feat)
            eng.thr = _invert_monotone_thresholds(tfun, eng.thr, saturates)
            self._skip_transform = True

        # predicated layouts for the row counts this predictor will serve
        # are materialised NOW, not on the first decision
        warm = getattr(self._engine, "warm", None)
        if warm is not None:
            warm(self.K)
            if self._live is not None:
                warm(int(self._live.size))

        self._tls = threading.local()

    def _node_transform(self, cols: np.ndarray):
        """``(tfun, saturates)``: the vectorised per-node column transform
        (element ``i`` applies the fused YJ+standardize of kept column
        ``cols[i]`` with the exact ufunc sequence of :meth:`_transform`)
        plus the mask of nodes whose transform saturates at a finite limit
        as ``x -> inf`` (negative-lambda Yeo-Johnson)."""
        mean = self._mean.ravel()[cols]
        scale = self._scale.ravel()[cols]
        if not self.use_yj:
            return (lambda x: (x - mean) / scale), np.zeros(cols.size, bool)
        lam = self._lam.ravel()[cols]
        lam_safe = self._lam_safe.ravel()[cols]
        islog = np.isin(cols, self._log_cols)

        def tfun(x: np.ndarray) -> np.ndarray:
            t = (np.power(x + 1.0, lam) - 1.0) / lam_safe
            if islog.any():
                t = np.where(islog, np.log1p(x), t)
            return (t - mean) / scale
        return tfun, (lam < 0) & ~islog

    # -- buffers --------------------------------------------------------------
    def _buffers(self, rows: int) -> tuple:
        """(X, T, nt) preallocated for this thread at ``rows`` candidates."""
        bufs = getattr(self._tls, "bufs", None)
        if bufs is None:
            bufs = self._tls.bufs = {}
        b = bufs.get(rows)
        if b is None:
            # F-order matches the reference pipeline's layout (its prune is
            # a fancy index, which numpy returns column-major), so even the
            # models' layout-sensitive low-order float bits reproduce
            b = bufs[rows] = (np.empty((rows, self.C), order="F"),
                              np.empty((rows, self.C), order="F"),
                              np.empty(rows))
        return b

    # -- feature building -----------------------------------------------------
    def _nt_into(self, dims: tuple, out: np.ndarray, bm: np.ndarray,
                 bn: np.ndarray, packed: np.ndarray | None) -> np.ndarray:
        if self._nt_mode == "grid":
            # == float(ceil(m/bm) * ceil(n/bn)) per candidate, vectorised;
            # tri_packed rows carry the packed-triangle fraction (cm+1)/2
            # (exact small integers in f64 — bit-equal to the reference
            # regardless of evaluation order)
            np.divide(dims[0], bm, out=out)
            np.ceil(out, out=out)
            if packed is not None:        # caller passes it only when set
                out[packed] = (out[packed] + 1.0) * 0.5
            out *= np.ceil(dims[-1] / bn)
            return out
        return np.asarray(self.knob_space.parallelism_vec(dims),
                          dtype=np.float64)

    # -- the fused pass -------------------------------------------------------
    def _transform(self, X: np.ndarray, T: np.ndarray) -> np.ndarray:
        """Yeo-Johnson + standardize over the already-pruned columns, fused.

        Reproduces ``pipeline.transform`` bit-for-bit on the kept columns:
        Table-III features are non-negative, so only the reference's
        positive YJ branch — ``(power(x+1, λ) - 1)/λ`` or ``log1p(x)`` at
        λ≈0 — is ever taken.
        """
        if self.use_yj:
            np.add(X, 1.0, out=T)
            np.power(T, self._lam, out=T)
            np.subtract(T, 1.0, out=T)
            np.divide(T, self._lam_safe, out=T)
            for j in self._log_cols:
                np.log1p(X[:, j], out=T[:, j])
            Z = T
        else:
            Z = X
        np.subtract(Z, self._mean, out=Z)
        np.divide(Z, self._scale, out=Z)
        return Z

    def _times(self, dims: tuple, rows_idx: np.ndarray | None) -> np.ndarray:
        """Predicted time per candidate (all K, or the live subset)."""
        if rows_idx is None:
            rows = self.K
            bm = getattr(self, "_bm", None)
            bn = getattr(self, "_bn", None)
            packed = self._packed if getattr(self, "_has_packed", False) \
                else None
            nt_const = self._nt_const
            const_fold = getattr(self, "_const_fold", None)
        else:
            rows = int(rows_idx.size)
            bm = getattr(self, "_bm_live", None)
            bn = getattr(self, "_bn_live", None)
            packed = self._packed_live \
                if getattr(self, "_has_packed_live", False) else None
            nt_const = getattr(self, "_nt_const_live", None)
            const_fold = getattr(self, "_const_fold_live", None)
        inv = None
        if self._nt_mode == "const":
            nt = nt_const
            if const_fold is not None and const_fold[0].size < rows:
                nt, inv = const_fold
        else:
            _, _, ntb = self._buffers(rows)
            nt = self._nt_into(dims, ntb, bm, bn, packed)
            if rows_idx is not None and self._nt_mode == "generic":
                nt = nt[rows_idx]
            if self._dedup:
                # dict-based exact fold: ~4x cheaper than np.unique at
                # candidate-set sizes, and keeps first-seen order
                seen: dict = {}
                uinv = []
                for v in nt.tolist():
                    j = seen.get(v)
                    if j is None:
                        j = seen[v] = len(seen)
                    uinv.append(j)
                if len(seen) < rows:
                    nt = np.fromiter(seen, dtype=np.float64)
                    inv = np.asarray(uinv, dtype=np.int64)
        X, T, _ = self._buffers(int(nt.size))
        F.fill_features_into(self.op, dims, nt, self.keep, X)
        Z = X if self._skip_transform else self._transform(X, T)
        pred = self._predict(Z)
        if self.log_target:
            pred = np.exp(pred)      # before the scatter: fewer rows
        if inv is not None:
            pred = pred[inv.reshape(-1)]
        return pred

    # -- public API -----------------------------------------------------------
    def predict_times(self, dims: tuple) -> np.ndarray:
        """Predicted runtime for every knob candidate (= reference
        ``TunedSubroutine.predict_times``, bit-identical)."""
        return self._times(tuple(dims), None)

    def select_index(self, dims: tuple) -> int:
        dims = tuple(dims)
        live = self._live
        if live is not None and self._in_bounds(dims):
            return int(live[int(np.argmin(self._times(dims, live)))])
        return int(np.argmin(self._times(dims, None)))

    def select(self, dims: tuple):
        return self.candidates[self.select_index(dims)]

    def _in_bounds(self, dims: tuple) -> bool:
        lo, hi = self._dims_lo, self._dims_hi
        for i, d in enumerate(dims):
            if d < lo[i] or d > hi[i]:
                return False
        return True

    # -- batched API ----------------------------------------------------------
    def predict_times_batch(self, dims_list) -> np.ndarray:
        """(B, K) predicted times for B dims in ONE feature-build + predict.

        Row ``b`` is bit-identical to ``predict_times(dims_list[b])`` — all
        feature/transform arithmetic is elementwise and the models predict
        row-wise, so batching cannot change any decision.
        """
        B = len(dims_list)
        dims_arr = np.asarray(dims_list, dtype=np.float64)
        if self._nt_mode == "grid":
            cm = np.ceil(dims_arr[:, :1] / self._bm)
            if self._has_packed:
                cm = np.where(self._packed, (cm + 1.0) * 0.5, cm)
            nt = cm * np.ceil(dims_arr[:, -1:] / self._bn)
        elif self._nt_mode == "const":
            nt = np.broadcast_to(self._nt_const, (B, self.K))
        else:
            nt = np.stack([np.asarray(self.knob_space.parallelism_vec(
                tuple(int(v) for v in d)), dtype=np.float64)
                for d in dims_list])
        if self._dedup:
            # fold duplicate (item, nt) rows across the whole batch: the
            # complex key packs the pair exactly (two float64s), and rows
            # with equal dims AND nt are byte-identical, so one evaluation
            # per distinct key scatters back bit-exactly
            keys = np.empty((B, self.K), dtype=np.complex128)
            keys.real = nt
            keys.imag = np.arange(B, dtype=np.float64)[:, None]
            uk, inv = np.unique(keys.reshape(-1), return_inverse=True)
            U = uk.size
            if U < B * self.K:
                dims_u = dims_arr[uk.imag.astype(np.int64)]
                nt_u = np.ascontiguousarray(uk.real)
                X3 = np.empty((self.C, U, 1))
                Xv = X3.transpose(1, 2, 0)
                F.fill_features_batch(self.op, dims_u, nt_u.reshape(U, 1),
                                      self.keep, Xv)
                Xf = Xv.reshape(U, self.C)
                if self._skip_transform:
                    pred = self._predict(Xf)
                else:
                    T = np.empty((U, self.C), order="F")
                    pred = self._predict(self._transform(Xf, T))
                pred = pred[inv.reshape(-1)]
                t = np.exp(pred) if self.log_target else pred
                return t.reshape(B, self.K)
        # (B, K, C) view over an F-ordered (B*K, C) buffer, so the matrix
        # handed to the model has the same layout class as the single-call
        # path's F-ordered buffers (bit-stable tie-breaking either way:
        # identical feature rows within one matrix predict identical values)
        X3 = np.empty((self.C, B, self.K))
        Xv = X3.transpose(1, 2, 0)
        F.fill_features_batch(self.op, dims_arr, nt, self.keep, Xv)
        Xf = Xv.reshape(B * self.K, self.C)
        if self._skip_transform:
            pred = self._predict(Xf)
        else:
            T = np.empty((B * self.K, self.C), order="F")
            pred = self._predict(self._transform(Xf, T))
        t = np.exp(pred) if self.log_target else pred
        return t.reshape(B, self.K)

    def select_many(self, dims_list) -> list:
        """Argmin knob per dims, vectorised across the whole batch.

        Applies the same dominated-candidate restriction as :meth:`select`
        (per item, honouring the bounds fallback), so batched and
        one-at-a-time decisions agree."""
        t = self.predict_times_batch(dims_list)
        live = self._live
        out = []
        for b, dims in enumerate(dims_list):
            if live is not None and self._in_bounds(tuple(dims)):
                i = int(live[int(np.argmin(t[b, live]))])
            else:
                i = int(np.argmin(t[b]))
            out.append(self.candidates[i])
        return out


def compile_predictor(sub, *, prune=False,
                      coreset: bool = False) -> CompiledPredictor | None:
    """Fold a :class:`~repro.core.tuner.TunedSubroutine`-like artifact into a
    :class:`CompiledPredictor`.

    ``prune``: ``False`` (full candidate set), ``True`` (argmin live set),
    or ``"band"`` (confidence-band live set — candidates ever within the
    persisted ``fast_band_pct`` % of the winner).  ``coreset=True`` opts a
    KNN artifact into its persisted inexact subsample.

    Returns ``None`` when the artifact lacks the required pieces (stub
    subroutines in tests, partially constructed objects) or compilation
    fails — callers fall back to the reference ``sub.select`` path, which is
    always correct, just slower.
    """
    pipeline = getattr(sub, "pipeline", None)
    model = getattr(sub, "model", None)
    space = getattr(sub, "knob_space", None)
    op = getattr(sub, "op", None)
    if pipeline is None or model is None or space is None \
            or op not in F.SUBROUTINE_NDIMS:
        return None
    try:
        cp = CompiledPredictor(
            op, space, pipeline, model,
            getattr(sub, "log_target", False),
            live_idx=getattr(sub, "fast_live_idx", None),
            dims_lo=getattr(sub, "fast_dims_lo", None),
            dims_hi=getattr(sub, "fast_dims_hi", None),
            band_idx=getattr(sub, "fast_band_idx", None),
            knn_coreset=getattr(sub, "fast_knn_coreset", None),
            prune=prune, coreset=coreset)
        # carried through so hot-swap/telemetry consumers (the online
        # retuner, the decision-cache export) can attribute a prediction to
        # the artifact generation that produced it without reaching back
        # into the source subroutine
        cp.artifact_version = int(getattr(sub, "artifact_version", 0) or 0)
        return cp
    except Exception as e:                       # noqa: BLE001
        warnings.warn(f"fast-path compile failed for {op!r} "
                      f"({type(e).__name__}: {e}); using reference path",
                      RuntimeWarning, stacklevel=2)
        return None
