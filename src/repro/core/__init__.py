"""ADSALA core — the paper's contribution: ML-driven runtime selection of
BLAS L3 execution configs (paper: thread count; TPU: Pallas block config).

Public surface:
    install_subroutine  — full install-time pipeline for one subroutine
    TunedSubroutine     — the persisted artifact (model + pipeline + knobs)
    AdsalaRuntime       — per-process runtime decision engine with memo cache
    ModelRegistry       — atomic msgpack persistence
    block_knob_space / thread_knob_space — the tunable config spaces
    oracle_time         — analytic v5e time oracle (CPU-only calibration)
"""

from .features import (SUBROUTINES, SUBROUTINE_NDIMS, build_features,
                       feature_names, footprint_words)
from .halton import halton_sequence, sample_dims, scrambled_halton
from .knobs import Knob, KnobSpace, block_knob_space, thread_knob_space
from .dataset import TimingDataset, gather
from .oracle import V5E, TpuSpec, oracle_time
from .preprocess import PreprocessPipeline, YeoJohnsonTransformer
from .fastpath import CompiledPredictor, compile_predictor
from .lof import lof_scores, remove_outliers
from .selection import ModelReport, evaluate_candidates, select_best
from .tuner import (TunedSubroutine, attach_knn_coreset, install_backend,
                    install_subroutine)
from .runtime import (AdsalaRuntime, BackendStats, BucketStats, RuntimeStats,
                      global_runtime)
from .registry import (ModelRegistry, load_subroutine, pack_state,
                       save_subroutine, unpack_state)
from .distill import DistilledTree

__all__ = [
    "SUBROUTINES", "SUBROUTINE_NDIMS", "build_features", "feature_names",
    "footprint_words", "halton_sequence", "sample_dims", "scrambled_halton",
    "Knob", "KnobSpace", "block_knob_space", "thread_knob_space",
    "TimingDataset", "gather", "V5E", "TpuSpec", "oracle_time",
    "PreprocessPipeline", "YeoJohnsonTransformer", "CompiledPredictor",
    "compile_predictor", "lof_scores",
    "remove_outliers", "ModelReport", "evaluate_candidates", "select_best",
    "TunedSubroutine", "install_subroutine", "install_backend",
    "attach_knn_coreset",
    "AdsalaRuntime", "BackendStats", "BucketStats", "RuntimeStats",
    "global_runtime", "ModelRegistry", "load_subroutine", "pack_state",
    "save_subroutine", "unpack_state", "DistilledTree",
]
