"""Crash-safe journaled persistence for runtime warm state.

The decision cache and knob quarantines are what make a restarted server
cheap (zero model evaluations on every previously seen shape — PR 2/PR 6),
so losing them to a crash mid-write silently re-inflicts the whole cold
start.  This module is the durability contract those files sit on:

* **Snapshots** are written atomically (temp file in the same directory +
  ``fsync`` + ``os.replace``) so a reader never observes a half-written
  file, and every record inside carries its own CRC32 checksum so a file
  corrupted *after* landing (torn sector, truncation, bit rot) loses only
  the damaged records.
* **Journals** are append-only side files (``<name>.journal``) holding the
  incremental records produced *between* snapshots.  Each append is a
  single flushed write; a crash mid-append tears at most the record being
  written.  Every journal record starts on its own line *prefixed* by a
  newline, so a torn tail is terminated by the next successful append and
  one torn record never swallows its successor.
* **Recovery** (:func:`read_records` / :meth:`DurableStore.load`) is
  tolerant by construction: torn or corrupt lines — bad checksum,
  truncated payload, non-JSON garbage — are dropped and *counted*, never
  raised.  The caller decides what a partial state means; this layer only
  promises that every record it returns was written completely.

File format (line-oriented, human-greppable)::

    #adsala-durable v1
    a1b2c3d4 {"backend":"pallas","op":"gemm",...}
    0f9e8d7c {"quarantine":1,...}

Fault-injection sites (see :mod:`repro.serving.faults`): writers fire
``snapshot_write`` / ``journal_append`` through an optional plan before
touching the filesystem.  A plan that raises :class:`TornWrite` makes the
writer persist only the first ``frac`` of the payload *non-atomically* at
the final path before re-raising — the deterministic stand-in for a crash
mid-write that recovery must shrug off.
"""

from __future__ import annotations

import contextlib
import json
import os
import tempfile
import threading
import zlib
from pathlib import Path

try:  # POSIX only; on other platforms appends fall back to best-effort
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX host
    fcntl = None

__all__ = ["TornWrite", "DurableStore", "JournalFollower", "MAGIC",
           "encode_record", "decode_line", "write_snapshot",
           "append_journal", "read_records", "atomic_write_bytes",
           "is_durable"]

#: first line of every durable snapshot; readers use it to distinguish the
#: checksummed format from legacy plain-JSON files
MAGIC = "#adsala-durable v1"


class TornWrite(RuntimeError):
    """Injected torn write: a durability writer that receives this from its
    fault plan persists only the first ``frac`` of the payload (at the
    FINAL path, non-atomically — the crash it models does not get to run
    the rename) and then re-raises.  Recovery must drop exactly the torn
    records, counted, without raising."""

    def __init__(self, frac: float = 0.5) -> None:
        if not 0.0 <= frac < 1.0:
            raise ValueError("frac must be in [0, 1)")
        super().__init__(f"injected torn write at {frac:.0%} of the payload")
        self.frac = float(frac)


def _crc(payload: bytes) -> str:
    return format(zlib.crc32(payload) & 0xFFFFFFFF, "08x")


def encode_record(record: dict) -> str:
    """One JSON-safe dict → one self-checksummed line (no newline)."""
    payload = json.dumps(record, sort_keys=True, separators=(",", ":"))
    return _crc(payload.encode("utf-8")) + " " + payload


def decode_line(line: str) -> dict | None:
    """Inverse of :func:`encode_record`; None for anything damaged (bad
    checksum, truncated JSON, non-dict payload) — never raises."""
    line = line.strip()
    if not line:
        return None
    crc, sep, payload = line.partition(" ")
    if not sep or _crc(payload.encode("utf-8")) != crc:
        return None
    try:
        obj = json.loads(payload)
    except ValueError:
        return None
    return obj if isinstance(obj, dict) else None


def _fire(faults, site: str, path: Path, data: bytes,
          append: bool) -> None:
    """Run the fault site; a TornWrite lands the truncated payload at the
    final path (appended for journals, clobbered for snapshots) before
    propagating — the write 'happened' as far as the disk is concerned."""
    try:
        faults.fire(site, path=str(path), size=len(data))
    except TornWrite as t:
        cut = int(len(data) * t.frac)
        with open(path, "ab" if append else "wb") as f:
            f.write(data[:cut])
        raise


def atomic_write_bytes(path: str | Path, data: bytes, *,
                       faults=None, site: str = "snapshot_write") -> None:
    """Write-temp + fsync + rename: a reader sees the old bytes or the new
    bytes, never a mix — and a crash anywhere in here leaves the previous
    file intact."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    if faults is not None:
        _fire(faults, site, path, data, append=False)
    fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def write_snapshot(path: str | Path, records: list[dict], *,
                   faults=None) -> None:
    """Atomically replace ``path`` with a checksummed snapshot of
    ``records`` (magic header + one :func:`encode_record` line each)."""
    lines = [MAGIC]
    lines.extend(encode_record(r) for r in records)
    atomic_write_bytes(path, ("\n".join(lines) + "\n").encode("utf-8"),
                       faults=faults, site="snapshot_write")


@contextlib.contextmanager
def _exclusive(f):
    """``fcntl.flock(LOCK_EX)`` around a file object — a no-op where flock
    is unavailable.  O_APPEND makes each single ``write()`` atomic with
    respect to the *offset*, but one Python-level write can still be split
    into several kernel writes under memory pressure, and two processes
    flushing interleaved chunks tear both records.  The lock serialises
    whole-record appends across processes; a single writer pays one
    uncontended syscall pair."""
    if fcntl is None:
        yield
        return
    fcntl.flock(f.fileno(), fcntl.LOCK_EX)
    try:
        yield
    finally:
        fcntl.flock(f.fileno(), fcntl.LOCK_UN)


def append_journal(path: str | Path, record: dict, *,
                   faults=None, fsync: bool = False) -> None:
    """Append one checksummed record to the journal.  The record is
    *prefixed* with a newline so it terminates any torn previous append;
    the write is flushed (surviving a process SIGKILL) and optionally
    fsynced (surviving power loss — off by default, the journal is an
    incremental optimisation over the last fsynced snapshot).  The append
    is ``flock``-guarded so concurrent writers from several processes (a
    serving fleet sharing one journal) never interleave mid-record."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    data = ("\n" + encode_record(record)).encode("utf-8")
    if faults is not None:
        _fire(faults, "journal_append", path, data, append=True)
    with open(path, "ab") as f:
        with _exclusive(f):
            f.write(data)
            f.flush()
            if fsync:
                os.fsync(f.fileno())


def read_records(path: str | Path) -> tuple[list[dict], int]:
    """Tolerant read: ``(records, dropped)``.  A missing file is empty, a
    torn/corrupt line is dropped and counted, comment lines (the magic
    header) are skipped — nothing raises."""
    path = Path(path)
    if not path.exists():
        return [], 0
    try:
        text = path.read_bytes().decode("utf-8", errors="replace")
    except OSError:
        return [], 1
    records: list[dict] = []
    dropped = 0
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("#"):
            continue
        rec = decode_line(stripped)
        if rec is None:
            dropped += 1
        else:
            records.append(rec)
    return records, dropped


def is_durable(path: str | Path) -> bool:
    """Does ``path`` start with the durable magic header?  (False for
    missing/unreadable files and legacy plain-JSON payloads.)"""
    try:
        with open(path, "rb") as f:
            head = f.read(len(MAGIC))
    except OSError:
        return False
    return head.decode("utf-8", errors="replace") == MAGIC


class DurableStore:
    """A snapshot + journal pair behind one logical state file.

    :meth:`snapshot` atomically replaces the snapshot and then truncates
    the journal (its records are now absorbed); :meth:`append` journals
    one incremental record; :meth:`load` returns snapshot records followed
    by journal records — journal last, so on key collisions a replayed
    increment wins over the stale snapshot value.  A crash between the
    snapshot rename and the journal truncate merely replays records the
    snapshot already holds, which is harmless as long as the caller's
    import is idempotent (the runtime's is: same key, same knob).
    """

    def __init__(self, path: str | Path, *, faults=None,
                 journal_fsync: bool = False) -> None:
        self.path = Path(path)
        self.journal_path = self.path.with_name(self.path.name + ".journal")
        self._faults = faults
        self._journal_fsync = bool(journal_fsync)
        self._lock = threading.Lock()

    def snapshot(self, records: list[dict]) -> None:
        with self._lock:
            write_snapshot(self.path, records, faults=self._faults)
            try:
                self.journal_path.unlink()
            except FileNotFoundError:
                pass

    def append(self, record: dict) -> None:
        with self._lock:
            append_journal(self.journal_path, record, faults=self._faults,
                           fsync=self._journal_fsync)

    def load(self) -> tuple[list[dict], int]:
        """(snapshot records + journal records, torn records dropped)."""
        with self._lock:
            snap, d_snap = read_records(self.path)
            jour, d_jour = read_records(self.journal_path)
        return snap + jour, d_snap + d_jour

    def follower(self) -> "JournalFollower":
        """A fresh incremental reader over this store's journal."""
        return JournalFollower(self.journal_path)


class JournalFollower:
    """Incremental reader over a (possibly shared) journal file.

    A fleet of serving processes appends decisions to one journal; each
    member absorbs its peers' entries by polling.  The poll must be cheap
    enough to run every scheduler tick, so :meth:`changed` is a single
    ``stat`` (file size vs. bytes already consumed) and :meth:`poll` reads
    only the bytes appended since the previous call.

    Two sharp edges of a live journal are handled here:

    * **Mid-append tails.**  Journal records are newline-*prefixed*, so the
      final record in the file is never newline-terminated and a reader can
      race a writer mid-flush.  A trailing line that fails its checksum is
      *carried* (not dropped) and re-examined on the next poll once more
      bytes land; it is only counted dropped when a later append terminates
      it without it ever having checksummed.
    * **Truncation.**  ``DurableStore.snapshot`` absorbs the journal and
      deletes it.  A follower that observes the file shrink (or vanish)
      resets to offset zero and replays from the start — safe because
      journal absorption is idempotent downstream (same key, same knob).
      Replacement is detected by inode *and* by the file's head bytes: a
      recreated journal can reuse the deleted one's inode at the very size
      already consumed, but its first record's checksum differs.
    """

    #: head-of-file bytes remembered to detect same-inode replacement
    _HEAD_LEN = 64

    def __init__(self, path: str | Path) -> None:
        self.path = Path(path)
        self._read_pos = 0          # raw bytes consumed from the file
        self._carry = b""           # undecodable tail, awaiting more bytes
        self._ino: int | None = None    # inode of the file last read from
        self._head = b""            # first bytes of the current generation
        self.dropped = 0            # torn records skipped (terminated ones)

    @property
    def position(self) -> int:
        return self._read_pos

    def changed(self) -> bool:
        """One ``stat``: has the journal grown, shrunk, or been *replaced*
        (snapshot deletes + a later append recreates it — possibly at the
        very size we had consumed) since the last poll?  False for a
        missing file we never read from.  This is a cheap *hint*: a
        recreated file reusing both our inode and our exact consumed size
        is only caught by :meth:`poll`'s head-bytes check (and by the next
        append growing the file) — callers gating on ``changed()`` absorb
        it one tick later."""
        try:
            st = os.stat(self.path)
        except OSError:
            return self._read_pos != 0
        return st.st_size != self._read_pos or st.st_ino != self._ino

    def _reset(self, ino: int | None) -> None:
        self._read_pos = 0
        self._carry = b""
        self._ino = ino
        self._head = b""

    def poll(self) -> list[dict]:
        """Records appended since the previous poll (possibly empty)."""
        try:
            f = open(self.path, "rb")
        except OSError:             # vanished: forget it, replay on return
            if self._read_pos or self._ino is not None:
                self._reset(None)
            return []
        with f:
            # fstat the OPEN fd so identity/size/bytes are one consistent
            # view even if the path is replaced mid-poll
            st = os.fstat(f.fileno())
            if st.st_ino != self._ino or st.st_size < self._read_pos:
                self._reset(st.st_ino)      # new file generation: replay
            elif self._head and f.read(len(self._head)) != self._head:
                self._reset(st.st_ino)      # same inode, different file
            if st.st_size == self._read_pos:
                return []
            if not self._head:
                self._head = f.read(self._HEAD_LEN)
            f.seek(self._read_pos)
            chunk = f.read()
        self._read_pos += len(chunk)
        buf = self._carry + chunk
        *lines, tail = buf.split(b"\n")
        records: list[dict] = []
        for raw in lines:
            s = raw.decode("utf-8", errors="replace").strip()
            if not s or s.startswith("#"):
                continue
            rec = decode_line(s)
            if rec is None:
                self.dropped += 1
            else:
                records.append(rec)
        # The tail has no terminating newline: it is complete iff it
        # checksums (a strict prefix passing CRC32 *and* parsing as JSON
        # is not a practical concern).  Otherwise hold it for next poll.
        self._carry = b""
        s = tail.decode("utf-8", errors="replace").strip()
        if s and not s.startswith("#"):
            rec = decode_line(s)
            if rec is not None:
                records.append(rec)
            else:
                self._carry = tail
        return records
