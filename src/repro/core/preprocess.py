"""Data preprocessing: Yeo-Johnson power transform (MLE), standardization,
correlation-threshold feature pruning (paper §II-C, §IV-C).

All components are numpy-only (no scipy/sklearn in the environment), carry
``get_state()/set_state()`` for msgpack/npz persistence, and are composed by
:class:`PreprocessPipeline` in the order the paper prescribes:

    Yeo-Johnson(MLE λ per feature) → standardize → corr-prune(|ρ| > 0.8)
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "yeo_johnson", "yeo_johnson_inverse", "YeoJohnsonTransformer",
    "StandardScaler", "CorrelationPruner", "PreprocessPipeline",
]


# ---------------------------------------------------------------------------
# Yeo-Johnson
# ---------------------------------------------------------------------------

def yeo_johnson(x: np.ndarray, lmbda: float) -> np.ndarray:
    """Yeo-Johnson transform of ``x`` with parameter ``lmbda``.

    Defined piecewise for x >= 0 and x < 0 [Yeo & Johnson 2000]; accepts
    non-positive values, unlike Box-Cox (the property the paper relies on).
    """
    x = np.asarray(x, dtype=np.float64)
    out = np.empty_like(x)
    pos = x >= 0
    if abs(lmbda) > 1e-6:
        out[pos] = (np.power(x[pos] + 1.0, lmbda) - 1.0) / lmbda
    else:
        out[pos] = np.log1p(x[pos])
    if abs(lmbda - 2.0) > 1e-6:
        out[~pos] = -(np.power(1.0 - x[~pos], 2.0 - lmbda) - 1.0) / (2.0 - lmbda)
    else:
        out[~pos] = -np.log1p(-x[~pos])
    return out


def yeo_johnson_inverse(y: np.ndarray, lmbda: float) -> np.ndarray:
    """Inverse of :func:`yeo_johnson` (used in property tests)."""
    y = np.asarray(y, dtype=np.float64)
    out = np.empty_like(y)
    pos = y >= 0
    if abs(lmbda) > 1e-6:
        out[pos] = np.power(lmbda * y[pos] + 1.0, 1.0 / lmbda) - 1.0
    else:
        out[pos] = np.expm1(y[pos])
    if abs(lmbda - 2.0) > 1e-6:
        out[~pos] = 1.0 - np.power(-(2.0 - lmbda) * y[~pos] + 1.0,
                                   1.0 / (2.0 - lmbda))
    else:
        out[~pos] = -np.expm1(-y[~pos])
    return out


def _yj_log_likelihood(x: np.ndarray, lmbda: float) -> float:
    """Profile log-likelihood of λ under a Gaussian model (MLE objective)."""
    n = x.shape[0]
    y = yeo_johnson(x, lmbda)
    var = y.var()
    if var <= 1e-300 or not np.isfinite(var):
        return -np.inf
    ll = -0.5 * n * np.log(var)
    # Jacobian term: (λ-1)·Σ sign(x)·log(1+|x|)
    ll += (lmbda - 1.0) * np.sum(np.sign(x) * np.log1p(np.abs(x)))
    return float(ll)


def _fit_lambda(x: np.ndarray, lo: float = -3.0, hi: float = 3.0,
                coarse: int = 25, iters: int = 60) -> float:
    """MLE λ via coarse grid + golden-section refinement (scipy-free)."""
    grid = np.linspace(lo, hi, coarse)
    lls = np.array([_yj_log_likelihood(x, l) for l in grid])
    if not np.any(np.isfinite(lls)):
        return 1.0
    k = int(np.nanargmax(np.where(np.isfinite(lls), lls, -np.inf)))
    a = grid[max(k - 1, 0)]
    b = grid[min(k + 1, coarse - 1)]
    gr = (np.sqrt(5.0) - 1.0) / 2.0
    c, d = b - gr * (b - a), a + gr * (b - a)
    fc, fd = _yj_log_likelihood(x, c), _yj_log_likelihood(x, d)
    for _ in range(iters):
        if fc > fd:
            b, d, fd = d, c, fc
            c = b - gr * (b - a)
            fc = _yj_log_likelihood(x, c)
        else:
            a, c, fc = c, d, fd
            d = a + gr * (b - a)
            fd = _yj_log_likelihood(x, d)
        if abs(b - a) < 1e-4:
            break
    return float((a + b) / 2.0)


class YeoJohnsonTransformer:
    """Per-feature Yeo-Johnson with MLE-fitted λ (paper: MLE parameter est.)."""

    def __init__(self) -> None:
        self.lambdas_: np.ndarray | None = None

    def fit(self, X: np.ndarray) -> "YeoJohnsonTransformer":
        X = np.asarray(X, dtype=np.float64)
        self.lambdas_ = np.array([_fit_lambda(X[:, j])
                                  for j in range(X.shape[1])])
        return self

    def transform(self, X: np.ndarray) -> np.ndarray:
        assert self.lambdas_ is not None, "fit first"
        X = np.asarray(X, dtype=np.float64)
        # vectorised over features (runtime eval path): both YJ branches
        # computed on the full matrix, selected by sign/λ masks
        lam = self.lambdas_[None, :]
        pos = X >= 0
        with np.errstate(divide="ignore", invalid="ignore"):
            p_gen = (np.power(np.where(pos, X, 0.0) + 1.0, lam) - 1.0) /                 np.where(np.abs(lam) > 1e-6, lam, 1.0)
            p_log = np.log1p(np.where(pos, X, 0.0))
            n_gen = -(np.power(1.0 - np.where(pos, 0.0, X), 2.0 - lam) - 1.0)                 / np.where(np.abs(2.0 - lam) > 1e-6, 2.0 - lam, 1.0)
            n_log = -np.log1p(-np.where(pos, 0.0, X))
        out = np.where(pos,
                       np.where(np.abs(lam) > 1e-6, p_gen, p_log),
                       np.where(np.abs(lam - 2.0) > 1e-6, n_gen, n_log))
        return out

    def fit_transform(self, X: np.ndarray) -> np.ndarray:
        return self.fit(X).transform(X)

    def get_state(self) -> dict:
        return {"lambdas": self.lambdas_}

    def set_state(self, s: dict) -> None:
        self.lambdas_ = np.asarray(s["lambdas"], dtype=np.float64)


# ---------------------------------------------------------------------------
# Standardization
# ---------------------------------------------------------------------------

class StandardScaler:
    def __init__(self) -> None:
        self.mean_: np.ndarray | None = None
        self.scale_: np.ndarray | None = None

    def fit(self, X: np.ndarray) -> "StandardScaler":
        X = np.asarray(X, dtype=np.float64)
        self.mean_ = X.mean(axis=0)
        std = X.std(axis=0)
        self.scale_ = np.where(std > 1e-12, std, 1.0)
        return self

    def transform(self, X: np.ndarray) -> np.ndarray:
        return (np.asarray(X, dtype=np.float64) - self.mean_) / self.scale_

    def fit_transform(self, X: np.ndarray) -> np.ndarray:
        return self.fit(X).transform(X)

    def get_state(self) -> dict:
        return {"mean": self.mean_, "scale": self.scale_}

    def set_state(self, s: dict) -> None:
        self.mean_ = np.asarray(s["mean"], dtype=np.float64)
        self.scale_ = np.asarray(s["scale"], dtype=np.float64)


# ---------------------------------------------------------------------------
# Correlation pruning
# ---------------------------------------------------------------------------

class CorrelationPruner:
    """Drop features with pairwise |ρ| above ``threshold`` (paper: 80%).

    For each correlated pair, the paper removes the member with the larger
    *total* correlation with all other features — reproduced exactly.
    """

    def __init__(self, threshold: float = 0.8) -> None:
        self.threshold = threshold
        self.keep_: np.ndarray | None = None

    def fit(self, X: np.ndarray) -> "CorrelationPruner":
        X = np.asarray(X, dtype=np.float64)
        d = X.shape[1]
        std = X.std(axis=0)
        safe = np.where(std > 1e-12, std, 1.0)
        Z = (X - X.mean(axis=0)) / safe
        corr = np.abs(Z.T @ Z / max(X.shape[0], 1))
        corr[np.arange(d), np.arange(d)] = 0.0
        # constant features carry no information: drop them outright
        alive = std > 1e-12
        total = corr.sum(axis=1)
        # iteratively remove worst offender of the highest-correlation pair
        while True:
            masked = corr * np.outer(alive, alive)
            i, j = np.unravel_index(np.argmax(masked), masked.shape)
            if masked[i, j] <= self.threshold:
                break
            drop = i if total[i] >= total[j] else j
            alive[drop] = False
        self.keep_ = np.flatnonzero(alive)
        if self.keep_.size == 0:   # degenerate guard: keep at least one feature
            self.keep_ = np.array([0])
        return self

    def transform(self, X: np.ndarray) -> np.ndarray:
        return np.asarray(X, dtype=np.float64)[:, self.keep_]

    def fit_transform(self, X: np.ndarray) -> np.ndarray:
        return self.fit(X).transform(X)

    def get_state(self) -> dict:
        return {"threshold": self.threshold, "keep": self.keep_}

    def set_state(self, s: dict) -> None:
        self.threshold = float(s["threshold"])
        self.keep_ = np.asarray(s["keep"], dtype=np.int64)


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------

class PreprocessPipeline:
    """Yeo-Johnson → standardize → corr-prune, exactly as paper §IV-C."""

    def __init__(self, corr_threshold: float = 0.8,
                 use_yeo_johnson: bool = True) -> None:
        self.use_yeo_johnson = use_yeo_johnson
        self.yj = YeoJohnsonTransformer()
        self.scaler = StandardScaler()
        self.pruner = CorrelationPruner(corr_threshold)

    def fit_transform(self, X: np.ndarray) -> np.ndarray:
        Z = self.yj.fit_transform(X) if self.use_yeo_johnson else np.asarray(
            X, dtype=np.float64)
        Z = self.scaler.fit_transform(Z)
        return self.pruner.fit_transform(Z)

    def transform(self, X: np.ndarray) -> np.ndarray:
        Z = self.yj.transform(X) if self.use_yeo_johnson else np.asarray(
            X, dtype=np.float64)
        Z = self.scaler.transform(Z)
        return self.pruner.transform(Z)

    def fused_params(self) -> tuple:
        """Everything the compiled fast path needs, pre-restricted to the
        columns that survive the correlation prune:
        ``(keep_idx, lambdas_kept | None, mean_kept, scale_kept)``.

        The three stages are elementwise per column, so transforming only
        the kept columns with these sliced parameters is bit-identical to
        ``transform()`` followed by the prune's column selection.
        """
        if self.pruner.keep_ is None or self.scaler.mean_ is None:
            raise ValueError("pipeline not fitted")
        keep = np.asarray(self.pruner.keep_, dtype=np.int64)
        lam = self.yj.lambdas_[keep] if self.use_yeo_johnson else None
        return keep, lam, self.scaler.mean_[keep], self.scaler.scale_[keep]

    def get_state(self) -> dict:
        return {
            "use_yj": self.use_yeo_johnson,
            "yj": self.yj.get_state(),
            "scaler": self.scaler.get_state(),
            "pruner": self.pruner.get_state(),
        }

    def set_state(self, s: dict) -> None:
        self.use_yeo_johnson = bool(s["use_yj"])
        self.yj.set_state(s["yj"])
        self.scaler.set_state(s["scaler"])
        self.pruner.set_state(s["pruner"])
