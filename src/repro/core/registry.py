"""Persisted model store: TunedSubroutine ↔ msgpack files (paper Fig. 1a:
"two files containing the configurations together with the production-ready
ML model will be saved for later use at runtime").

Serialisation is structural (no pickle): numpy arrays are encoded as
``{__nd__: 1, dtype, shape, data}`` msgpack maps, so artifacts are portable
across Python versions and safe to load.  Writes are atomic
(tmp-file + rename) so a preempted install never leaves a torn artifact.
"""

from __future__ import annotations

import os
import tempfile
from pathlib import Path

import msgpack
import numpy as np

from .knobs import KnobSpace
from .ml import make_model
from .preprocess import PreprocessPipeline
from .tuner import TunedSubroutine

__all__ = ["pack_state", "unpack_state", "save_subroutine",
           "load_subroutine", "ModelRegistry"]


def _encode(obj):
    if isinstance(obj, np.ndarray):
        return {"__nd__": 1, "dtype": str(obj.dtype),
                "shape": list(obj.shape),
                "data": obj.tobytes()}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    raise TypeError(f"cannot serialise {type(obj)}")


def _decode(obj):
    if isinstance(obj, dict) and obj.get("__nd__") == 1:
        return np.frombuffer(obj["data"], dtype=obj["dtype"]).reshape(
            obj["shape"]).copy()
    return obj


def pack_state(state: dict) -> bytes:
    return msgpack.packb(state, default=_encode, use_bin_type=True)


def unpack_state(data: bytes) -> dict:
    return msgpack.unpackb(data, object_hook=_decode, raw=False,
                           strict_map_key=False)


def _atomic_write(path: Path, data: bytes) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def save_subroutine(sub: TunedSubroutine, root: str | Path) -> Path:
    path = Path(root) / f"{sub.op}_b{sub.dtype_bytes}.adsala"
    _atomic_write(path, pack_state(sub.get_state()))
    return path


def load_subroutine(path: str | Path) -> TunedSubroutine:
    state = unpack_state(Path(path).read_bytes())
    knobs = KnobSpace(state["knobs"]["name"], state["knobs"]["candidates"])
    # restore grid-parallelism semantics for block knob spaces
    if knobs.name == "blocks":
        from .knobs import _grid_parallelism
        knobs._parallelism_fn = _grid_parallelism
    pipeline = PreprocessPipeline()
    pipeline.set_state(state["pipeline"])
    model = make_model(state["model_name"])
    model.set_state(state["model"])
    return TunedSubroutine(
        op=state["op"], dtype_bytes=int(state["dtype_bytes"]),
        knob_space=knobs, pipeline=pipeline, model=model,
        model_name=state["model_name"], log_target=bool(state["log_target"]))


class ModelRegistry:
    """Directory of installed subroutine artifacts."""

    def __init__(self, root: str | Path) -> None:
        self.root = Path(root)

    def save(self, sub: TunedSubroutine) -> Path:
        return save_subroutine(sub, self.root)

    def load_all(self) -> list[TunedSubroutine]:
        if not self.root.exists():
            return []
        return [load_subroutine(p) for p in sorted(self.root.glob("*.adsala"))]

    def load_into(self, runtime) -> int:
        subs = self.load_all()
        for s in subs:
            runtime.register(s)
        return len(subs)
