"""Persisted model store: TunedSubroutine ↔ msgpack files (paper Fig. 1a:
"two files containing the configurations together with the production-ready
ML model will be saved for later use at runtime").

Serialisation is structural (no pickle): numpy arrays are encoded as
``{__nd__: 1, dtype, shape, data}`` msgpack maps, so artifacts are portable
across Python versions and safe to load.  Writes are atomic
(tmp-file + rename) so a preempted install never leaves a torn artifact.
"""

from __future__ import annotations

import hashlib
import json
import math
import os
import platform
import re
import tempfile
import threading
from pathlib import Path

import msgpack
import numpy as np

from .durable import (DurableStore, JournalFollower, is_durable,
                      read_records, write_snapshot)
from .knobs import KnobSpace
from .ml import make_model
from .preprocess import PreprocessPipeline
from .tuner import SCHEMA_VERSION, TunedSubroutine

__all__ = ["pack_state", "unpack_state", "save_subroutine",
           "load_subroutine", "ModelRegistry", "host_fingerprint",
           "fingerprint_slug", "fingerprint_distance"]

#: backend assumed for v1 artifacts persisted before backend tagging.
#: Legacy stores were *timed* on the cpu_blocked black box but *served* the
#: pallas ops path (the seed's kernels.ops consulted them directly), so
#: "pallas" preserves their dispatch role; recalibrate to retag.
_LEGACY_BACKEND = "pallas"


def _artifact_backend(path: Path) -> str:
    return path.stem.split("__", 1)[0] if "__" in path.stem \
        else _LEGACY_BACKEND


def _encode(obj):
    if isinstance(obj, np.ndarray):
        return {"__nd__": 1, "dtype": str(obj.dtype),
                "shape": list(obj.shape),
                "data": obj.tobytes()}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    raise TypeError(f"cannot serialise {type(obj)}")


def _decode(obj):
    if isinstance(obj, dict) and obj.get("__nd__") == 1:
        return np.frombuffer(obj["data"], dtype=obj["dtype"]).reshape(
            obj["shape"]).copy()
    return obj


def pack_state(state: dict) -> bytes:
    return msgpack.packb(state, default=_encode, use_bin_type=True)


def unpack_state(data: bytes) -> dict:
    return msgpack.unpackb(data, object_hook=_decode, raw=False,
                           strict_map_key=False)


def _atomic_write(path: Path, data: bytes) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def artifact_name(sub: TunedSubroutine) -> str:
    """``{backend}__{op}_b{bytes}.adsala`` (legacy v1 files had no backend
    prefix and load as the ``pallas`` backend)."""
    return f"{sub.backend}__{sub.op}_b{sub.dtype_bytes}.adsala"


def save_subroutine(sub: TunedSubroutine, root: str | Path) -> Path:
    path = Path(root) / artifact_name(sub)
    _atomic_write(path, pack_state(sub.get_state()))
    return path


def load_subroutine(path: str | Path) -> TunedSubroutine:
    state = unpack_state(Path(path).read_bytes())
    version = int(state.get("version", 1))
    if version > SCHEMA_VERSION:
        raise ValueError(
            f"{path}: artifact schema v{version} is newer than this "
            f"library's v{SCHEMA_VERSION}; upgrade the library or "
            f"recalibrate")
    knobs = KnobSpace(state["knobs"]["name"], state["knobs"]["candidates"])
    # restore grid-parallelism semantics for block knob spaces
    if knobs.name == "blocks":
        from .knobs import _grid_parallelism
        knobs._parallelism_fn = _grid_parallelism
    pipeline = PreprocessPipeline()
    pipeline.set_state(state["pipeline"])
    model = make_model(state["model_name"])
    model.set_state(state["model"])
    sub = TunedSubroutine(
        op=state["op"], dtype_bytes=int(state["dtype_bytes"]),
        knob_space=knobs, pipeline=pipeline, model=model,
        model_name=state["model_name"], log_target=bool(state["log_target"]),
        backend=str(state.get("backend", _LEGACY_BACKEND)))
    # optional fast-path dominated-candidate analysis (absent on artifacts
    # installed before the compiled decision engine)
    if "fast_live_idx" in state:
        sub.fast_live_idx = np.asarray(state["fast_live_idx"],
                                       dtype=np.int64)
        sub.fast_dims_lo = np.asarray(state["fast_dims_lo"], dtype=np.int64)
        sub.fast_dims_hi = np.asarray(state["fast_dims_hi"], dtype=np.int64)
    # optional confidence-band live set and opt-in KNN coreset (PR 4)
    if "fast_band_idx" in state:
        sub.fast_band_idx = np.asarray(state["fast_band_idx"],
                                       dtype=np.int64)
        sub.fast_band_pct = float(state["fast_band_pct"])
    if "fast_knn_coreset" in state:
        sub.fast_knn_coreset = np.asarray(state["fast_knn_coreset"],
                                          dtype=np.int64)
    # registry-stamped artifact generation (absent on artifacts persisted
    # before versioning, or never saved through a ModelRegistry → 0)
    sub.artifact_version = int(state.get("artifact_version", 0))
    return sub


# -- architecture fingerprints ------------------------------------------------
#
# The paper's generality claim (Intel/AMD × MKL/BLIS) is operationalised by
# keying artifact sets on a host *fingerprint*: the handful of platform facts
# that dominate which block config wins (CPU model, core count, cache line).
# One registry directory then serves a heterogeneous fleet — each process
# resolves the sub-registry matching its own hardware, with a deterministic
# nearest-fingerprint fallback for hosts nobody calibrated on.

def _read_first(path: str) -> str:
    try:
        with open(path, "r", encoding="utf-8", errors="replace") as f:
            return f.readline().strip()
    except OSError:
        return ""


def _probe_cpu_model() -> str:
    """Human CPU model string: /proc/cpuinfo on Linux, platform fallbacks
    elsewhere.  Empty string when nothing is known."""
    try:
        with open("/proc/cpuinfo", "r", encoding="utf-8",
                  errors="replace") as f:
            for line in f:
                if line.lower().startswith(("model name", "hardware",
                                            "processor\t")):
                    _, _, val = line.partition(":")
                    val = val.strip()
                    if val:
                        return val
    except OSError:
        pass
    return platform.processor() or platform.machine() or ""


def _probe_cache_line() -> int:
    """Coherency line size in bytes (sysfs probe; 64 when unknown — the
    overwhelmingly common value on the paper's platforms)."""
    val = _read_first(
        "/sys/devices/system/cpu/cpu0/cache/index0/coherency_line_size")
    try:
        size = int(val)
    except ValueError:
        size = 0
    return size if size > 0 else 64


def host_fingerprint() -> dict:
    """Architecture fingerprint of *this* host, from cheap platform probes.

    Keys: ``cpu_model`` (string, may be empty), ``machine`` (ISA, e.g.
    ``x86_64``/``aarch64``), ``cores`` (``os.cpu_count()``), ``cache_line``
    (bytes).  Stable across processes on one host; JSON-safe."""
    return {
        "cpu_model": _probe_cpu_model(),
        "machine": platform.machine() or "",
        "cores": int(os.cpu_count() or 1),
        "cache_line": _probe_cache_line(),
    }


def fingerprint_slug(fp: dict) -> str:
    """Deterministic directory-safe slug for a fingerprint: a normalised
    ``{machine}-{cores}c-{cache_line}l-{model hash}`` so two processes on
    identical hardware always resolve the same sub-registry."""
    model = str(fp.get("cpu_model", "")).lower()
    digest = hashlib.sha256(model.encode("utf-8")).hexdigest()[:8]
    machine = re.sub(r"[^a-z0-9]+", "", str(fp.get("machine", "")).lower()) \
        or "unknown"
    return (f"{machine}-{int(fp.get('cores', 0) or 0)}c-"
            f"{int(fp.get('cache_line', 0) or 0)}l-{digest}")


def fingerprint_distance(a: dict, b: dict) -> float:
    """Deterministic dissimilarity score between two fingerprints (0 for an
    exact match).  Weighted so the facts that change which knob wins
    dominate: a different CPU model outweighs everything else, a different
    ISA is next, then |log2| of the core-count ratio (8→16 cores is as far
    as 16→32), then cache-line mismatch as a tie-breaker."""
    score = 0.0
    if str(a.get("cpu_model", "")).lower() != \
            str(b.get("cpu_model", "")).lower():
        score += 100.0
    if str(a.get("machine", "")) != str(b.get("machine", "")):
        score += 50.0
    ca = max(1, int(a.get("cores", 1) or 1))
    cb = max(1, int(b.get("cores", 1) or 1))
    score += abs(math.log2(ca / cb))
    if int(a.get("cache_line", 0) or 0) != int(b.get("cache_line", 0) or 0):
        score += 0.5
    return score


class ModelRegistry:
    """Directory of installed, backend-tagged subroutine artifacts.

    A process hydrates its per-backend model sets at startup with a single
    ``registry.load_into(runtime)`` — every artifact carries its backend tag,
    so one directory can hold the full pallas + cpu_blocked (+ custom) sets.
    """

    #: sidecar mapping artifact filename -> last stamped version.  Kept
    #: separate from the artifacts so the counter survives a delete +
    #: reinstall of a model file — versions never move backwards.
    VERSIONS = "versions.json"

    def __init__(self, root: str | Path, *, faults=None) -> None:
        self.root = Path(root)
        self._version_lock = threading.Lock()
        #: optional repro.serving.faults.FaultPlan (chaos harness)
        self._faults = faults
        #: (path, error) pairs from the most recent :meth:`load_into` —
        #: artifacts that failed to load and were skipped
        self.last_load_errors: list[tuple[str, str]] = []
        #: recovery accounting of the most recent :meth:`load_decision_cache`
        self.last_recovery: dict[str, object] = {}
        #: how the most recent :meth:`resolve_fingerprint` chose its
        #: sub-registry: {"mode": exact|nearest|flat, "slug", "distance"}
        self.last_fingerprint_resolution: dict[str, object] = {}
        self._decision_store: DurableStore | None = None

    @property
    def versions_path(self) -> Path:
        return self.root / self.VERSIONS

    def _read_versions(self) -> dict[str, int]:
        path = self.versions_path
        if not path.exists():
            return {}
        try:
            if is_durable(path):
                # checksummed snapshot: one {"versions": {...}} record; a
                # torn record reads as empty (versions restart at 0 —
                # caches stamped by the lost generations are then merely
                # dropped at warm start, never replayed wrongly)
                out: dict[str, int] = {}
                for rec in read_records(path)[0]:
                    for k, v in rec.get("versions", {}).items():
                        out[str(k)] = max(out.get(str(k), 0), int(v))
                return out
            # legacy plain-JSON sidecar (pre-durable stores)
            return {str(k): int(v)
                    for k, v in json.loads(path.read_text()).items()}
        except (ValueError, OSError):
            return {}

    def artifact_version(self, name: str) -> int:
        """Last version stamped for this artifact filename (0 = never)."""
        return self._read_versions().get(name, 0)

    def save(self, sub: TunedSubroutine) -> Path:
        """Persist one artifact, stamping the next monotonically increasing
        version for its filename onto ``sub.artifact_version`` first.  A
        reinstalled/retuned model therefore never shares a version with its
        predecessor, and decision-cache entries recorded against the old
        generation are rejected at warm start."""
        name = artifact_name(sub)
        with self._version_lock:
            versions = self._read_versions()
            # never move backwards, even if the sub was stamped elsewhere
            versions[name] = max(versions.get(name, 0),
                                 int(getattr(sub, "artifact_version", 0))) + 1
            sub.artifact_version = versions[name]
            write_snapshot(self.versions_path, [{"versions": versions}],
                           faults=self._faults)
        return save_subroutine(sub, self.root)

    def load_all(self, backend: str | None = None) -> list[TunedSubroutine]:
        """Load artifacts, filtering by the filename's backend tag *before*
        unpacking — one backend's bad/newer artifact can't break another's
        load, and startup only unpickles what it asked for."""
        if not self.root.exists():
            return []
        paths = sorted(self.root.glob("*.adsala"))
        if backend is not None:
            paths = [p for p in paths if _artifact_backend(p) == backend]
        return [load_subroutine(p) for p in paths]

    def backends(self) -> tuple[str, ...]:
        """Backend tags present in the store (from filenames; legacy
        unprefixed files are pallas)."""
        if not self.root.exists():
            return ()
        return tuple(sorted({_artifact_backend(p)
                             for p in self.root.glob("*.adsala")}))

    def load_into(self, runtime, backend: str | None = None) -> int:
        """Hydrate ``runtime`` with every (matching) artifact.  Each
        ``register`` compiles the artifact's fast-path predictor up front,
        so a served process pays the fold cost at startup, not on its
        first uncached call.

        Per-artifact fault isolation: one corrupt/unreadable artifact is
        skipped (recorded in :attr:`last_load_errors`) instead of aborting
        the whole hydration — the runtime serves the models that DID load
        and falls back to default knobs for the one that didn't.  Returns
        the number of artifacts registered."""
        self.last_load_errors = []
        if not self.root.exists():
            return 0
        paths = sorted(self.root.glob("*.adsala"))
        if backend is not None:
            paths = [p for p in paths if _artifact_backend(p) == backend]
        n = 0
        for p in paths:
            try:
                if self._faults is not None:
                    self._faults.fire("artifact_load", path=str(p))
                runtime.register(load_subroutine(p))
                n += 1
            except Exception as e:       # noqa: BLE001 — skip, keep loading
                self.last_load_errors.append(
                    (str(p), f"{type(e).__name__}: {e}"))
        return n

    # -- warm-start decision cache -------------------------------------------
    #: filename of the persisted runtime decision cache (beside the models)
    DECISION_CACHE = "decision_cache.json"

    #: decision-cache snapshot schema written by this library (durable
    #: format; v1/v2 legacy plain-JSON payloads still load)
    DECISION_CACHE_VERSION = 3

    @property
    def decision_cache_path(self) -> Path:
        return self.root / self.DECISION_CACHE

    def _cache_store(self) -> DurableStore:
        store = self._decision_store
        if store is None:
            store = self._decision_store = DurableStore(
                self.decision_cache_path, faults=self._faults)
        return store

    def save_decision_cache(self, runtime) -> Path:
        """Persist the runtime's LRU decision cache beside the artifacts so a
        restarted server warm-starts past the cold model evaluations.

        Snapshot v3 is the durable checksummed format (one header record +
        one record per :meth:`~repro.core.runtime.AdsalaRuntime.export_cache`
        entry); a successful snapshot absorbs and truncates the incremental
        decision journal.  Every entry carries the ``artifact_version`` of
        the subroutine that made the decision, so a restart after a
        reinstall or an online retune rejects the stale entries instead of
        replaying the predecessor model's knobs with zero evals and no
        warning."""
        header = {"header": 1, "version": self.DECISION_CACHE_VERSION}
        self._cache_store().snapshot([header] + runtime.export_cache())
        return self.decision_cache_path

    def journal_decision(self, record: dict) -> None:
        """Append one incremental decision/quarantine record (an
        ``export_cache``-shaped dict) to the decision journal — the
        crash-safety increment between snapshots.  Wire this as
        ``runtime.decision_journal`` so every new cached decision survives
        a crash that never reached the next :meth:`save_decision_cache`."""
        self._cache_store().append(record)

    def load_decision_cache(self, runtime) -> int:
        """Warm-start ``runtime`` from a persisted decision cache; returns
        the number of imported decisions (0 when no cache file exists).

        Recovery is corruption-tolerant: torn/corrupt records in the
        snapshot or journal are dropped (counted in :attr:`last_recovery`
        and, for malformed-but-checksummed records, in the runtime's
        ``import_drops_corrupt``) and a fully unreadable legacy payload
        degrades to a cold start — a crashed writer must never stop the
        server from starting.  A *well-formed* snapshot from a NEWER
        library still raises ``ValueError``: that is an operator error
        (downgrade), not corruption.  Journal records are imported after
        the snapshot's, so incremental updates win on key collisions.
        v1 caches (persisted before artifact versioning) load with their
        entries treated as version 0 — they only warm-start version-0
        (never-registry-stamped) subroutines."""
        path = self.decision_cache_path
        self.last_recovery = {"snapshot_records": 0, "journal_records": 0,
                              "dropped_records": 0, "cold_start": False}
        entries: list[dict] = []
        if path.exists():
            if is_durable(path):
                snap, dropped = read_records(path)
                headers = [r for r in snap if r.get("header")]
                if headers and int(headers[0].get("version", 0)) > \
                        self.DECISION_CACHE_VERSION:
                    raise ValueError(
                        f"{path}: decision-cache snapshot "
                        f"v{headers[0]['version']} is newer than this "
                        f"library's v{self.DECISION_CACHE_VERSION}")
                entries = [r for r in snap if not r.get("header")]
                self.last_recovery["dropped_records"] += dropped
            else:
                try:
                    payload = json.loads(path.read_text())
                except ValueError:
                    # torn legacy write / garbage file: cold start, never
                    # propagate — warm start is an optimisation
                    payload = None
                if isinstance(payload, dict):
                    if int(payload.get("version", 1)) not in (1, 2):
                        raise ValueError(
                            f"{path}: unknown decision-cache version "
                            f"{payload.get('version')!r}")
                    entries = [e for e in payload.get("entries") or []
                               if isinstance(e, dict)]
                else:
                    self.last_recovery["cold_start"] = True
                    self.last_recovery["dropped_records"] += 1
        self.last_recovery["snapshot_records"] = len(entries)
        journal, j_dropped = read_records(self._cache_store().journal_path)
        self.last_recovery["journal_records"] = len(journal)
        self.last_recovery["dropped_records"] += j_dropped
        entries.extend(journal)
        if not entries:
            return 0
        return runtime.import_cache(entries)

    def journal_follower(self) -> JournalFollower:
        """Incremental reader over this registry's decision journal — the
        fleet-coherence poll: every serving process tails the shared
        journal and absorbs the decisions/quarantines its peers append."""
        return self._cache_store().follower()

    # -- fingerprint-keyed sub-registries ------------------------------------
    #: subdirectory holding one sub-registry per architecture fingerprint
    ARCH_DIR = "arch"

    #: sidecar inside each sub-registry recording the fingerprint it was
    #: calibrated for (written by :meth:`for_fingerprint`)
    FINGERPRINT = "fingerprint.json"

    def for_fingerprint(self, fp: dict | None = None, *,
                        create: bool = False) -> "ModelRegistry":
        """The sub-registry keyed by ``fp`` (default: this host's probe).

        With ``create=True`` the directory and its ``fingerprint.json``
        sidecar are written — this is how a calibration/install job claims
        the slot for the architecture it ran on.  The returned registry is
        a full :class:`ModelRegistry` (own artifacts, versions sidecar,
        decision cache + shared journal)."""
        fp = dict(fp or host_fingerprint())
        sub = ModelRegistry(self.root / self.ARCH_DIR / fingerprint_slug(fp),
                            faults=self._faults)
        if create:
            write_snapshot(sub.root / self.FINGERPRINT,
                           [{"fingerprint": fp}], faults=self._faults)
        return sub

    def fingerprints(self) -> list[tuple[str, dict]]:
        """Every calibrated ``(slug, fingerprint)`` under ``arch/``, sorted
        by slug.  Sub-registries with a missing/corrupt sidecar are skipped
        (they cannot be matched, so they cannot be served)."""
        arch = self.root / self.ARCH_DIR
        if not arch.is_dir():
            return []
        out: list[tuple[str, dict]] = []
        for child in sorted(arch.iterdir()):
            sidecar = child / self.FINGERPRINT
            if not child.is_dir() or not sidecar.exists():
                continue
            for rec in read_records(sidecar)[0]:
                fp = rec.get("fingerprint")
                if isinstance(fp, dict):
                    out.append((child.name, fp))
                    break
        return out

    def resolve_fingerprint(self, fp: dict | None = None) -> "ModelRegistry":
        """The sub-registry a serving process on host ``fp`` should load.

        Resolution order (recorded in :attr:`last_fingerprint_resolution`):

        1. **exact** — a calibrated sub-registry whose slug matches ``fp``;
        2. **nearest** — the calibrated sub-registry minimising
           :func:`fingerprint_distance` (ties broken by slug) — an unseen
           host borrows the closest architecture's models rather than
           starting knob-blind;
        3. **flat** — no ``arch/`` entries at all: the registry root
           itself (the single-architecture layout every prior PR used).
        """
        fp = dict(fp or host_fingerprint())
        slug = fingerprint_slug(fp)
        known = self.fingerprints()
        for cand_slug, _cand_fp in known:
            if cand_slug == slug:
                self.last_fingerprint_resolution = {
                    "mode": "exact", "slug": slug, "distance": 0.0}
                return ModelRegistry(self.root / self.ARCH_DIR / slug,
                                     faults=self._faults)
        if known:
            best_slug, _best_fp, best_d = min(
                ((s, f, fingerprint_distance(fp, f)) for s, f in known),
                key=lambda t: (t[2], t[0]))
            self.last_fingerprint_resolution = {
                "mode": "nearest", "slug": best_slug, "distance": best_d}
            return ModelRegistry(self.root / self.ARCH_DIR / best_slug,
                                 faults=self._faults)
        self.last_fingerprint_resolution = {
            "mode": "flat", "slug": "", "distance": 0.0}
        return self
