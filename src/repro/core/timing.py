"""Wall-clock timing harness (the paper's install-time "timing program").

Times a zero-argument callable with warmup + best-of-k repeats.  JAX arrays
are synchronised via ``block_until_ready`` (the callable is responsible for
returning its output so we can block on it).
"""

from __future__ import annotations

import time
from typing import Callable

import numpy as np

__all__ = ["time_callable", "median_time"]


def _block(x) -> None:
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass


def time_callable(fn: Callable[[], object], *, warmup: int = 1,
                  repeats: int = 3, min_time_s: float = 0.0) -> float:
    """Median wall-clock seconds of ``fn`` over ``repeats`` runs."""
    for _ in range(warmup):
        _block(fn())
    times = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        _block(fn())
        dt = time.perf_counter() - t0
        times.append(dt)
        if min_time_s and sum(times) > min_time_s and len(times) >= 2:
            break
    return float(np.median(times))


def median_time(fn: Callable[[], object], **kw) -> float:
    return time_callable(fn, **kw)
