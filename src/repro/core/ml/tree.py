"""Array-based CART regression tree (variance-reduction splits).

The tree is stored as flat numpy arrays (feature, threshold, left, right,
value) so that (a) predict is a vectorised iterative descent, (b) the model
serialises to plain arrays for the registry, and (c) ensembles stay compact.

Split search is exact: per feature, sort once, scan prefix sums of y and y²
to evaluate the variance reduction of every split point — O(d · n log n) per
node, vectorised over split positions.
"""

from __future__ import annotations

import numpy as np

from .base import Estimator, register

__all__ = ["DecisionTree", "ArrayTree"]

_LEAF = -1


class ArrayTree:
    """Flat-array binary regression tree."""

    def __init__(self) -> None:
        self.feature: np.ndarray = np.zeros(0, dtype=np.int32)
        self.threshold: np.ndarray = np.zeros(0, dtype=np.float64)
        self.left: np.ndarray = np.zeros(0, dtype=np.int32)
        self.right: np.ndarray = np.zeros(0, dtype=np.int32)
        self.value: np.ndarray = np.zeros(0, dtype=np.float64)
        self.depth: int = 0

    # -- construction -------------------------------------------------------
    def build(self, X: np.ndarray, y: np.ndarray, sample_weight: np.ndarray,
              *, max_depth: int, min_samples_leaf: int,
              max_features: int | None, rng: np.random.Generator,
              min_impurity_decrease: float = 0.0) -> "ArrayTree":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        w = np.asarray(sample_weight, dtype=np.float64)

        feat, thr, left, right, val = [], [], [], [], []

        def new_node() -> int:
            feat.append(_LEAF)
            thr.append(0.0)
            left.append(_LEAF)
            right.append(_LEAF)
            val.append(0.0)
            return len(feat) - 1

        max_seen_depth = 0

        def grow(idx: np.ndarray, depth: int) -> int:
            nonlocal max_seen_depth
            max_seen_depth = max(max_seen_depth, depth)
            node = new_node()
            yi, wi = y[idx], w[idx]
            wsum = wi.sum()
            mean = float((wi * yi).sum() / max(wsum, 1e-300))
            val[node] = mean
            if depth >= max_depth or idx.size < 2 * min_samples_leaf:
                return node
            best = _best_split(X[idx], yi, wi, min_samples_leaf,
                               max_features, rng)
            if best is None or best[2] <= min_impurity_decrease:
                return node
            j, t, _gain = best
            mask = X[idx, j] <= t
            li, ri = idx[mask], idx[~mask]
            if li.size < min_samples_leaf or ri.size < min_samples_leaf:
                return node
            feat[node] = j
            thr[node] = t
            left[node] = grow(li, depth + 1)
            right[node] = grow(ri, depth + 1)
            return node

        grow(np.arange(X.shape[0]), 0)
        self.feature = np.asarray(feat, dtype=np.int32)
        self.threshold = np.asarray(thr, dtype=np.float64)
        self.left = np.asarray(left, dtype=np.int32)
        self.right = np.asarray(right, dtype=np.int32)
        self.value = np.asarray(val, dtype=np.float64)
        self.depth = max_seen_depth
        return self

    # -- inference ----------------------------------------------------------
    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        node = np.zeros(X.shape[0], dtype=np.int32)
        for _ in range(self.depth + 1):
            f = self.feature[node]
            is_split = f != _LEAF
            if not is_split.any():
                break
            fx = X[np.arange(X.shape[0]), np.maximum(f, 0)]
            go_left = fx <= self.threshold[node]
            nxt = np.where(go_left, self.left[node], self.right[node])
            node = np.where(is_split, nxt, node)
        return self.value[node]

    # -- predicated export ----------------------------------------------------
    def predicated_arrays(self) -> tuple:
        """``(feature, threshold, left, right)`` with leaves rewritten as
        self-loops: a leaf keeps feature 0, threshold ``+inf`` (every
        ``x <= +inf`` comparison goes left) and both children pointing back
        at itself.  Descending this layout a *fixed* number of levels is
        branchless — no per-level "all rows done?" check — and lands on the
        same node as the reference early-exit descent, because a finished
        row just spins on its leaf.  Comparisons and index lookups only, so
        any descent over these arrays is bit-identical to :meth:`predict`.
        """
        leaf = self.feature < 0
        nodes = np.arange(self.feature.size, dtype=np.int64)
        feat = np.where(leaf, 0, self.feature).astype(np.int64)
        thr = np.where(leaf, np.inf, self.threshold)
        left = np.where(leaf, nodes, self.left.astype(np.int64))
        right = np.where(leaf, nodes, self.right.astype(np.int64))
        return feat, thr, left, right

    # -- persistence ----------------------------------------------------------
    def get_state(self) -> dict:
        return {"feature": self.feature, "threshold": self.threshold,
                "left": self.left, "right": self.right, "value": self.value,
                "depth": self.depth}

    def set_state(self, s: dict) -> None:
        self.feature = np.asarray(s["feature"], dtype=np.int32)
        self.threshold = np.asarray(s["threshold"], dtype=np.float64)
        self.left = np.asarray(s["left"], dtype=np.int32)
        self.right = np.asarray(s["right"], dtype=np.int32)
        self.value = np.asarray(s["value"], dtype=np.float64)
        self.depth = int(s["depth"])


def _best_split(X: np.ndarray, y: np.ndarray, w: np.ndarray,
                min_samples_leaf: int, max_features: int | None,
                rng: np.random.Generator):
    """Exact best (feature, threshold, gain) by weighted variance reduction."""
    n, d = X.shape
    feats = np.arange(d)
    if max_features is not None and max_features < d:
        feats = rng.choice(d, size=max_features, replace=False)
    wy = w * y
    tot_w = w.sum()
    tot_wy = wy.sum()
    tot_wyy = (w * y * y).sum()
    base_sse = tot_wyy - tot_wy ** 2 / max(tot_w, 1e-300)
    best = None
    best_gain = 0.0
    for j in feats:
        order = np.argsort(X[:, j], kind="stable")
        xs = X[order, j]
        cw = np.cumsum(w[order])
        cwy = np.cumsum(wy[order])
        cwyy = np.cumsum((w * y * y)[order])
        # candidate split after position i (left = [0..i])
        i = np.arange(n - 1)
        valid = (xs[i] < xs[i + 1])
        if min_samples_leaf > 1:
            valid &= (i + 1 >= min_samples_leaf) & \
                     (n - (i + 1) >= min_samples_leaf)
        if not valid.any():
            continue
        lw, lwy, lwyy = cw[i], cwy[i], cwyy[i]
        rw, rwy, rwyy = tot_w - lw, tot_wy - lwy, tot_wyy - lwyy
        sse = (lwyy - lwy ** 2 / np.maximum(lw, 1e-300)) + \
              (rwyy - rwy ** 2 / np.maximum(rw, 1e-300))
        sse = np.where(valid, sse, np.inf)
        k = int(np.argmin(sse))
        gain = base_sse - sse[k]
        if gain > best_gain:
            best_gain = float(gain)
            best = (int(j), float((xs[k] + xs[k + 1]) / 2.0), float(gain))
    return best


@register
class DecisionTree(Estimator):
    NAME = "DecisionTree"
    PARAM_GRID = {"max_depth": [4, 6, 8, 12],
                  "min_samples_leaf": [1, 2, 5]}

    def __init__(self, max_depth: int = 8, min_samples_leaf: int = 2,
                 max_features: int | None = None, seed: int = 0) -> None:
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.seed = seed
        self.tree_ = ArrayTree()

    @property
    def trees_(self) -> tuple:
        """Uniform tree-model interface (ensembles expose ``trees_`` too):
        the compiled decision engine lowers every tree family through one
        table-driven representation."""
        return (self.tree_,)

    def fit(self, X, y):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        rng = np.random.default_rng(self.seed)
        self.tree_.build(X, y, np.ones(len(y)), max_depth=self.max_depth,
                         min_samples_leaf=self.min_samples_leaf,
                         max_features=self.max_features, rng=rng)
        return self

    def predict(self, X):
        return self.tree_.predict(X)

    def get_state(self):
        return {"tree": self.tree_.get_state(),
                "max_depth": self.max_depth,
                "min_samples_leaf": self.min_samples_leaf}

    def set_state(self, s):
        self.tree_.set_state(s["tree"])
        self.max_depth = int(s["max_depth"])
        self.min_samples_leaf = int(s["min_samples_leaf"])
