"""k-Nearest-Neighbours regressor (brute-force, distance-weighted option).

Neighbour selection is *canonical*: the k nearest points ordered by
``(distance², original index)``.  ``np.argpartition`` (the usual brute-force
shortcut) breaks distance ties in an unspecified per-call order, which makes
the prediction's low-order bits depend on the partition algorithm — an
alternative exact implementation (the compiled KD/ball lookup in
:mod:`repro.core.fastpath`) could then never reproduce it bit-for-bit.  A
stable argsort pins both the neighbour *set* and the summation *order*, so
any implementation that selects the same canonical neighbours computes the
identical float result.
"""

from __future__ import annotations

import numpy as np

from .base import Estimator, register

__all__ = ["KNN"]


@register
class KNN(Estimator):
    NAME = "KNN"
    PARAM_GRID = {"k": [3, 5, 9, 15], "weights": ["uniform", "distance"]}

    def __init__(self, k: int = 5, weights: str = "uniform") -> None:
        self.k = k
        self.weights = weights
        self.X_: np.ndarray | None = None
        self.y_: np.ndarray | None = None

    def fit(self, X, y):
        # C-contiguous training points for the same reason as predict's
        # query canonicalisation: an F-ordered training matrix (the
        # preprocess pipeline's natural output layout) would flip the
        # broadcast distance reduction to a strided, differently-associated
        # summation
        self.X_ = np.ascontiguousarray(np.asarray(X, dtype=np.float64))
        self.y_ = np.asarray(y, dtype=np.float64)
        return self

    def predict(self, X):
        # C-contiguous queries pin the distance reduction's association
        # order regardless of the caller's buffer layout — any exact
        # alternative implementation then reproduces the same bits from
        # gathered candidate subsets
        X = np.ascontiguousarray(np.asarray(X, dtype=np.float64))
        k = min(self.k, self.X_.shape[0])
        # (q, n) squared distances
        d2 = ((X[:, None, :] - self.X_[None, :, :]) ** 2).sum(-1)
        # canonical neighbours: k smallest by (d2, index) — stable sort ties
        nn = np.argsort(d2, axis=1, kind="stable")[:, :k]
        ny = self.y_[nn]
        nd = np.sqrt(np.take_along_axis(d2, nn, axis=1)) \
            if self.weights == "distance" else None
        return self._combine(ny, nd)

    def _combine(self, ny: np.ndarray, nd: np.ndarray | None) -> np.ndarray:
        """Fold the ``(q, k)`` neighbour targets (and distances, for the
        ``distance`` weighting) into predictions.  Shared with the compiled
        fast path so both combine canonical neighbours with the exact same
        ufunc sequence (bit-identical results)."""
        if self.weights == "distance":
            w = 1.0 / np.maximum(nd, 1e-12)
            return (w * ny).sum(1) / w.sum(1)
        return ny.mean(1)

    def get_state(self):
        return {"X": self.X_, "y": self.y_, "k": self.k,
                "weights": self.weights}

    def set_state(self, s):
        self.X_ = np.ascontiguousarray(np.asarray(s["X"], dtype=np.float64))
        self.y_ = np.asarray(s["y"], dtype=np.float64)
        self.k = int(s["k"])
        self.weights = str(s["weights"])
