"""k-Nearest-Neighbours regressor (brute-force, distance-weighted option)."""

from __future__ import annotations

import numpy as np

from .base import Estimator, register

__all__ = ["KNN"]


@register
class KNN(Estimator):
    NAME = "KNN"
    PARAM_GRID = {"k": [3, 5, 9, 15], "weights": ["uniform", "distance"]}

    def __init__(self, k: int = 5, weights: str = "uniform") -> None:
        self.k = k
        self.weights = weights
        self.X_: np.ndarray | None = None
        self.y_: np.ndarray | None = None

    def fit(self, X, y):
        self.X_ = np.asarray(X, dtype=np.float64)
        self.y_ = np.asarray(y, dtype=np.float64)
        return self

    def predict(self, X):
        X = np.asarray(X, dtype=np.float64)
        k = min(self.k, self.X_.shape[0])
        # (q, n) squared distances
        d2 = ((X[:, None, :] - self.X_[None, :, :]) ** 2).sum(-1)
        nn = np.argpartition(d2, k - 1, axis=1)[:, :k]
        ny = self.y_[nn]
        if self.weights == "distance":
            nd = np.sqrt(np.take_along_axis(d2, nn, axis=1))
            w = 1.0 / np.maximum(nd, 1e-12)
            return (w * ny).sum(1) / w.sum(1)
        return ny.mean(1)

    def get_state(self):
        return {"X": self.X_, "y": self.y_, "k": self.k,
                "weights": self.weights}

    def set_state(self, s):
        self.X_ = np.asarray(s["X"], dtype=np.float64)
        self.y_ = np.asarray(s["y"], dtype=np.float64)
        self.k = int(s["k"])
        self.weights = str(s["weights"])
