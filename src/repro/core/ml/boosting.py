"""XGBoost-style gradient-boosted trees (second-order, L2 leaf shrinkage).

For squared loss the Hessian is 1, so the XGBoost leaf weight
``w* = -G/(H + λ)`` reduces to ``sum(residual)/(n_leaf + λ)`` — standard GBT
with an L2-regularised leaf value plus learning-rate shrinkage, subsampling
and early stopping on a holdout.  This is the paper's ``XGBRegressor``
candidate implemented numpy-only.
"""

from __future__ import annotations

import numpy as np

from .base import Estimator, register
from .tree import ArrayTree

__all__ = ["XGBoost"]


@register
class XGBoost(Estimator):
    NAME = "XGBoost"
    PARAM_GRID = {"n_estimators": [100, 200], "max_depth": [3, 4, 6],
                  "learning_rate": [0.05, 0.1, 0.2],
                  "reg_lambda": [0.0, 1.0]}

    def __init__(self, n_estimators: int = 200, max_depth: int = 4,
                 learning_rate: float = 0.1, reg_lambda: float = 1.0,
                 subsample: float = 0.9, early_stopping_rounds: int = 25,
                 seed: int = 0) -> None:
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.learning_rate = learning_rate
        self.reg_lambda = reg_lambda
        self.subsample = subsample
        self.early_stopping_rounds = early_stopping_rounds
        self.seed = seed
        self.base_: float = 0.0
        self.trees_: list[ArrayTree] = []

    def _shrink_leaves(self, tree: ArrayTree, X, residual, reg_lambda):
        """Recompute leaf values with L2 shrinkage: sum(res)/(count+λ)."""
        leaf_of = self._leaf_index(tree, X)
        nleaf = tree.value.shape[0]
        sums = np.bincount(leaf_of, weights=residual, minlength=nleaf)
        cnts = np.bincount(leaf_of, minlength=nleaf).astype(np.float64)
        is_leaf = tree.feature == -1
        new_val = np.where(cnts > 0,
                           sums / np.maximum(cnts + reg_lambda, 1e-12),
                           tree.value)
        tree.value = np.where(is_leaf, new_val, tree.value)

    @staticmethod
    def _leaf_index(tree: ArrayTree, X: np.ndarray) -> np.ndarray:
        node = np.zeros(X.shape[0], dtype=np.int32)
        for _ in range(tree.depth + 1):
            f = tree.feature[node]
            is_split = f != -1
            if not is_split.any():
                break
            fx = X[np.arange(X.shape[0]), np.maximum(f, 0)]
            nxt = np.where(fx <= tree.threshold[node],
                           tree.left[node], tree.right[node])
            node = np.where(is_split, nxt, node)
        return node

    def fit(self, X, y):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n = X.shape[0]
        rng = np.random.default_rng(self.seed)
        # holdout for early stopping
        perm = rng.permutation(n)
        n_val = max(1, int(0.15 * n)) if n >= 20 else 0
        val_idx, tr_idx = perm[:n_val], perm[n_val:]
        Xt, yt = X[tr_idx], y[tr_idx]
        Xv, yv = X[val_idx], y[val_idx]

        self.base_ = float(y.mean())
        pred_t = np.full(len(yt), self.base_)
        pred_v = np.full(len(yv), self.base_)
        self.trees_ = []
        best_val = np.inf
        best_len = 0
        for _ in range(self.n_estimators):
            residual = yt - pred_t
            if self.subsample < 1.0:
                m = rng.random(len(yt)) < self.subsample
                if m.sum() < 8:
                    m[:] = True
            else:
                m = np.ones(len(yt), dtype=bool)
            t = ArrayTree().build(Xt[m], residual[m], np.ones(int(m.sum())),
                                  max_depth=self.max_depth,
                                  min_samples_leaf=2, max_features=None,
                                  rng=rng)
            self._shrink_leaves(t, Xt[m], residual[m], self.reg_lambda)
            pred_t += self.learning_rate * t.predict(Xt)
            self.trees_.append(t)
            if n_val:
                pred_v += self.learning_rate * t.predict(Xv)
                val_rmse = float(np.sqrt(np.mean((yv - pred_v) ** 2)))
                if val_rmse < best_val - 1e-12:
                    best_val = val_rmse
                    best_len = len(self.trees_)
                elif len(self.trees_) - best_len >= self.early_stopping_rounds:
                    break
        if n_val and best_len:
            self.trees_ = self.trees_[:best_len]
        return self

    def predict(self, X):
        X = np.asarray(X, dtype=np.float64)
        out = np.full(X.shape[0], self.base_)
        for t in self.trees_:
            out += self.learning_rate * t.predict(X)
        return out

    def get_state(self):
        return {"trees": [t.get_state() for t in self.trees_],
                "base": self.base_, "lr": self.learning_rate,
                "params": self.get_params()}

    def set_state(self, s):
        self.set_params(**{k: v for k, v in s["params"].items()})
        self.base_ = float(s["base"])
        self.learning_rate = float(s["lr"])
        self.trees_ = []
        for ts in s["trees"]:
            t = ArrayTree()
            t.set_state(ts)
            self.trees_.append(t)
