"""Hyper-parameter tuning: seeded random search over PARAM_GRID with K-fold
CV (paper §IV-C: "the hyperparameter tuning is performed for all models")."""

from __future__ import annotations

import itertools

import numpy as np

from .base import Estimator
from .metrics import cross_val_rmse

__all__ = ["tune_model"]


def tune_model(model: Estimator, X: np.ndarray, y: np.ndarray, *,
               n_trials: int = 8, cv: int = 3, seed: int = 0) -> Estimator:
    """Return a freshly-fitted model with the best CV hyper-parameters."""
    grid = model.PARAM_GRID
    if not grid:
        return model.clone().fit(X, y)
    keys = sorted(grid)
    combos = list(itertools.product(*[grid[k] for k in keys]))
    rng = np.random.default_rng(seed)
    if len(combos) > n_trials:
        picks = rng.choice(len(combos), size=n_trials, replace=False)
        combos = [combos[i] for i in picks]
    best_params, best_err = None, np.inf
    for combo in combos:
        params = dict(zip(keys, combo))
        cand = model.clone().set_params(**params)
        err = cross_val_rmse(cand, X, y, k=cv, seed=seed)
        if err < best_err:
            best_err, best_params = err, params
    out = model.clone().set_params(**(best_params or {}))
    out.fit(X, y)
    return out
