"""Regression metrics + K-fold cross-validation."""

from __future__ import annotations

import numpy as np

__all__ = ["rmse", "normalized_rmse", "r2", "kfold_indices", "cross_val_rmse"]


def rmse(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    return float(np.sqrt(np.mean((np.asarray(y_true) - np.asarray(y_pred)) ** 2)))


def normalized_rmse(y_true: np.ndarray, y_pred: np.ndarray,
                    baseline_rmse: float | None = None) -> float:
    """RMSE normalised as in paper Table VI (relative to the worst linear
    model's RMSE when ``baseline_rmse`` given, else to the std of y)."""
    e = rmse(y_true, y_pred)
    denom = baseline_rmse if baseline_rmse else float(np.std(y_true)) or 1.0
    return e / max(denom, 1e-300)


def r2(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    y_true = np.asarray(y_true)
    ss_res = float(np.sum((y_true - y_pred) ** 2))
    ss_tot = float(np.sum((y_true - y_true.mean()) ** 2))
    return 1.0 - ss_res / max(ss_tot, 1e-300)


def kfold_indices(n: int, k: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    folds = np.array_split(perm, k)
    for i in range(k):
        val = folds[i]
        train = np.concatenate([folds[j] for j in range(k) if j != i])
        yield train, val


def cross_val_rmse(model, X: np.ndarray, y: np.ndarray, k: int = 3,
                   seed: int = 0) -> float:
    errs = []
    for tr, va in kfold_indices(len(y), k, seed):
        m = model.clone()
        m.fit(X[tr], y[tr])
        errs.append(rmse(y[va], m.predict(X[va])))
    return float(np.mean(errs))
