"""Tree ensembles: RandomForest (bagging) and AdaBoost.R2."""

from __future__ import annotations

import numpy as np

from .base import Estimator, register
from .tree import ArrayTree

__all__ = ["RandomForest", "AdaBoost"]


@register
class RandomForest(Estimator):
    NAME = "RandomForest"
    PARAM_GRID = {"n_estimators": [50, 100], "max_depth": [8, 12, 16],
                  "max_features_frac": [0.5, 0.8, 1.0]}

    def __init__(self, n_estimators: int = 100, max_depth: int = 12,
                 min_samples_leaf: int = 1, max_features_frac: float = 0.8,
                 seed: int = 0) -> None:
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.max_features_frac = max_features_frac
        self.seed = seed
        self.trees_: list[ArrayTree] = []

    def fit(self, X, y):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n, d = X.shape
        rng = np.random.default_rng(self.seed)
        mf = max(1, int(round(self.max_features_frac * d)))
        self.trees_ = []
        for _ in range(self.n_estimators):
            idx = rng.integers(0, n, size=n)          # bootstrap
            t = ArrayTree().build(X[idx], y[idx], np.ones(n),
                                  max_depth=self.max_depth,
                                  min_samples_leaf=self.min_samples_leaf,
                                  max_features=mf, rng=rng)
            self.trees_.append(t)
        return self

    def predict(self, X):
        X = np.asarray(X, dtype=np.float64)
        return np.mean([t.predict(X) for t in self.trees_], axis=0)

    def get_state(self):
        return {"trees": [t.get_state() for t in self.trees_],
                "params": self.get_params()}

    def set_state(self, s):
        self.set_params(**{k: v for k, v in s["params"].items()})
        self.trees_ = []
        for ts in s["trees"]:
            t = ArrayTree()
            t.set_state(ts)
            self.trees_.append(t)


@register
class AdaBoost(Estimator):
    """AdaBoost.R2 (Drucker 1997) with shallow regression-tree learners."""
    NAME = "AdaBoost"
    PARAM_GRID = {"n_estimators": [50, 100], "max_depth": [3, 4, 6]}

    def __init__(self, n_estimators: int = 50, max_depth: int = 4,
                 seed: int = 0) -> None:
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.seed = seed
        self.trees_: list[ArrayTree] = []
        self.betas_: np.ndarray = np.zeros(0)

    def fit(self, X, y):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n = X.shape[0]
        rng = np.random.default_rng(self.seed)
        w = np.full(n, 1.0 / n)
        self.trees_, betas = [], []
        for _ in range(self.n_estimators):
            idx = rng.choice(n, size=n, p=w / w.sum())
            t = ArrayTree().build(X[idx], y[idx], np.ones(n),
                                  max_depth=self.max_depth,
                                  min_samples_leaf=1, max_features=None,
                                  rng=rng)
            pred = t.predict(X)
            err = np.abs(pred - y)
            emax = err.max()
            if emax <= 1e-300:
                self.trees_.append(t)
                betas.append(1e-6)
                break
            L = err / emax                       # linear loss
            ebar = float((w * L).sum() / w.sum())
            if ebar >= 0.5:
                break
            beta = ebar / (1.0 - ebar)
            w = w * np.power(beta, 1.0 - L)
            self.trees_.append(t)
            betas.append(beta)
        if not self.trees_:                      # fallback: single tree
            t = ArrayTree().build(X, y, np.ones(n), max_depth=self.max_depth,
                                  min_samples_leaf=1, max_features=None,
                                  rng=rng)
            self.trees_, betas = [t], [0.5]
        self.betas_ = np.asarray(betas)
        return self

    def predict(self, X):
        """Weighted-median prediction (AdaBoost.R2 combination rule)."""
        X = np.asarray(X, dtype=np.float64)
        preds = np.stack([t.predict(X) for t in self.trees_], axis=1)  # (n,T)
        logw = np.log(1.0 / np.maximum(self.betas_, 1e-300))
        order = np.argsort(preds, axis=1)
        sorted_preds = np.take_along_axis(preds, order, axis=1)
        cum = np.cumsum(logw[order], axis=1)
        half = 0.5 * logw.sum()
        pick = (cum >= half).argmax(axis=1)
        return sorted_preds[np.arange(X.shape[0]), pick]

    def get_state(self):
        return {"trees": [t.get_state() for t in self.trees_],
                "betas": self.betas_, "params": self.get_params()}

    def set_state(self, s):
        self.set_params(**{k: v for k, v in s["params"].items()})
        self.betas_ = np.asarray(s["betas"], dtype=np.float64)
        self.trees_ = []
        for ts in s["trees"]:
            t = ArrayTree()
            t.set_state(ts)
            self.trees_.append(t)
