"""Base estimator interface for the from-scratch ML library.

Every model implements::

    fit(X, y) -> self
    predict(X) -> (n,) float64
    get_params() / set_params(**p)           # hyper-parameter tuning
    get_state() / set_state(state)           # persistence (plain dict of
                                             # numpy arrays / scalars / lists)

plus a class-level ``PARAM_GRID`` used by ``core.ml.tuning`` for random
search.  Registry lookup is by ``NAME``.
"""

from __future__ import annotations

import numpy as np

__all__ = ["Estimator", "MODEL_REGISTRY", "register", "make_model"]

MODEL_REGISTRY: dict[str, type] = {}


def register(cls):
    MODEL_REGISTRY[cls.NAME] = cls
    return cls


def make_model(name: str, **params) -> "Estimator":
    return MODEL_REGISTRY[name](**params)


class Estimator:
    NAME = "base"
    PARAM_GRID: dict[str, list] = {}

    def fit(self, X: np.ndarray, y: np.ndarray) -> "Estimator":
        raise NotImplementedError

    def predict(self, X: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    # -- hyper-parameters --------------------------------------------------
    def get_params(self) -> dict:
        return {k: getattr(self, k) for k in self.PARAM_GRID}

    def set_params(self, **params) -> "Estimator":
        for k, v in params.items():
            setattr(self, k, v)
        return self

    # -- persistence -------------------------------------------------------
    def get_state(self) -> dict:
        raise NotImplementedError

    def set_state(self, state: dict) -> None:
        raise NotImplementedError

    def clone(self) -> "Estimator":
        return type(self)(**self.get_params())
