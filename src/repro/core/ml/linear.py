"""Linear model family: OLS, Ridge, ElasticNet (coordinate descent),
Bayesian ridge (evidence maximization).  numpy-only.
"""

from __future__ import annotations

import numpy as np

from .base import Estimator, register

__all__ = ["LinearRegression", "Ridge", "ElasticNet", "BayesianRidge"]


def _add_bias(X: np.ndarray) -> np.ndarray:
    return np.concatenate([X, np.ones((X.shape[0], 1))], axis=1)


def _matvec(X: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Deterministic row-wise X @ w.

    BLAS gemv processes rows in blocks whose FMA arrangement depends on row
    position and buffer alignment, so bit-identical rows can yield
    different low bits — which breaks tie-stability of the runtime's argmin
    knob decision (equal-feature candidates must predict equal times).
    einsum's fixed reduction order is alignment- and row-position-stable,
    and normalising to one memory layout makes the result a function of the
    VALUES alone: the same row predicts the same bits no matter which
    buffer (reference pipeline, fast-path single, fast-path batch) it
    arrived in.
    """
    return np.einsum("ij,j->i", np.ascontiguousarray(X), w)


@register
class LinearRegression(Estimator):
    NAME = "LinearRegression"
    PARAM_GRID: dict[str, list] = {}

    def __init__(self) -> None:
        self.coef_: np.ndarray | None = None

    def fit(self, X, y):
        Xb = _add_bias(np.asarray(X, dtype=np.float64))
        self.coef_, *_ = np.linalg.lstsq(Xb, np.asarray(y, dtype=np.float64),
                                         rcond=None)
        return self

    def predict(self, X):
        return _matvec(_add_bias(np.asarray(X, dtype=np.float64)), self.coef_)

    def get_state(self):
        return {"coef": self.coef_}

    def set_state(self, s):
        self.coef_ = np.asarray(s["coef"], dtype=np.float64)


@register
class Ridge(Estimator):
    NAME = "Ridge"
    PARAM_GRID = {"alpha": [0.01, 0.1, 1.0, 10.0]}

    def __init__(self, alpha: float = 1.0) -> None:
        self.alpha = alpha
        self.coef_: np.ndarray | None = None

    def fit(self, X, y):
        Xb = _add_bias(np.asarray(X, dtype=np.float64))
        d = Xb.shape[1]
        reg = self.alpha * np.eye(d)
        reg[-1, -1] = 0.0  # don't penalise the bias
        self.coef_ = np.linalg.solve(Xb.T @ Xb + reg, Xb.T @ np.asarray(y))
        return self

    def predict(self, X):
        return _matvec(_add_bias(np.asarray(X, dtype=np.float64)), self.coef_)

    def get_state(self):
        return {"coef": self.coef_, "alpha": self.alpha}

    def set_state(self, s):
        self.coef_ = np.asarray(s["coef"], dtype=np.float64)
        self.alpha = float(s["alpha"])


@register
class ElasticNet(Estimator):
    """ElasticNet via cyclic coordinate descent on centred data."""
    NAME = "ElasticNet"
    PARAM_GRID = {"alpha": [1e-4, 1e-3, 1e-2, 1e-1],
                  "l1_ratio": [0.2, 0.5, 0.8]}

    def __init__(self, alpha: float = 1e-3, l1_ratio: float = 0.5,
                 max_iter: int = 300, tol: float = 1e-8) -> None:
        self.alpha = alpha
        self.l1_ratio = l1_ratio
        self.max_iter = max_iter
        self.tol = tol
        self.coef_: np.ndarray | None = None
        self.intercept_: float = 0.0
        self._x_mean: np.ndarray | None = None
        self._y_mean: float = 0.0

    def fit(self, X, y):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n, d = X.shape
        self._x_mean = X.mean(axis=0)
        self._y_mean = float(y.mean())
        Xc = X - self._x_mean
        yc = y - self._y_mean
        l1 = self.alpha * self.l1_ratio * n
        l2 = self.alpha * (1.0 - self.l1_ratio) * n
        col_sq = (Xc ** 2).sum(axis=0) + l2
        col_sq = np.where(col_sq > 1e-12, col_sq, 1.0)
        w = np.zeros(d)
        r = yc.copy()                      # residual = yc - Xc @ w
        for _ in range(self.max_iter):
            w_max_delta = 0.0
            for j in range(d):
                wj = w[j]
                rho = Xc[:, j] @ r + wj * (col_sq[j] - l2)
                # soft threshold
                nj = np.sign(rho) * max(abs(rho) - l1, 0.0) / col_sq[j]
                if nj != wj:
                    r -= (nj - wj) * Xc[:, j]
                    w[j] = nj
                    w_max_delta = max(w_max_delta, abs(nj - wj))
            if w_max_delta < self.tol:
                break
        self.coef_ = w
        self.intercept_ = self._y_mean - float(self._x_mean @ w)
        return self

    def predict(self, X):
        return _matvec(np.asarray(X, dtype=np.float64), self.coef_) + self.intercept_

    def get_state(self):
        return {"coef": self.coef_, "intercept": self.intercept_,
                "alpha": self.alpha, "l1_ratio": self.l1_ratio}

    def set_state(self, s):
        self.coef_ = np.asarray(s["coef"], dtype=np.float64)
        self.intercept_ = float(s["intercept"])
        self.alpha = float(s["alpha"])
        self.l1_ratio = float(s["l1_ratio"])


@register
class BayesianRidge(Estimator):
    """Bayesian linear regression with evidence-maximised precisions
    (MacKay-style iterative update of alpha=noise, lambda=weights)."""
    NAME = "BayesianRidge"
    PARAM_GRID = {"max_iter": [300]}

    def __init__(self, max_iter: int = 300, tol: float = 1e-6) -> None:
        self.max_iter = max_iter
        self.tol = tol
        self.coef_: np.ndarray | None = None
        self.alpha_: float = 1.0    # noise precision
        self.lambda_: float = 1.0   # weight precision

    def fit(self, X, y):
        Xb = _add_bias(np.asarray(X, dtype=np.float64))
        y = np.asarray(y, dtype=np.float64)
        n, d = Xb.shape
        XtX = Xb.T @ Xb
        Xty = Xb.T @ y
        eigvals = np.linalg.eigvalsh(XtX)
        eigvals = np.maximum(eigvals, 0.0)
        alpha, lam = 1.0 / max(np.var(y), 1e-12), 1.0
        mn = np.zeros(d)
        for _ in range(self.max_iter):
            A = lam * np.eye(d) + alpha * XtX
            mn_new = alpha * np.linalg.solve(A, Xty)
            gamma = float(np.sum(alpha * eigvals / (lam + alpha * eigvals)))
            lam_new = gamma / max(float(mn_new @ mn_new), 1e-300)
            resid = y - Xb @ mn_new
            alpha_new = max(n - gamma, 1e-6) / max(float(resid @ resid), 1e-300)
            done = (abs(np.log(max(lam_new, 1e-300)) - np.log(max(lam, 1e-300)))
                    < self.tol)
            mn, lam, alpha = mn_new, lam_new, alpha_new
            if done:
                break
        self.coef_, self.alpha_, self.lambda_ = mn, alpha, lam
        return self

    def predict(self, X):
        return _matvec(_add_bias(np.asarray(X, dtype=np.float64)), self.coef_)

    def get_state(self):
        return {"coef": self.coef_, "alpha": self.alpha_, "lambda": self.lambda_}

    def set_state(self, s):
        self.coef_ = np.asarray(s["coef"], dtype=np.float64)
        self.alpha_ = float(s["alpha"])
        self.lambda_ = float(s["lambda"])
