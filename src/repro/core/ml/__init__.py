"""From-scratch numpy ML library implementing the paper's 8 candidate models
(Table II/VI): LinearRegression, ElasticNet, BayesianRidge, DecisionTree,
RandomForest, AdaBoost, XGBoost, KNN (+ Ridge as a utility)."""

from .base import Estimator, MODEL_REGISTRY, make_model, register
from .linear import LinearRegression, Ridge, ElasticNet, BayesianRidge
from .tree import DecisionTree, ArrayTree
from .forest import RandomForest, AdaBoost
from .boosting import XGBoost
from .knn import KNN
from .metrics import rmse, normalized_rmse, r2, cross_val_rmse
from .tuning import tune_model

#: Candidate set compared in paper Table VI (SVM excluded — see DESIGN.md §2).
PAPER_CANDIDATES = (
    "LinearRegression", "ElasticNet", "BayesianRidge", "DecisionTree",
    "RandomForest", "AdaBoost", "XGBoost", "KNN",
)

__all__ = [
    "Estimator", "MODEL_REGISTRY", "make_model", "register",
    "LinearRegression", "Ridge", "ElasticNet", "BayesianRidge",
    "DecisionTree", "ArrayTree", "RandomForest", "AdaBoost", "XGBoost", "KNN",
    "rmse", "normalized_rmse", "r2", "cross_val_rmse", "tune_model",
    "PAPER_CANDIDATES",
]
