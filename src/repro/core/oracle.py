"""Analytic TPU-v5e time oracle for BLAS L3 block configs.

The paper gathers *measured* wall-clock timings at install time.  On real TPU
hardware this framework does exactly that (the same ``dataset.gather`` sweep,
timing ``kernels.ops`` calls).  In this CPU-only container the TPU *target*
cannot be timed, so the install pipeline can alternatively be pointed at this
analytic oracle — a three-term roofline model of a blocked BLAS kernel on one
v5e core — keeping every other stage (sampling, features, preprocessing,
model selection, runtime argmin) identical.  DESIGN.md §2 records this
adaptation.

Model for C[m,n] += A[m,k]·B[k,n] tiled (bm, bk, bn):

  compute   = useful_flops / (peak_flops · mxu_util(bm,bk,bn))
  memory    = hbm_bytes(blocking) / hbm_bw        (A re-read ⌈n/bn⌉ times,
                                                   B re-read ⌈m/bm⌉ times,
                                                   C read+written once)
  overhead  = grid_cells · per_step_cost          (pipeline bubbles, DMA setup)

  t = max(compute, memory) + overhead  (+ optional lognormal noise)

The SYMM/SYRK/SYR2K/TRMM/TRSM variants adjust flops/bytes per their
triangular/symmetric structure and the kernel variant ('full' vs 'tri').
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = ["TpuSpec", "V5E", "oracle_time"]


@dataclasses.dataclass(frozen=True)
class TpuSpec:
    peak_flops_bf16: float = 197e12
    peak_flops_f32: float = 98.5e12        # v5e MXU f32 ≈ half bf16
    hbm_bw: float = 819e9                  # bytes/s
    vmem_bytes: int = 128 * 1024 * 1024
    grid_step_cost_s: float = 1.2e-6       # DMA issue + pipeline bubble / cell
    mxu_dim: int = 128


V5E = TpuSpec()


def _mxu_util(bm: int, bk: int, bn: int, spec: TpuSpec) -> float:
    """MXU utilisation penalty for tiles that under-fill the 128x128 array
    or are too small to hide the systolic pipeline latency."""
    d = spec.mxu_dim
    fill = min(bm / d, 1.0) * min(bn / d, 1.0) * min(bk / d, 1.0)
    # small-k tiles pay the systolic drain every pass
    drain = bk / (bk + d)
    return max(fill * drain, 0.05)


def _flops_bytes(op: str, dims: tuple[int, ...], knob: dict,
                 dtype_bytes: int) -> tuple[float, float]:
    bm, bk, bn = knob["bm"], knob["bk"], knob["bn"]
    variant = knob.get("variant", "full")
    if op == "gemm":
        m, k, n = dims
        flops = 2.0 * m * k * n
        rbytes = dtype_bytes * (m * k * math.ceil(n / bn)
                                + k * n * math.ceil(m / bm) + 2 * m * n)
        return flops, rbytes
    if op == "symm":
        m, n = dims
        flops = 2.0 * m * m * n
        rbytes = dtype_bytes * (m * m * math.ceil(n / bn)
                                + m * n * math.ceil(m / bm) + 2 * m * n)
        return flops, rbytes
    if op in ("syrk", "syr2k"):
        n, k = dims
        mult = 2.0 if op == "syr2k" else 1.0
        tri = 0.55 if variant == "tri" else 1.0   # tri kernels do ~half FLOPs
        flops = mult * 2.0 * n * n * k * tri
        rbytes = dtype_bytes * (mult * n * k * math.ceil(n / bn) * tri
                                + 2 * n * n)
        return flops, rbytes
    if op in ("trmm", "trsm"):
        m, n = dims
        tri = 0.55 if variant == "tri" else 1.0
        flops = m * m * n * tri * (1.0 if op == "trmm" else 1.0)
        flops *= 2.0
        rbytes = dtype_bytes * (m * m * math.ceil(n / bn) * tri
                                + 2 * m * n * math.ceil(m / bm))
        return flops, rbytes
    raise ValueError(op)


def _grid_cells(op: str, dims: tuple[int, ...], knob: dict) -> int:
    bm, bk, bn = knob["bm"], knob["bk"], knob["bn"]
    if op == "gemm":
        m, k, n = dims
    elif op == "symm":
        m, n = dims
        k = m
    elif op in ("syrk", "syr2k"):
        n, k = dims
        m = n
    else:  # trmm/trsm
        m, n = dims
        k = m
    return (math.ceil(m / bm) * math.ceil(n / bn) * math.ceil(k / bk))


def oracle_time(op: str, dims: tuple[int, ...], knob, *,
                dtype_bytes: int = 2, spec: TpuSpec = V5E,
                noise_rng: np.random.Generator | None = None,
                noise_sigma: float = 0.03) -> float:
    """Predicted seconds for one kernel call on one v5e core."""
    kd = knob.dict if hasattr(knob, "dict") else dict(knob)
    flops, rbytes = _flops_bytes(op, tuple(int(d) for d in dims), kd,
                                 dtype_bytes)
    peak = spec.peak_flops_bf16 if dtype_bytes == 2 else spec.peak_flops_f32
    util = _mxu_util(kd["bm"], kd["bk"], kd["bn"], spec)
    t_compute = flops / (peak * util)
    t_memory = rbytes / spec.hbm_bw
    t_overhead = _grid_cells(op, dims, kd) * spec.grid_step_cost_s
    t = max(t_compute, t_memory) + t_overhead
    if noise_rng is not None:
        t *= float(np.exp(noise_rng.normal(0.0, noise_sigma)))
    return float(t)
