"""End-to-end install-time tuning pipeline for one BLAS L3 subroutine
(paper Fig. 1a):

    Halton sampling → timing sweep → Table-III features → LOF outlier removal
    → Yeo-Johnson + standardize + corr-prune → stratified split → per-model
    hyper-parameter tuning → estimated-speedup model selection → persist.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from . import features as F
from .dataset import TimingDataset, gather
from .knobs import Knob, KnobSpace
from .lof import remove_outliers
from .ml import PAPER_CANDIDATES
from .preprocess import PreprocessPipeline
from .selection import ModelReport, evaluate_candidates, select_best
from .split import stratified_split

__all__ = ["TunedSubroutine", "install_subroutine", "install_backend",
           "attach_knn_coreset"]

#: persisted artifact schema: v1 = single-backend (implicit pallas),
#: v2 = backend-tagged
SCHEMA_VERSION = 2


@dataclasses.dataclass
class TunedSubroutine:
    """The production artifact: everything runtime needs for one subroutine."""
    op: str
    dtype_bytes: int
    knob_space: KnobSpace
    pipeline: PreprocessPipeline
    model: object                       # fitted Estimator
    model_name: str
    log_target: bool
    reports: list[ModelReport] = dataclasses.field(default_factory=list)
    dataset: TimingDataset | None = None
    backend: str = "pallas"             # execution backend this was tuned on
    #: monotonically increasing per-artifact generation, stamped by
    #: :meth:`~repro.core.registry.ModelRegistry.save` (0 = never persisted
    #: through a registry / pre-versioning artifact).  The runtime persists
    #: it with every decision-cache entry so a warm restart can reject
    #: decisions made by a different generation of this model instead of
    #: silently replaying a predecessor's knobs.
    artifact_version: int = 0
    #: dominated-candidate analysis for the compiled fast path (optional,
    #: persisted): knob indices the model ever argmin-selects over the
    #: install dataset's dims, and that dataset's dims bounding box
    fast_live_idx: np.ndarray | None = None
    fast_dims_lo: np.ndarray | None = None
    fast_dims_hi: np.ndarray | None = None
    #: confidence-band variant of the live set (optional, persisted): knob
    #: indices whose predicted time ever comes within ``fast_band_pct`` % of
    #: the per-dims winner over the install dataset — a superset of
    #: ``fast_live_idx`` that tolerates interpolation wobble
    fast_band_idx: np.ndarray | None = None
    fast_band_pct: float | None = None
    #: opt-in KNN coreset (optional, persisted): indices into the fitted
    #: KNN's training set for the inexact-but-faster compiled lookup
    fast_knn_coreset: np.ndarray | None = None

    # -- runtime decision --------------------------------------------------
    def predict_times(self, dims: tuple[int, ...]) -> np.ndarray:
        """Predicted runtime for every knob candidate at these dims.

        This is the REFERENCE decision path: the runtime serves decisions
        through :meth:`compiled` (bit-identical argmin, far lower latency)
        and parity tests compare the two."""
        K = len(self.knob_space)
        X = F.build_features(self.op, np.tile(np.array(dims), (K, 1)),
                             self.knob_space.parallelism_vec(dims))
        pred = self.model.predict(self.pipeline.transform(X))
        return np.exp(pred) if self.log_target else pred

    def select(self, dims: tuple[int, ...]) -> Knob:
        return self.knob_space.candidates[int(np.argmin(self.predict_times(dims)))]

    def compiled(self, *, prune=False, coreset: bool = False):
        """The cached :class:`~repro.core.fastpath.CompiledPredictor` for
        this artifact (None when uncompilable).  ``prune`` may be ``False``,
        ``True`` (argmin live set) or ``"band"`` (confidence-band live
        set); ``coreset=True`` opts a KNN artifact into its persisted
        subsample."""
        cache = getattr(self, "_compiled_cache", None)
        if cache is None:
            cache = self._compiled_cache = {}
        key = (prune, coreset)
        if key not in cache:
            from .fastpath import compile_predictor
            cache[key] = compile_predictor(self, prune=prune,
                                           coreset=coreset)
        return cache[key]

    # -- persistence ---------------------------------------------------------
    def get_state(self) -> dict:
        state = {
            "version": SCHEMA_VERSION,
            "backend": self.backend,
            "op": self.op,
            "dtype_bytes": self.dtype_bytes,
            "knobs": self.knob_space.get_state(),
            "pipeline": self.pipeline.get_state(),
            "model_name": self.model_name,
            "model": self.model.get_state(),
            "log_target": self.log_target,
            "reports": [r.row() for r in self.reports],
        }
        # optional keys: absent on pre-fast-path artifacts, ignored by
        # older readers — no schema bump needed
        if self.artifact_version:
            state["artifact_version"] = int(self.artifact_version)
        if self.fast_live_idx is not None:
            state["fast_live_idx"] = np.asarray(self.fast_live_idx,
                                                dtype=np.int64)
            state["fast_dims_lo"] = np.asarray(self.fast_dims_lo,
                                               dtype=np.int64)
            state["fast_dims_hi"] = np.asarray(self.fast_dims_hi,
                                               dtype=np.int64)
        if self.fast_band_idx is not None:
            state["fast_band_idx"] = np.asarray(self.fast_band_idx,
                                                dtype=np.int64)
            state["fast_band_pct"] = float(self.fast_band_pct)
        if self.fast_knn_coreset is not None:
            state["fast_knn_coreset"] = np.asarray(self.fast_knn_coreset,
                                                   dtype=np.int64)
        return state


def install_subroutine(
    op: str,
    knob_space: KnobSpace,
    timer_fn: Callable[[tuple[int, ...], Knob], float],
    *,
    n_samples: int = 200,
    dim_lo: int = 16,
    dim_hi: int = 1024,
    max_footprint_bytes: int | None = 32 * 1024 * 1024,
    dtype_bytes: int = 4,
    candidates: Sequence[str] = PAPER_CANDIDATES,
    log_target: bool = True,
    use_lof: bool = True,
    use_yeo_johnson: bool = True,
    tune_trials: int = 6,
    test_frac: float = 0.15,
    seed: int = 0,
    dataset: TimingDataset | None = None,
    keep_dataset: bool = True,
    progress: Callable[[int, int], None] | None = None,
    backend: str = "pallas",
    band_pct: float = 10.0,
    knn_coreset_frac: float | None = None,
) -> TunedSubroutine:
    """Run the full ADSALA install for one subroutine; returns the artifact."""
    ds = dataset if dataset is not None else gather(
        op, knob_space, timer_fn, n_samples=n_samples, dim_lo=dim_lo,
        dim_hi=dim_hi, max_footprint_bytes=max_footprint_bytes,
        dtype_bytes=dtype_bytes, seed=seed, progress=progress)

    # stratify samples on their best measured time so slow/fast regimes are
    # represented in both splits (paper: stratified sampling, 15% test)
    best_t = ds.times.min(axis=1)
    train_s, test_s = stratified_split(np.log(np.maximum(best_t, 1e-12)),
                                       test_frac=test_frac, seed=seed)

    # LOF outlier removal on the flattened training rows (features ∪ label)
    lof_keep = None
    if use_lof:
        X_all, y_all, sample_idx = ds.flatten()
        in_train = np.isin(sample_idx, train_s)
        y_log = np.log(np.maximum(y_all, 1e-12))
        _, _, keep_sub = remove_outliers(X_all[in_train], y_log[in_train])
        lof_keep = np.ones(X_all.shape[0], dtype=bool)
        lof_keep[np.flatnonzero(in_train)] = keep_sub

    pipeline = PreprocessPipeline(use_yeo_johnson=use_yeo_johnson)
    reports = evaluate_candidates(
        ds, pipeline, train_s, test_s, candidates=candidates,
        log_target=log_target, tune_trials=tune_trials, seed=seed,
        lof_keep_mask=lof_keep)
    best = select_best(reports)
    sub = TunedSubroutine(
        op=op, dtype_bytes=dtype_bytes, knob_space=knob_space,
        pipeline=pipeline, model=best.model, model_name=best.name,
        log_target=log_target, reports=reports,
        dataset=ds if keep_dataset else None, backend=backend)
    _analyze_dominated(sub, ds, band_pct=band_pct)
    if knn_coreset_frac is not None:
        attach_knn_coreset(sub, frac=knn_coreset_frac, seed=seed)
    return sub


def _analyze_dominated(sub: TunedSubroutine, ds: TimingDataset,
                       chunk: int = 32, band_pct: float = 10.0) -> None:
    """Record which knob candidates the selected model ever argmin-picks
    over the gathered dims (plus the dims bounding box) on the artifact, so
    the compiled fast path can optionally drop the dominated candidates
    (``prune=True``) inside the regime that validated the drop.

    Additionally records the confidence-band live set: candidates whose
    predicted time ever comes within ``band_pct`` % of the per-dims winner.
    A candidate outside the band on EVERY install dims is dominated with
    margin — dropping it is robust to the interpolation wobble that makes
    the argmin-only set brittle — while near-winners survive, so
    ``prune="band"`` trades less latency for more safety."""
    cp = sub.compiled()
    if cp is None or ds.n_samples == 0:
        return
    chosen: list[np.ndarray] = []
    K = len(sub.knob_space)
    ratio_min = np.full(K, np.inf)
    for i in range(0, ds.n_samples, chunk):     # chunked: bounds KNN memory
        dims_list = [tuple(int(v) for v in d) for d in ds.dims[i:i + chunk]]
        t = cp.predict_times_batch(dims_list)
        chosen.append(np.argmin(t, axis=1))
        # per-candidate closest approach to the winner in this chunk
        ratio = t / np.maximum(t.min(axis=1, keepdims=True), 1e-300)
        np.minimum(ratio_min, ratio.min(axis=0), out=ratio_min)
    sub.fast_live_idx = np.unique(np.concatenate(chosen)).astype(np.int64)
    sub.fast_dims_lo = ds.dims.min(axis=0).astype(np.int64)
    sub.fast_dims_hi = ds.dims.max(axis=0).astype(np.int64)
    sub.fast_band_idx = np.flatnonzero(
        ratio_min <= 1.0 + band_pct / 100.0).astype(np.int64)
    sub.fast_band_pct = float(band_pct)


def attach_knn_coreset(sub: TunedSubroutine, *, frac: float = 0.25,
                       min_size: int = 64, seed: int = 0) -> bool:
    """Persist an opt-in coreset subsample on a KNN artifact.

    The subsample is stratified over the fitted targets (equal-count y
    quantiles, uniform within each), so fast/slow timing regimes stay
    represented.  The compiled fast path only consults it under
    ``coreset=True`` — default decisions are unchanged.  Returns False for
    non-KNN models (nothing to attach)."""
    model = sub.model
    if getattr(model, "NAME", None) != "KNN" or model.X_ is None:
        return False
    n = model.X_.shape[0]
    size = int(np.clip(round(frac * n), min(min_size, n), n))
    if size >= n:
        sub.fast_knn_coreset = np.arange(n, dtype=np.int64)
        return True
    rng = np.random.default_rng(seed)
    strata = max(1, size // 8)
    order = np.argsort(model.y_, kind="stable")
    picks: list[np.ndarray] = []
    for part, quota in zip(np.array_split(order, strata),
                           np.array_split(np.arange(size), strata)):
        take = min(len(quota), part.size)
        picks.append(rng.choice(part, size=take, replace=False))
    sub.fast_knn_coreset = np.sort(np.concatenate(picks)).astype(np.int64)
    return True


def install_backend(
    backend,                            # repro.backends.Backend
    *,
    ops: Sequence[str] | None = None,
    dtype=None,
    sizes: Sequence[int] | None = None,
    runtime=None,                       # AdsalaRuntime to register into
    registry=None,                      # ModelRegistry to persist into
    log: Callable[[str], None] | None = None,
    **install_kw,
) -> dict[str, TunedSubroutine]:
    """Sweep all (or selected) ops of one execution backend in one call.

    The backend supplies its own knob space and calibration timer, so the
    identical install pipeline runs against any registered implementation —
    the repo analogue of installing ADSALA on MKL and then on BLIS.  Tuned
    artifacts are optionally registered into a live runtime and persisted
    backend-tagged through a :class:`~repro.core.registry.ModelRegistry`.
    """
    dtype = np.float32 if dtype is None else dtype
    dtype_bytes = int(np.dtype(dtype).itemsize)
    out: dict[str, TunedSubroutine] = {}
    for op in (tuple(ops) if ops else backend.ops()):
        space = (backend.knob_space(op, sizes=tuple(sizes)) if sizes
                 else backend.knob_space(op))
        timer = backend.timer_fn(op, dtype)
        sub = install_subroutine(op, space, timer, dtype_bytes=dtype_bytes,
                                 backend=backend.name, **install_kw)
        if registry is not None:
            registry.save(sub)
        if runtime is not None:
            runtime.register(sub)
        out[op] = sub
        if log is not None:
            log(f"[install_backend] {backend.name}/{op}: "
                f"best={sub.model_name} over {len(space)} knobs")
    return out
