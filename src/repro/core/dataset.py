"""Install-time data gathering (paper §III-A / §IV-B).

Quasi-random (scrambled Halton) dimension samples × full knob sweep, each
timed by a caller-provided ``timer_fn(dims, knob) -> seconds``.  Times are
stored densely as (samples, knobs) so the selection stage can compute
ideal/estimated speedups against the measured optimum (paper Table VI).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from . import features as F
from .halton import sample_dims
from .knobs import Knob, KnobSpace

__all__ = ["TimingDataset", "gather"]


@dataclasses.dataclass
class TimingDataset:
    op: str
    dims: np.ndarray          # (S, ndims) int64
    times: np.ndarray         # (S, K) seconds
    knob_space: KnobSpace
    dtype_bytes: int
    gather_seconds: float = 0.0

    @property
    def n_samples(self) -> int:
        return self.dims.shape[0]

    def flatten(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Return (X_features, y_times, sample_index) flattened over knobs."""
        S, K = self.times.shape
        dims_rep = np.repeat(self.dims, K, axis=0)
        nt = np.concatenate([self.knob_space.parallelism_vec(tuple(d))
                             for d in self.dims])
        X = F.build_features(self.op, dims_rep, nt)
        y = self.times.reshape(-1)
        sample_idx = np.repeat(np.arange(S), K)
        return X, y, sample_idx

    def default_knob_index(self) -> int:
        """The baseline config: maximum parallelism (paper: max threads).

        For block knobs this is the candidate with the *largest grid
        parallelism on a reference shape* — i.e. the smallest (bm, bn) —
        matching the paper's "use all available parallelism" default.
        """
        ref = tuple(int(v) for v in self.dims.max(axis=0))
        p = self.knob_space.parallelism_vec(ref)
        return int(np.argmax(p))

    def get_state(self) -> dict:
        return {"op": self.op, "dims": self.dims, "times": self.times,
                "knobs": self.knob_space.get_state(),
                "dtype_bytes": self.dtype_bytes,
                "gather_seconds": self.gather_seconds}


def gather(
    op: str,
    knob_space: KnobSpace,
    timer_fn: Callable[[tuple[int, ...], Knob], float],
    *,
    n_samples: int = 250,
    dim_lo: int = 16,
    dim_hi: int = 2048,
    max_footprint_bytes: int | None = 32 * 1024 * 1024,
    dtype_bytes: int = 4,
    seed: int = 0,
    progress: Callable[[int, int], None] | None = None,
) -> TimingDataset:
    """Sweep Halton-sampled dims × every knob candidate through ``timer_fn``."""
    ndims = F.SUBROUTINE_NDIMS[op]

    def fp_bytes(d: tuple[int, ...]) -> int:
        return F.footprint_words(op, d) * dtype_bytes

    dims = sample_dims(n_samples, ndims, lo=dim_lo, hi=dim_hi,
                       max_footprint_bytes=max_footprint_bytes,
                       footprint_fn=fp_bytes, seed=seed)
    S, K = dims.shape[0], len(knob_space)
    times = np.empty((S, K), dtype=np.float64)
    t0 = time.perf_counter()
    for i, drow in enumerate(dims):
        d = tuple(int(v) for v in drow)
        for j, knob in enumerate(knob_space):
            times[i, j] = timer_fn(d, knob)
        if progress is not None:
            progress(i + 1, S)
    return TimingDataset(op=op, dims=dims, times=times, knob_space=knob_space,
                         dtype_bytes=dtype_bytes,
                         gather_seconds=time.perf_counter() - t0)
