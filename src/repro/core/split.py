"""Stratified train/test split on runtime quantiles (paper §VI-A: stratified
sampling, 15% test)."""

from __future__ import annotations

import numpy as np

__all__ = ["stratified_split"]


def stratified_split(y: np.ndarray, *, test_frac: float = 0.15,
                     n_bins: int = 10, seed: int = 0):
    """Return (train_idx, test_idx), stratified over quantile bins of ``y``."""
    y = np.asarray(y, dtype=np.float64)
    n = y.shape[0]
    rng = np.random.default_rng(seed)
    n_bins = max(1, min(n_bins, n // 4 or 1))
    edges = np.quantile(y, np.linspace(0, 1, n_bins + 1)[1:-1])
    bins = np.searchsorted(edges, y)
    train, test = [], []
    for b in np.unique(bins):
        idx = np.flatnonzero(bins == b)
        rng.shuffle(idx)
        k = int(round(test_frac * idx.size))
        test.append(idx[:k])
        train.append(idx[k:])
    train = np.concatenate(train) if train else np.arange(n)
    test = np.concatenate(test) if test else np.array([], dtype=np.int64)
    if test.size == 0 and n > 1:          # guarantee a non-empty test set
        train, test = train[:-1], train[-1:]
    return np.sort(train), np.sort(test)
