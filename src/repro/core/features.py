"""Feature engineering for BLAS L3 runtime models (paper Table III).

Two feature sets, chosen by the number of free matrix dimensions of the
subroutine:

  3-dim (GEMM):                m, k, n, nt, m*k, m*n, k*n, m*k*n, footprint,
                               m/nt, k/nt, n/nt, m*k/nt, m*n/nt, k*n/nt,
                               m*k*n/nt, footprint/nt
  2-dim (SYMM/SYRK/SYR2K/TRMM/TRSM):
                               m, n, nt, m*n, footprint,
                               m/nt, n/nt, m*n/nt, footprint/nt

``nt`` is the parallelism measure of the execution config (thread count on
CPU; number of parallel Pallas grid cells on TPU — see DESIGN.md §2).
``footprint`` is the summed size, in words, of the matrices the subroutine
reads/writes (paper footnote 1: overwritten operands counted once).
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "SUBROUTINES", "SUBROUTINE_NDIMS", "footprint_words",
    "footprint_words_vec",
    "feature_names", "build_features",
    "fill_features_into", "fill_features_batch",
]

# dims per subroutine (paper Table I). GEMM: (m,k,n); SYMM/TRMM/TRSM: (m,n);
# SYRK/SYR2K: (n,k) — treated as the generic 2-dim pair, in listed order.
SUBROUTINE_NDIMS = {
    "gemm": 3,
    "symm": 2,
    "syrk": 2,
    "syr2k": 2,
    "trmm": 2,
    "trsm": 2,
}
SUBROUTINES = tuple(SUBROUTINE_NDIMS)


def footprint_words(op: str, dims: tuple[int, ...]) -> int:
    """Summed matrix sizes in words (paper's memory_footprint feature)."""
    if op == "gemm":
        m, k, n = dims
        return m * k + k * n + m * n
    if op == "symm":
        m, n = dims
        return m * m + 2 * m * n           # A(mxm) + B(mxn) + C(mxn)
    if op == "syrk":
        n, k = dims
        return n * k + n * n               # A(nxk) + C(nxn)
    if op == "syr2k":
        n, k = dims
        return 2 * n * k + n * n           # A + B (nxk) + C(nxn)
    if op in ("trmm", "trsm"):
        m, n = dims
        return m * m + m * n               # A(mxm) + B(mxn); B overwritten
    raise ValueError(f"unknown subroutine {op!r}")


def footprint_words_vec(op: str, dims: np.ndarray) -> np.ndarray:
    """Vectorised footprint (runtime eval path: called per BLAS decision)."""
    d = np.asarray(dims, dtype=np.float64)
    if op == "gemm":
        m, k, n = d[:, 0], d[:, 1], d[:, 2]
        return m * k + k * n + m * n
    a, b = d[:, 0], d[:, 1]
    if op == "symm":
        return a * a + 2 * a * b
    if op == "syrk":
        return a * b + a * a
    if op == "syr2k":
        return 2 * a * b + a * a
    return a * a + a * b          # trmm / trsm


def feature_names(ndims: int) -> list[str]:
    if ndims == 3:
        return [
            "m", "k", "n", "nt",
            "m*k", "m*n", "k*n", "m*k*n", "footprint",
            "m/nt", "k/nt", "n/nt",
            "m*k/nt", "m*n/nt", "k*n/nt", "m*k*n/nt", "footprint/nt",
        ]
    if ndims == 2:
        return [
            "m", "n", "nt", "m*n", "footprint",
            "m/nt", "n/nt", "m*n/nt", "footprint/nt",
        ]
    raise ValueError(f"ndims must be 2 or 3, got {ndims}")


def build_features(op: str, dims: np.ndarray, nt: np.ndarray) -> np.ndarray:
    """Build the Table-III feature matrix.

    dims: (N, ndims) int array of matrix dimensions.
    nt:   (N,) parallelism measure per sample.
    Returns (N, n_features) float64.
    """
    dims = np.asarray(dims, dtype=np.float64)
    nt = np.asarray(nt, dtype=np.float64).reshape(-1)
    ndims = SUBROUTINE_NDIMS[op]
    assert dims.shape[1] == ndims, (op, dims.shape)
    fp = footprint_words_vec(op, dims)
    if ndims == 3:
        m, k, n = dims[:, 0], dims[:, 1], dims[:, 2]
        cols = [
            m, k, n, nt,
            m * k, m * n, k * n, m * k * n, fp,
            m / nt, k / nt, n / nt,
            m * k / nt, m * n / nt, k * n / nt, m * k * n / nt, fp / nt,
        ]
    else:
        m, n = dims[:, 0], dims[:, 1]
        cols = [
            m, n, nt, m * n, fp,
            m / nt, n / nt, m * n / nt, fp / nt,
        ]
    return np.stack(cols, axis=1)


# ---------------------------------------------------------------------------
# fused column building (the compiled runtime fast path)
# ---------------------------------------------------------------------------

#: sentinel marking "this column IS the parallelism vector"
_NT = object()


def _term_spec(op: str, d: tuple) -> tuple:
    """Ordered Table-III column spec at fixed dims.

    ``d`` holds one value per free dim — np.float64 scalars (single call) or
    ``(B, 1)`` float64 arrays (batched).  Each entry is either a dims-only
    value (constant across candidates), the ``_NT`` sentinel, or a 1-tuple
    ``(numerator,)`` meaning ``numerator / nt``.  Every expression repeats
    :func:`build_features` / :func:`footprint_words_vec` term by term (same
    association order, float64 throughout), so filled columns are
    bit-identical to the reference matrix's.
    """
    if SUBROUTINE_NDIMS[op] == 3:
        m, k, n = d
        mk = m * k
        mn = m * n
        kn = k * n
        mkn = mk * n
        fp = mk + kn + mn
        return (m, k, n, _NT, mk, mn, kn, mkn, fp,
                (m,), (k,), (n,), (mk,), (mn,), (kn,), (mkn,), (fp,))
    m, n = d
    mn = m * n
    if op == "symm":
        fp = m * m + 2 * m * n
    elif op == "syrk":
        fp = m * n + m * m
    elif op == "syr2k":
        fp = 2 * m * n + m * m
    else:                               # trmm / trsm
        fp = m * m + m * n
    return (m, n, _NT, mn, fp, (m,), (n,), (mn,), (fp,))


def fill_features_into(op: str, dims: tuple, nt: np.ndarray,
                       col_idx: np.ndarray, out: np.ndarray) -> None:
    """Write the selected Table-III columns for ONE dims into ``out``.

    Bit-identical to ``build_features(op, tile(dims), nt)[:, col_idx]`` but
    with no tiling, no unused columns, and no intermediate stacking —
    ``out`` is the caller's preallocated ``(K, len(col_idx))`` buffer.
    """
    spec = _term_spec(op, tuple(np.float64(v) for v in dims))
    for j, c in enumerate(col_idx):
        s = spec[c]
        if type(s) is tuple:
            np.divide(s[0], nt, out=out[:, j])
        elif s is _NT:
            out[:, j] = nt
        else:
            out[:, j] = s


def fill_features_batch(op: str, dims_arr: np.ndarray, nt: np.ndarray,
                        col_idx: np.ndarray, out: np.ndarray) -> None:
    """Batched :func:`fill_features_into`: ``dims_arr`` is ``(B, ndims)``,
    ``nt`` is ``(B, K)``, ``out`` is the ``(B, K, len(col_idx))`` buffer.
    Item ``b`` of ``out`` is bit-identical to a single-dims fill."""
    d = tuple(dims_arr[:, i:i + 1] for i in range(dims_arr.shape[1]))
    spec = _term_spec(op, d)
    for j, c in enumerate(col_idx):
        s = spec[c]
        if type(s) is tuple:
            np.divide(s[0], nt, out=out[:, :, j])
        elif s is _NT:
            out[:, :, j] = nt
        else:
            out[:, :, j] = s
