"""Feature engineering for BLAS L3 runtime models (paper Table III).

Two feature sets, chosen by the number of free matrix dimensions of the
subroutine:

  3-dim (GEMM):                m, k, n, nt, m*k, m*n, k*n, m*k*n, footprint,
                               m/nt, k/nt, n/nt, m*k/nt, m*n/nt, k*n/nt,
                               m*k*n/nt, footprint/nt
  2-dim (SYMM/SYRK/SYR2K/TRMM/TRSM):
                               m, n, nt, m*n, footprint,
                               m/nt, n/nt, m*n/nt, footprint/nt

``nt`` is the parallelism measure of the execution config (thread count on
CPU; number of parallel Pallas grid cells on TPU — see DESIGN.md §2).
``footprint`` is the summed size, in words, of the matrices the subroutine
reads/writes (paper footnote 1: overwritten operands counted once).
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "SUBROUTINES", "SUBROUTINE_NDIMS", "footprint_words",
    "footprint_words_vec",
    "feature_names", "build_features",
]

# dims per subroutine (paper Table I). GEMM: (m,k,n); SYMM/TRMM/TRSM: (m,n);
# SYRK/SYR2K: (n,k) — treated as the generic 2-dim pair, in listed order.
SUBROUTINE_NDIMS = {
    "gemm": 3,
    "symm": 2,
    "syrk": 2,
    "syr2k": 2,
    "trmm": 2,
    "trsm": 2,
}
SUBROUTINES = tuple(SUBROUTINE_NDIMS)


def footprint_words(op: str, dims: tuple[int, ...]) -> int:
    """Summed matrix sizes in words (paper's memory_footprint feature)."""
    if op == "gemm":
        m, k, n = dims
        return m * k + k * n + m * n
    if op == "symm":
        m, n = dims
        return m * m + 2 * m * n           # A(mxm) + B(mxn) + C(mxn)
    if op == "syrk":
        n, k = dims
        return n * k + n * n               # A(nxk) + C(nxn)
    if op == "syr2k":
        n, k = dims
        return 2 * n * k + n * n           # A + B (nxk) + C(nxn)
    if op in ("trmm", "trsm"):
        m, n = dims
        return m * m + m * n               # A(mxm) + B(mxn); B overwritten
    raise ValueError(f"unknown subroutine {op!r}")


def footprint_words_vec(op: str, dims: np.ndarray) -> np.ndarray:
    """Vectorised footprint (runtime eval path: called per BLAS decision)."""
    d = np.asarray(dims, dtype=np.float64)
    if op == "gemm":
        m, k, n = d[:, 0], d[:, 1], d[:, 2]
        return m * k + k * n + m * n
    a, b = d[:, 0], d[:, 1]
    if op == "symm":
        return a * a + 2 * a * b
    if op == "syrk":
        return a * b + a * a
    if op == "syr2k":
        return 2 * a * b + a * a
    return a * a + a * b          # trmm / trsm


def feature_names(ndims: int) -> list[str]:
    if ndims == 3:
        return [
            "m", "k", "n", "nt",
            "m*k", "m*n", "k*n", "m*k*n", "footprint",
            "m/nt", "k/nt", "n/nt",
            "m*k/nt", "m*n/nt", "k*n/nt", "m*k*n/nt", "footprint/nt",
        ]
    if ndims == 2:
        return [
            "m", "n", "nt", "m*n", "footprint",
            "m/nt", "n/nt", "m*n/nt", "footprint/nt",
        ]
    raise ValueError(f"ndims must be 2 or 3, got {ndims}")


def build_features(op: str, dims: np.ndarray, nt: np.ndarray) -> np.ndarray:
    """Build the Table-III feature matrix.

    dims: (N, ndims) int array of matrix dimensions.
    nt:   (N,) parallelism measure per sample.
    Returns (N, n_features) float64.
    """
    dims = np.asarray(dims, dtype=np.float64)
    nt = np.asarray(nt, dtype=np.float64).reshape(-1)
    ndims = SUBROUTINE_NDIMS[op]
    assert dims.shape[1] == ndims, (op, dims.shape)
    fp = footprint_words_vec(op, dims)
    if ndims == 3:
        m, k, n = dims[:, 0], dims[:, 1], dims[:, 2]
        cols = [
            m, k, n, nt,
            m * k, m * n, k * n, m * k * n, fp,
            m / nt, k / nt, n / nt,
            m * k / nt, m * n / nt, k * n / nt, m * k * n / nt, fp / nt,
        ]
    else:
        m, n = dims[:, 0], dims[:, 1]
        cols = [
            m, n, nt, m * n, fp,
            m / nt, n / nt, m * n / nt, fp / nt,
        ]
    return np.stack(cols, axis=1)
