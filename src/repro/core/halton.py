"""Scrambled Halton quasi-random sequences (paper §IV-B).

The paper samples matrix-dimension space with a *scrambled* Halton sequence
(bases 2, 3, 4 for ``m, k, n``; bases 2, 3 for two-dimension subroutines) to
obtain low-discrepancy coverage while breaking the inter-dimension correlation
of the plain Halton sequence [Mascagni & Chi 2004].

We implement digit-permutation scrambling: for base ``b`` a fixed random
permutation ``pi_b`` of ``{0..b-1}`` (with ``pi_b(0)=0`` so the sequence stays
in (0,1)) is applied to every radical-inverse digit.  The permutation is drawn
from a seeded generator so sampling is reproducible per installation.
"""

from __future__ import annotations

import numpy as np

__all__ = ["halton_sequence", "scrambled_halton", "sample_dims"]

# Paper: bases 2,3,4 for (m,k,n); 2,3 for (m,n).  Base 4 is not prime; the
# paper uses it anyway — we honour that choice (radical inverse is well defined
# for any integer base >= 2).
BASES_3D = (2, 3, 4)
BASES_2D = (2, 3)


def _radical_inverse(indices: np.ndarray, base: int,
                     perm: np.ndarray | None = None) -> np.ndarray:
    """Vectorised (optionally scrambled) radical inverse of ``indices``."""
    idx = np.asarray(indices, dtype=np.int64).copy()
    out = np.zeros(idx.shape, dtype=np.float64)
    f = 1.0
    while np.any(idx > 0):
        f /= base
        digit = idx % base
        if perm is not None:
            digit = perm[digit]
        out += f * digit
        idx //= base
    return out


def _digit_permutation(base: int, rng: np.random.Generator) -> np.ndarray:
    """Random digit permutation fixing 0 (keeps points strictly inside (0,1))."""
    p = 1 + rng.permutation(base - 1)
    return np.concatenate([[0], p]).astype(np.int64)


def halton_sequence(n: int, bases: tuple[int, ...], *, start: int = 1) -> np.ndarray:
    """Plain Halton sequence, shape (n, len(bases)), values in (0, 1)."""
    idx = np.arange(start, start + n)
    return np.stack([_radical_inverse(idx, b) for b in bases], axis=1)


def scrambled_halton(n: int, bases: tuple[int, ...], *, seed: int = 0,
                     start: int = 1) -> np.ndarray:
    """Scrambled Halton sequence, shape (n, len(bases)), values in (0, 1)."""
    rng = np.random.default_rng(seed)
    idx = np.arange(start, start + n)
    cols = []
    for b in bases:
        perm = _digit_permutation(b, rng)
        cols.append(_radical_inverse(idx, b, perm))
    return np.stack(cols, axis=1)


def sample_dims(
    n: int,
    ndims: int,
    *,
    lo: int = 16,
    hi: int = 4096,
    max_footprint_bytes: int | None = None,
    footprint_fn=None,
    seed: int = 0,
    log_scale: bool = True,
) -> np.ndarray:
    """Sample ``n`` integer dimension tuples via scrambled Halton.

    Mirrors the paper's install-time sampling: quasi-random points are mapped
    into ``[lo, hi]`` (log-scaled by default so small/slim matrices are well
    represented) and rejected when ``footprint_fn(dims) > max_footprint_bytes``
    (the paper caps the summed matrix size at 500 MB; we keep the cap a
    parameter because the calibration budget differs per machine).

    Returns an (n, ndims) int64 array.
    """
    bases = BASES_3D[:ndims] if ndims == 3 else BASES_2D[:ndims]
    out = np.empty((0, ndims), dtype=np.int64)
    start = 1
    attempts = 0
    while out.shape[0] < n and attempts < 64:
        u = scrambled_halton(2 * n, bases, seed=seed, start=start)
        start += 2 * n
        attempts += 1
        if log_scale:
            dims = np.exp(np.log(lo) + u * (np.log(hi) - np.log(lo)))
        else:
            dims = lo + u * (hi - lo)
        dims = np.maximum(np.rint(dims).astype(np.int64), 1)
        if max_footprint_bytes is not None and footprint_fn is not None:
            keep = np.array([footprint_fn(tuple(d)) <= max_footprint_bytes
                             for d in dims])
            dims = dims[keep]
        out = np.concatenate([out, dims], axis=0)
    return out[:n]
