"""Local Outlier Factor (paper §II-C) — density-based outlier removal.

Brute-force numpy implementation (datasets are ~10^3 points × ≤15 dims, so
O(n²) distances are trivial).  Matches Breunig et al. 2000:

    reach-dist_k(a,b) = max(k-distance(b), d(a,b))
    lrd_k(a)          = 1 / mean_{b in kNN(a)} reach-dist_k(a,b)
    LOF_k(a)          = mean_{b in kNN(a)} lrd_k(b) / lrd_k(a)

Points with LOF above ``threshold`` are flagged as local outliers.
"""

from __future__ import annotations

import numpy as np

__all__ = ["lof_scores", "remove_outliers"]


def lof_scores(X: np.ndarray, k: int = 20) -> np.ndarray:
    X = np.asarray(X, dtype=np.float64)
    n = X.shape[0]
    k = min(k, n - 1)
    if k < 1:
        return np.ones(n)
    # pairwise distances
    sq = (X ** 2).sum(axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (X @ X.T)
    np.fill_diagonal(d2, np.inf)
    d = np.sqrt(np.maximum(d2, 0.0))
    # k nearest neighbours
    nn_idx = np.argpartition(d, k - 1, axis=1)[:, :k]           # (n, k)
    nn_d = np.take_along_axis(d, nn_idx, axis=1)                # (n, k)
    k_dist = nn_d.max(axis=1)                                   # k-distance(b)
    # reachability distance of each point from its neighbours
    reach = np.maximum(k_dist[nn_idx], nn_d)                    # (n, k)
    lrd = 1.0 / np.maximum(reach.mean(axis=1), 1e-300)
    lof = (lrd[nn_idx].mean(axis=1)) / lrd
    return lof


def remove_outliers(X: np.ndarray, y: np.ndarray, *, k: int = 20,
                    threshold: float = 1.5) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Return (X_clean, y_clean, keep_mask); outliers scored on [X | y]."""
    y = np.asarray(y, dtype=np.float64)
    # standardize jointly so runtime outliers count too (timing noise spikes)
    Z = np.concatenate([X, y[:, None]], axis=1)
    mu, sd = Z.mean(axis=0), Z.std(axis=0)
    Z = (Z - mu) / np.where(sd > 1e-12, sd, 1.0)
    scores = lof_scores(Z, k=k)
    keep = scores <= threshold
    # never drop more than 10% of the data (guard against aggressive k)
    if keep.sum() < 0.9 * len(keep):
        order = np.argsort(scores)
        keep = np.zeros(len(keep), dtype=bool)
        keep[order[: int(np.ceil(0.9 * len(order)))]] = True
    return X[keep], y[keep], keep
