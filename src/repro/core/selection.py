"""Model evaluation + automatic model selection (paper §IV-D, Table VI).

Selection metric is the *estimated speedup*

    s = t_original / (t_ADSALA + t_eval)

where ``t_original`` is the measured runtime at the default (max-parallelism)
config, ``t_ADSALA`` the measured runtime at the model's argmin-predicted
config, and ``t_eval`` the measured model evaluation latency for one BLAS
call (a batch predict over all knob candidates).  The model with the highest
estimated mean speedup wins — predictive accuracy and evaluation speed trade
off exactly as in the paper.

``t_eval`` is measured through the COMPILED fast path
(:class:`~repro.core.fastpath.CompiledPredictor`) — the path the production
runtime actually serves decisions from — so the metric charges each model
its real per-call cost, not the slower reference pipeline's.
"""

from __future__ import annotations

import dataclasses
import time
import types
from typing import Sequence

import numpy as np

from .dataset import TimingDataset
from .fastpath import CompiledPredictor, compile_predictor
from .ml import make_model, tune_model, rmse
from .preprocess import PreprocessPipeline

__all__ = ["ModelReport", "evaluate_candidates", "select_best"]


@dataclasses.dataclass
class ModelReport:
    name: str
    test_rmse: float
    normalized_rmse: float
    ideal_mean_speedup: float
    ideal_aggregate_speedup: float
    eval_time_us: float
    estimated_mean_speedup: float
    estimated_aggregate_speedup: float
    fit_seconds: float
    model: object = None  # the fitted Estimator

    def row(self) -> dict:
        return {k: getattr(self, k) for k in (
            "name", "normalized_rmse", "ideal_mean_speedup",
            "ideal_aggregate_speedup", "eval_time_us",
            "estimated_mean_speedup", "estimated_aggregate_speedup")}


def _measure_eval_time_us(compiled: CompiledPredictor,
                          dims: tuple[int, ...], *, repeats: int = 50
                          ) -> float:
    """Latency of one runtime decision through the compiled fast path —
    fused feature build + transform + predict + argmin over all knobs."""
    compiled.select(dims)                # warmup (allocates thread buffers)
    t0 = time.perf_counter()
    for _ in range(repeats):
        compiled.select(dims)
    return (time.perf_counter() - t0) / repeats * 1e6


def _measure_reference_eval_time_us(ds: TimingDataset,
                                    pipeline: PreprocessPipeline, model,
                                    dims: tuple[int, ...], *,
                                    repeats: int = 50) -> float:
    """Fallback when the fast path can't compile for this (space, model):
    time the reference transform + predict the runtime would serve."""
    from . import features as F
    K = len(ds.knob_space)
    X_one = F.build_features(ds.op, np.tile(np.array(dims), (K, 1)),
                             ds.knob_space.parallelism_vec(dims))
    model.predict(pipeline.transform(X_one))
    t0 = time.perf_counter()
    for _ in range(repeats):
        model.predict(pipeline.transform(X_one))
    return (time.perf_counter() - t0) / repeats * 1e6


def _speedups(times: np.ndarray, default_idx: int, chosen: np.ndarray,
              t_eval_s: float) -> tuple[float, float, float, float]:
    """(ideal_mean, ideal_agg, est_mean, est_agg) over test samples."""
    t_orig = times[:, default_idx]
    t_chosen = times[np.arange(times.shape[0]), chosen]
    ideal_mean = float(np.mean(t_orig / np.maximum(t_chosen, 1e-12)))
    ideal_agg = float(t_orig.sum() / max(t_chosen.sum(), 1e-12))
    est = t_chosen + t_eval_s
    est_mean = float(np.mean(t_orig / np.maximum(est, 1e-12)))
    est_agg = float(t_orig.sum() / max(est.sum(), 1e-12))
    return ideal_mean, ideal_agg, est_mean, est_agg


def evaluate_candidates(
    ds: TimingDataset,
    pipeline: PreprocessPipeline,
    train_sample_idx: np.ndarray,
    test_sample_idx: np.ndarray,
    *,
    candidates: Sequence[str],
    log_target: bool = True,
    tune_trials: int = 6,
    seed: int = 0,
    lof_keep_mask: np.ndarray | None = None,
) -> list[ModelReport]:
    """Fit/tune every candidate on train samples, score on test samples."""
    X_all, y_all, sample_idx = ds.flatten()
    y_fit = np.log(np.maximum(y_all, 1e-12)) if log_target else y_all

    in_train = np.isin(sample_idx, train_sample_idx)
    if lof_keep_mask is not None:
        in_train &= lof_keep_mask
    in_test = np.isin(sample_idx, test_sample_idx)

    Z_train = pipeline.fit_transform(X_all[in_train])
    Z_test = pipeline.transform(X_all[in_test])
    ytr, yte = y_fit[in_train], y_fit[in_test]

    # per-test-sample knob prediction setup
    K = len(ds.knob_space)
    test_samples = np.asarray(test_sample_idx)
    default_idx = ds.default_knob_index()
    times_test = ds.times[test_samples]             # (T, K) measured

    # one representative runtime call's dims (eval-time measurement)
    d0 = tuple(int(v) for v in ds.dims[test_samples[0]])

    # baseline RMSE for normalisation = worst linear-family candidate
    reports: list[ModelReport] = []
    for name in candidates:
        t0 = time.perf_counter()
        model = tune_model(make_model(name), Z_train, ytr,
                           n_trials=tune_trials, seed=seed)
        fit_s = time.perf_counter() - t0
        test_rmse = rmse(yte, model.predict(Z_test))
        # the exact artifact-compilation entry point the runtime uses, so
        # t_eval is charged at the lowering each family actually serves
        # (returns None for uncompilable combinations)
        compiled = compile_predictor(types.SimpleNamespace(
            op=ds.op, knob_space=ds.knob_space, pipeline=pipeline,
            model=model, log_target=log_target))
        if compiled is not None:
            t_eval_us = _measure_eval_time_us(compiled, d0)
        else:
            t_eval_us = _measure_reference_eval_time_us(
                ds, pipeline, model, d0)
        # argmin-predicted knob per test sample
        pred = model.predict(Z_test).reshape(len(test_samples), K)
        chosen = np.argmin(pred, axis=1)
        im, ia, em, ea = _speedups(times_test, default_idx, chosen,
                                   t_eval_us * 1e-6)
        reports.append(ModelReport(
            name=name, test_rmse=test_rmse, normalized_rmse=np.nan,
            ideal_mean_speedup=im, ideal_aggregate_speedup=ia,
            eval_time_us=t_eval_us, estimated_mean_speedup=em,
            estimated_aggregate_speedup=ea, fit_seconds=fit_s, model=model))

    # normalise RMSE by the worst candidate's RMSE (paper Table VI: linear
    # models sit at 1.00)
    worst = max(r.test_rmse for r in reports) or 1.0
    for r in reports:
        r.normalized_rmse = r.test_rmse / worst
    return reports


def select_best(reports: list[ModelReport]) -> ModelReport:
    """Paper IV-D: highest estimated mean speedup wins."""
    return max(reports, key=lambda r: r.estimated_mean_speedup)
