"""KnobSpace — the discrete runtime execution-config space ADSALA tunes over.

The paper's knob is the thread count ``nt ∈ {1..cores×HT}``.  On TPU the
runtime-variable knob of a BLAS L3 kernel is its Pallas block configuration
``(bm, bk, bn)`` (DESIGN.md §2).  Both are *finite discrete sets whose choice
changes runtime but not semantics* — the ADSALA mechanism (predict the runtime
of every candidate, run the argmin) only needs:

  * an enumeration of candidates,
  * a scalar ``parallelism(candidate, dims)`` measure that plays the role of
    ``nt`` in the paper's Table-III features.

Block shapes are MXU/VMEM-aligned multiples of 128 on the minor dims by
construction.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Sequence

import numpy as np

__all__ = ["Knob", "KnobSpace", "block_knob_space", "thread_knob_space"]


@dataclasses.dataclass(frozen=True)
class Knob:
    """One candidate execution config (an arbitrary mapping of named fields)."""
    values: tuple[tuple[str, Any], ...]

    @property
    def dict(self) -> dict:
        return dict(self.values)

    def __getitem__(self, k: str) -> Any:
        return self.dict[k]

    def __repr__(self) -> str:  # compact, stable — used as cache/registry keys
        return "Knob(" + ",".join(f"{k}={v}" for k, v in self.values) + ")"


class KnobSpace:
    """A named, enumerable space of execution configs."""

    def __init__(self, name: str, candidates: Sequence[dict],
                 parallelism_fn=None) -> None:
        self.name = name
        self.candidates: list[Knob] = [
            Knob(tuple(sorted(c.items()))) for c in candidates
        ]
        if not self.candidates:
            raise ValueError("empty knob space")
        self._parallelism_fn = parallelism_fn

    def __len__(self) -> int:
        return len(self.candidates)

    def __iter__(self):
        return iter(self.candidates)

    def parallelism(self, knob: Knob, dims: tuple[int, ...]) -> float:
        """The ``nt``-analogue feature for this knob at these dims."""
        if self._parallelism_fn is not None:
            return float(self._parallelism_fn(knob, dims))
        if "nt" in knob.dict:
            return float(knob["nt"])
        raise ValueError("knob space has no parallelism definition")

    def parallelism_vec(self, dims: tuple[int, ...]) -> np.ndarray:
        return np.array([self.parallelism(c, dims) for c in self.candidates])

    def index(self, knob: Knob) -> int:
        return self.candidates.index(knob)

    # -- persistence ------------------------------------------------------
    def get_state(self) -> dict:
        return {"name": self.name,
                "candidates": [c.dict for c in self.candidates]}


def thread_knob_space(max_threads: int, *,
                      powers_of_two: bool = False) -> KnobSpace:
    """The paper's literal knob: nt ∈ {1..max_threads} (or powers of two)."""
    if powers_of_two:
        nts = [2 ** i for i in range(int(math.log2(max_threads)) + 1)]
    else:
        nts = list(range(1, max_threads + 1))
    return KnobSpace("threads", [{"nt": t} for t in nts],
                     parallelism_fn=lambda k, dims: k["nt"])


def _grid_parallelism(knob: Knob, dims: tuple[int, ...]) -> float:
    """Parallel Pallas grid cells = ceil(m/bm)*ceil(n/bn) — the nt analogue.

    The ``tri_packed`` variant launches only the lower-triangle blocks, so
    its cell count carries the packed fraction: (cm+1)/2 live row blocks
    per column on average instead of cm.  This is what makes the variant
    *learnable* — it is the only knob-dependent feature channel, and
    without the adjustment full/tri_packed candidates would produce
    byte-identical Table-III rows the model provably cannot separate.
    ('full' and 'tri' launch the same grid — tri's dead cells still occupy
    slots — so those two deliberately share a feature row and tie.)
    Legacy persisted spaces contain no tri_packed candidates, so their
    features are bit-for-bit unchanged.
    """
    d = knob.dict
    if len(dims) == 3:
        m, _, n = dims
    else:
        m, n = dims
    cm = math.ceil(m / d["bm"])
    cn = math.ceil(n / d["bn"])
    if d.get("variant") == "tri_packed":
        return (cm + 1) * cn / 2.0
    return cm * cn


def block_knob_space(
    *,
    bms: Sequence[int] = (128, 256, 512),
    bks: Sequence[int] = (128, 256, 512),
    bns: Sequence[int] = (128, 256, 512),
    vmem_limit_bytes: int = 96 * 1024 * 1024,
    dtype_bytes: int = 4,
    variants: Sequence[str] = ("full",),
) -> KnobSpace:
    """TPU BLAS knob space: Pallas block shapes (bm, bk, bn) (+ kernel variant).

    Candidates whose VMEM working set (A, B, C + accumulator tiles) exceeds
    ``vmem_limit_bytes`` are excluded — they could never be launched.
    ``variants`` optionally adds the triangle-aware kernel variants
    (DESIGN.md §7.4) to the search space.
    """
    cands = []
    for bm, bk, bn, var in itertools.product(bms, bks, bns, variants):
        vmem = dtype_bytes * (bm * bk + bk * bn + 2 * bm * bn)
        if vmem <= vmem_limit_bytes:
            cands.append({"bm": bm, "bk": bk, "bn": bn, "variant": var})
    return KnobSpace("blocks", cands, parallelism_fn=_grid_parallelism)
