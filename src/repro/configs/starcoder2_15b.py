"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
(GELU MLP) vocab=49152, RoPE (arXiv:2402.19173)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, kv_heads=4,
    d_ff=24576, vocab=49152,
    mlp_type="gelu", rope_theta=1e5,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=8, kv_heads=2,
        d_ff=256, vocab=256,
        mlp_type="gelu",
        attn_q_chunk=32, attn_k_chunk=32, remat="none",
    )
