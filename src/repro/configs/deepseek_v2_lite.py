"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + 64 routed experts top-6
+ 2 shared experts, per-expert d_ff=1408, layer 0 dense FFN (d_ff=10944),
27L d_model=2048 16H, vocab=102400 (arXiv:2405.04434).
NOTE: the assignment's inline note says "160 routed" — that describes full
V2; the structured field (64e top-6) matches V2-*Lite* and is what we build
(DESIGN.md §4)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, kv_heads=16,
    d_ff=10944, vocab=102400,
    n_experts=64, top_k=6, n_shared_experts=2, moe_d_ff=1408,
    first_dense_layers=1, capacity_factor=1.25,
    use_mla=True, kv_lora=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, kv_heads=4,
        d_ff=160, vocab=256,
        n_experts=8, top_k=2, n_shared_experts=1, moe_d_ff=48,
        first_dense_layers=1, capacity_factor=1.25,
        use_mla=True, kv_lora=32, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16,
        attn_q_chunk=32, attn_k_chunk=32, remat="none",
    )
