"""Architecture registry: ``get_config(name)`` / ``--arch <id>``.

One module per assigned architecture, each exporting ``CONFIG`` (full-size,
exercised only via the dry-run) and ``smoke_config()`` (reduced same-family
config for CPU smoke tests).
"""

from __future__ import annotations

import importlib

from .base import ModelConfig, SHAPES, Shape, shape_applicable

ARCHITECTURES = (
    "zamba2_1p2b", "rwkv6_1p6b", "granite_moe_3b", "deepseek_v2_lite",
    "qwen15_4b", "starcoder2_15b", "granite_20b", "llama3_8b",
    "whisper_medium", "internvl2_76b",
)

# external ids (--arch) → module names
ALIASES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "qwen1.5-4b": "qwen15_4b",
    "starcoder2-15b": "starcoder2_15b",
    "granite-20b": "granite_20b",
    "llama3-8b": "llama3_8b",
    "whisper-medium": "whisper_medium",
    "internvl2-76b": "internvl2_76b",
}


def _module(name: str):
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def list_archs() -> tuple[str, ...]:
    return ARCHITECTURES


__all__ = ["ModelConfig", "SHAPES", "Shape", "shape_applicable",
           "ARCHITECTURES", "ALIASES", "get_config", "get_smoke_config",
           "list_archs"]
