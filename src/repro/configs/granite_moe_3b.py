"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8),
per-expert d_ff=512, vocab=49155, MoE 40 experts top-8
[hf:ibm-granite family].  NOTE: the assignment's structured field says 40e;
its inline note says 32 — we follow the structured field (DESIGN.md §4)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, kv_heads=8,
    d_ff=512, vocab=49155,
    n_experts=40, top_k=8, moe_d_ff=512, capacity_factor=1.25,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, kv_heads=2,
        d_ff=96, vocab=256,
        n_experts=8, top_k=2, moe_d_ff=96, capacity_factor=1.25,
        attn_q_chunk=32, attn_k_chunk=32, remat="none",
    )
