"""llama3-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 (SwiGLU)
vocab=128256, rope_theta=500000 (arXiv:2407.21783)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, kv_heads=8,
    d_ff=14336, vocab=128256,
    mlp_type="swiglu", rope_theta=5e5,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, kv_heads=2,
        d_ff=160, vocab=256,
        mlp_type="swiglu",
        attn_q_chunk=32, attn_k_chunk=32, remat="none",
    )
