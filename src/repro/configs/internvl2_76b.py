"""internvl2-76b [vlm] — InternViT + InternLM2 backbone (arXiv:2404.16821).
Backbone only: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The ViT frontend is a STUB: ``input_specs()`` supplies precomputed patch
embeddings (B, 256, d_model) prepended to the token stream through a
learned projection."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, kv_heads=8,
    d_ff=28672, vocab=128256,
    mlp_type="swiglu", rope_theta=5e5, vision_tokens=256,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm",
        n_layers=3, d_model=64, n_heads=4, kv_heads=2,
        d_ff=160, vocab=256,
        mlp_type="swiglu", vision_tokens=8,
        attn_q_chunk=32, attn_k_chunk=32, remat="none",
    )
