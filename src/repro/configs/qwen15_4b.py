"""qwen1.5-4b [dense] — 40L d_model=2560 20H (kv=20) d_ff=6912 (SwiGLU)
vocab=151936, QKV bias [hf:Qwen/Qwen1.5 family]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, kv_heads=20,
    d_ff=6912, vocab=151936,
    qkv_bias=True, mlp_type="swiglu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, kv_heads=4,
        d_ff=160, vocab=256,
        qkv_bias=True, mlp_type="swiglu",
        attn_q_chunk=32, attn_k_chunk=32, remat="none",
    )
