"""granite-20b [dense] — 52L d_model=6144 48H MQA (kv=1) d_ff=24576 (GELU)
vocab=49152, code model (arXiv:2405.04324).  kv=1 cannot shard across the
16-way model axis → KV projections replicate (models/sharding.py fallback)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, kv_heads=1,
    d_ff=24576, vocab=49152,
    mlp_type="gelu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=8, kv_heads=1,
        d_ff=256, vocab=256,
        mlp_type="gelu",
        attn_q_chunk=32, attn_k_chunk=32, remat="none",
    )
