"""zamba2-1.2b [hybrid] — Mamba2 backbone + one SHARED attention+MLP block
applied every 6 layers (arXiv:2411.15242).  38L = 6 super-blocks (6 mamba +
shared attn each) + 2 tail mamba layers.  The shared block reads
concat(hidden, embedding) through a per-invocation input projection."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, kv_heads=32,
    d_ff=8192, vocab=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_groups=1, ssm_chunk=256,
    shared_attn_every=6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, kv_heads=4,
        d_ff=128, vocab=256,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_groups=1,
        ssm_chunk=16, shared_attn_every=2,
        attn_q_chunk=32, attn_k_chunk=32, remat="none",
    )
