"""whisper-medium [audio] — encoder-decoder, 24 enc + 24 dec layers,
d_model=1024 16H d_ff=4096 (GELU) vocab=51865 (arXiv:2212.04356).
The conv frontend is a STUB: ``input_specs()`` supplies precomputed frame
embeddings (B, 1500, d_model); sinusoidal positions, no RoPE."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, kv_heads=16,
    d_ff=4096, vocab=51865,
    mlp_type="gelu", n_enc_layers=24, enc_seq=1500,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, kv_heads=4,
        d_ff=128, vocab=256,
        mlp_type="gelu", n_enc_layers=2, enc_seq=32,
        attn_q_chunk=32, attn_k_chunk=32, remat="none",
    )
