"""Model configuration schema shared by all 10 assigned architectures.

A config is a frozen dataclass; the layer stack is described by
``segments()`` — a list of (block_kind, repeat) pairs that the model
assembler turns into ``jax.lax.scan``s over stacked per-layer params (HLO
size stays O(#segments), critical for 512-device compiles).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["ModelConfig", "Shape", "SHAPES", "shape_applicable"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0                 # 0 → d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    mlp_type: Literal["swiglu", "gelu"] = "swiglu"

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                 # per-expert FFN width
    first_dense_layers: int = 0       # leading dense-FFN layers (deepseek)
    capacity_factor: float = 1.25

    # --- MLA (deepseek) ------------------------------------------------------
    use_mla: bool = False
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # --- SSM (mamba2) --------------------------------------------------------
    ssm_state: int = 64
    ssm_headdim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256
    ssm_groups: int = 1

    # --- RWKV6 ----------------------------------------------------------------
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 128
    rwkv_lora: int = 64

    # --- hybrid (zamba2): one shared attn+mlp block applied every k layers ---
    shared_attn_every: int = 6

    # --- encoder-decoder (whisper) --------------------------------------------
    n_enc_layers: int = 0
    enc_seq: int = 1500               # 30 s of audio → 1500 frames (stub)

    # --- VLM (internvl): stubbed ViT frontend → patch-embedding prefix -------
    vision_tokens: int = 0

    # --- numerics / execution -------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: Literal["none", "block", "dots", "nested"] = "nested"
    ce_chunk: int = 2048              # fused-CE seq tile (0 = materialise)
    unroll_attn: int = 1              # costing: inline N flash kv trips
    unroll_ssm: int = 1               # costing: inline N SSD/WKV chunk trips
    attn_q_chunk: int = 1024          # blockwise-attention q tile
    attn_k_chunk: int = 1024          # blockwise-attention kv tile
    causal_skip: bool = False         # skip fully-masked kv blocks (§Perf)
    use_pallas_gemm: bool = False     # route dense matmuls through run_op
    gemm_backend: str = "pallas"      # run_op backend key for routed matmuls
    gemm_interpret: bool | None = None  # None → backend auto (TPU: compiled)

    # ------------------------------------------------------------------------
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def segments(self) -> list[tuple[str, int]]:
        """(block_kind, repeat) pairs, in order."""
        if self.family == "hybrid":   # zamba2: 6×(super = 6·mamba+shared) + 2
            supers, tail = divmod(self.n_layers, self.shared_attn_every)
            segs = [("zamba_super", supers)]
            if tail:
                segs.append(("mamba2", tail))
            return segs
        if self.family == "ssm":
            return [("rwkv6", self.n_layers)]
        if self.family == "moe":
            segs = []
            if self.first_dense_layers:
                segs.append(("attn", self.first_dense_layers))
            segs.append(("moe", self.n_layers - self.first_dense_layers))
            return segs
        if self.family == "audio":    # decoder side; encoder handled apart
            return [("dec_cross", self.n_layers)]
        return [("attn", self.n_layers)]   # dense, vlm backbone

    def is_decoder_only(self) -> bool:
        return self.family not in ("audio",)

    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing → long_500k applies."""
        return self.family in ("ssm", "hybrid")


# ---------------------------------------------------------------------------
# The assigned input-shape set (one per cell of the dry-run/roofline table)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """(applicable?, reason-if-not) — the skip rules from the assignment."""
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return False, "full-attention arch: long_500k needs sub-quadratic attention"
    return True, ""
