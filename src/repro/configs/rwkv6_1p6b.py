"""rwkv6-1.6b [ssm] "Finch" — attention-free, data-dependent decay
(arXiv:2404.05892).  24L, d_model=2048, channel-mix d_ff=7168 (3.5×d),
vocab=65536; 32 heads of 64 (d_model/64)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, kv_heads=32,
    d_ff=7168, vocab=65536,
    rwkv_head_dim=64, rwkv_chunk=128, rwkv_lora=64,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="ssm",
        n_layers=3, d_model=64, n_heads=4, kv_heads=4,
        d_ff=224, vocab=256,
        rwkv_head_dim=16, rwkv_chunk=16, rwkv_lora=8, remat="none",
    )
