"""Int8 gradient compression with error feedback — the distributed-
optimization trick for bandwidth-bound DP all-reduces.

Per-leaf symmetric int8 quantisation (per-tensor scale = max|g|/127).  The
quantisation residual is carried in an error-feedback buffer and added to the
next step's gradient, so compression bias vanishes over time (Karimireddy
et al. 2019).  Under pjit the quantised tensors are what crosses the ICI:
the all-reduce operand is int8 — 4× fewer collective bytes, visible directly
in the roofline's collective term.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_error_feedback", "compress_decompress", "quantize_int8",
           "dequantize_int8"]


def quantize_int8(g):
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(grads, ef):
    """Apply int8 round-trip with error feedback.
    Returns (decompressed grads, new error buffers).  In the training step
    this straddles the DP all-reduce: the int8 tensor is the collective
    operand."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), g32 - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
