"""AdamW with decoupled weight decay, global-norm clipping, and cosine
schedule — pytree-native, shardable (optimizer state inherits each param's
sharding, so FSDP'd params get FSDP'd moments for free)."""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "init_adamw", "adamw_update",
           "cosine_schedule", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), \
        {"lr": lr, "grad_norm": gn}
