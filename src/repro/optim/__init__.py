"""Optimizer substrate: AdamW + cosine schedule + clipping + int8 gradient
compression with error feedback."""

from .adamw import (AdamWConfig, AdamWState, adamw_update, clip_by_global_norm,
                    cosine_schedule, global_norm, init_adamw)
from .compression import (compress_decompress, dequantize_int8,
                          init_error_feedback, quantize_int8)

__all__ = ["AdamWConfig", "AdamWState", "adamw_update", "clip_by_global_norm",
           "cosine_schedule", "global_norm", "init_adamw",
           "compress_decompress", "dequantize_int8", "init_error_feedback",
           "quantize_int8"]
