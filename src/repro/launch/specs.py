"""Sharding-spec assignment for params, optimizer state, caches, and inputs.

Params are classified by pytree path (suffix patterns) into column-parallel,
row-parallel, expert, embedding, … logical layouts; ``logical_spec`` then
maps logical names → mesh axes under the active :class:`ShardingRules` and
silently degrades to replication where a dim doesn't divide (MQA kv=1,
odd vocab sizes).  Leading layer-stack dims (from segment scanning) are
always unsharded.

Serve-time KV caches choose between head-sharding (kv_heads divisible by the
model axis) and sequence-sharding (the SP fallback for MQA/low-kv archs and
the MLA latent cache) — decided per-arch at spec time.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, Shape
from repro.models.sharding import (DEFAULT_RULES, MULTIPOD_RULES,
                                   ShardingRules, logical_spec,
                                   mesh_axis_size)

__all__ = ["param_logical_names", "param_specs", "tree_shardings",
           "cache_specs", "input_specs", "rules_for", "abstract_params",
           "abstract_train_state", "abstract_caches"]

_ROW_PARALLEL = ("wo", "wd", "w2", "out_proj", "cm_wv")
_REPLICATED_SUFFIX = ("scale", "bias", "b", "A_log", "D", "dt_bias", "u",
                      "mu", "mu_x", "w0", "ln_scale", "ln_bias", "cm_mu_k",
                      "cm_mu_r")


def rules_for(mesh: Mesh, shape_kind: str,
              cfg: ModelConfig | None = None) -> ShardingRules:
    """Default rules per execution kind: train uses FSDP over 'data' and
    Megatron-style sequence parallelism (seq → 'model' between blocks: the
    saved scan carries shrink by the TP degree — the difference between
    fitting HBM and not at 4k×256; see EXPERIMENTS.md §Perf); serve keeps
    weights replicated across 'data' (no per-step gather) UNLESS the bf16
    weights would exceed ~6 GB/chip under TP alone (internvl2-76b), in which
    case serve keeps the FSDP axis and pays the per-layer gather."""
    base = MULTIPOD_RULES if "pod" in mesh.axis_names else DEFAULT_RULES
    if cfg is not None and cfg.n_heads % mesh.shape["model"] != 0:
        # heads can't shard over TP → partition attention compute by batch
        # over ('data','model') instead of replicating it model-axis-wide
        base = base.replace(batch_attn=base.axes_for("batch") + ("model",))
    if cfg is not None and cfg.n_experts and             cfg.n_experts % mesh.shape["model"] != 0:
        # experts can't shard over TP → slot-parallel expert compute
        base = base.replace(expert_cap=("model",))
    if shape_kind in ("train", "prefill"):
        base = base.replace(seq=("model",))
    if shape_kind in ("prefill", "decode"):
        keep_fsdp = False
        if cfg is not None:
            per_chip = (2 * total_params(cfg)) / mesh.shape["model"]
            keep_fsdp = per_chip > 6e9
        if not keep_fsdp:
            base = base.replace(embed_fsdp=())
    return base


def total_params(cfg: ModelConfig) -> int:
    """Approximate TOTAL parameter count (all experts for MoE)."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    hd = cfg.hd()
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    attn = d * cfg.n_heads * hd + 2 * d * cfg.kv_heads * hd + \
        cfg.n_heads * hd * d
    if cfg.use_mla:
        attn = (d * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                + d * (cfg.kv_lora + cfg.qk_rope_dim)
                + cfg.kv_lora * cfg.n_heads *
                (cfg.qk_nope_dim + cfg.v_head_dim)
                + cfg.n_heads * cfg.v_head_dim * d)
    if cfg.family == "ssm":
        per = 5 * d * d + 2 * d * cfg.d_ff
    elif cfg.family == "hybrid":
        d_in = cfg.ssm_expand * d
        per = 2 * d * d_in + d_in * d
    elif cfg.n_experts:
        ff = 3 * d * (cfg.moe_d_ff or cfg.d_ff) * \
            (cfg.n_experts + cfg.n_shared_experts)
        per = attn + ff
    else:
        ff = (3 if cfg.mlp_type == "swiglu" else 2) * d * cfg.d_ff
        per = attn + ff
    total = emb + L * per
    if cfg.family == "audio":
        total += cfg.n_enc_layers * (4 * d * d + 2 * d * cfg.d_ff)
    return int(total)


def param_logical_names(path: str, ndim: int) -> tuple:
    """Trailing logical axis names for a param leaf at ``path``."""
    parts = path.split("/")
    leafname = parts[-1]
    parent = parts[-2] if len(parts) > 1 else ""

    if path.endswith("embed/table"):
        return ("vocab", "embed_fsdp")
    if path.endswith("lm_head/w"):
        return ("embed_fsdp", "vocab")
    if parent == "moe" or (len(parts) > 1 and parts[-1] in
                           ("wg", "wu", "wd") and ndim == 3):
        if leafname in ("wg", "wu"):
            return ("experts", "embed_fsdp", "expert_mlp")
        if leafname == "wd":
            return ("experts", "expert_mlp", "embed_fsdp")
    if leafname == "conv_w":
        return (None, "ssm_inner")
    if leafname == "conv_b":
        return ("ssm_inner",)
    if leafname in _REPLICATED_SUFFIX or "lora" in leafname:
        return (None,) * min(ndim, 3)
    if leafname == "w":
        if parent in _ROW_PARALLEL:
            return ("o_in", "embed_fsdp")
        if parent == "wkv_b":
            return (None, "qkv")
        if parent == "in_proj":
            return ("embed_fsdp", "ssm_inner")
        if parent == "router":
            return (None, None)
        return ("embed_fsdp", "qkv")      # column-parallel default
    if leafname == "b":
        return ("qkv",)
    return (None,) * min(ndim, 2)


def _spec_for(path: str, leaf, rules: ShardingRules, mesh: Mesh) -> P:
    trailing = param_logical_names(path, leaf.ndim)
    trailing = trailing[: leaf.ndim]
    names = (None,) * (leaf.ndim - len(trailing)) + tuple(trailing)
    return logical_spec(rules, mesh, names, dims=leaf.shape)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def param_specs(params_abstract, rules: ShardingRules, mesh: Mesh):
    """PartitionSpec tree mirroring a (possibly abstract) param tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_abstract)
    return treedef.unflatten(
        [_spec_for(_path_str(p), leaf, rules, mesh) for p, leaf in flat])


def tree_shardings(tree_abstract, specs, mesh: Mesh):
    return jax.tree.map(lambda _, s: NamedSharding(mesh, s), tree_abstract,
                        specs)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def cache_logical_names(cfg: ModelConfig, path: str, ndim: int,
                        mesh: Mesh) -> tuple:
    model_n = mesh.shape["model"]
    heads_shardable = cfg.kv_heads % model_n == 0
    leaf = path.split("/")[-1]
    if leaf in ("k", "v"):
        if heads_shardable:
            return ("batch", None, "kv_heads", None)
        return ("batch", "kv_seq_model", None, None)    # SP fallback
    if leaf in ("c_kv", "k_rope"):
        return ("batch", "kv_seq_model", None)          # MLA latent cache
    if leaf == "ssm":
        return ("batch", "heads", None, None)
    if leaf == "conv":
        return ("batch", None, "ssm_inner")
    if leaf == "S":
        return ("batch", "heads", None, None)
    if leaf in ("tm_prev", "cm_prev"):
        return ("batch", None)
    if leaf == "len":
        return ()
    return (None,) * ndim


def cache_specs(cfg: ModelConfig, caches_abstract, rules: ShardingRules,
                mesh: Mesh):
    rules = rules.replace(kv_seq_model=("model",))
    flat, treedef = jax.tree_util.tree_flatten_with_path(caches_abstract)
    out = []
    for p, leaf in flat:
        names = cache_logical_names(cfg, _path_str(p), leaf.ndim, mesh)
        names = ((None,) * (leaf.ndim - len(names)) + tuple(names)
                 )[-leaf.ndim:] if leaf.ndim else ()
        out.append(logical_spec(rules, mesh, names, dims=leaf.shape))
    return treedef.unflatten(out)


# ---------------------------------------------------------------------------
# inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: Shape, mesh: Mesh):
    """ShapeDtypeStruct stand-ins (weak-type-correct, sharded, no
    allocation) for every model input of this (arch × shape) cell."""
    from repro.launch.mesh import batch_axes
    B = shape.global_batch
    S = shape.seq_len
    baxes = batch_axes(mesh)
    bspec = (baxes if B % mesh_axis_size(mesh, baxes) == 0 else None)

    def tok(b, s):
        return jax.ShapeDtypeStruct(
            (b, s), np.int32,
            sharding=NamedSharding(mesh, P(bspec, None)))

    def dense(b, s, d):
        return jax.ShapeDtypeStruct(
            (b, s, d), np.float32,
            sharding=NamedSharding(mesh, P(bspec, None, None)))

    if shape.kind == "train" or shape.kind == "prefill":
        seq_tokens = S - (cfg.vision_tokens if cfg.family == "vlm" else 0)
        batch = {"tokens": tok(B, seq_tokens)}
        if shape.kind == "train":
            batch["labels"] = tok(B, seq_tokens)
        if cfg.family == "vlm":
            batch["vision"] = dense(B, cfg.vision_tokens, cfg.d_model)
        if cfg.family == "audio":
            batch["frames"] = dense(B, cfg.enc_seq, cfg.d_model)
        return batch
    # decode: one new token against a seq_len cache
    batch = {"tokens": tok(B, 1)}
    if cfg.family == "audio":
        batch["enc_out"] = dense(B, cfg.enc_seq, cfg.d_model)
    return batch


# ---------------------------------------------------------------------------
# abstract state builders (eval_shape — no allocation)
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig, rules: ShardingRules, mesh: Mesh):
    from repro.models import init_params
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    specs = param_specs(shapes, rules, mesh)
    return jax.tree.map(
        lambda sh, sp: jax.ShapeDtypeStruct(
            sh.shape, sh.dtype, sharding=NamedSharding(mesh, sp)),
        shapes, specs), specs


def abstract_train_state(cfg: ModelConfig, rules: ShardingRules, mesh: Mesh):
    """(abstract params, abstract AdamW state) with matching shardings."""
    from repro.optim import init_adamw
    aparams, pspecs = abstract_params(cfg, rules, mesh)
    astate = jax.eval_shape(init_adamw, aparams)
    # moments inherit param specs; step is replicated
    mu = jax.tree.map(
        lambda sh, sp: jax.ShapeDtypeStruct(
            sh.shape, sh.dtype, sharding=NamedSharding(mesh, sp)),
        astate.mu, pspecs)
    nu = jax.tree.map(
        lambda sh, sp: jax.ShapeDtypeStruct(
            sh.shape, sh.dtype, sharding=NamedSharding(mesh, sp)),
        astate.nu, pspecs)
    step = jax.ShapeDtypeStruct((), np.int32,
                                sharding=NamedSharding(mesh, P()))
    from repro.optim import AdamWState
    return aparams, AdamWState(step=step, mu=mu, nu=nu), pspecs


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int,
                    rules: ShardingRules, mesh: Mesh, dtype="bfloat16"):
    from repro.models import init_decode_state
    shapes = jax.eval_shape(
        lambda: init_decode_state(cfg, batch, max_len, dtype=dtype))
    specs = cache_specs(cfg, shapes, rules, mesh)
    return jax.tree.map(
        lambda sh, sp: jax.ShapeDtypeStruct(
            sh.shape, sh.dtype, sharding=NamedSharding(mesh, sp)),
        shapes, specs), specs
