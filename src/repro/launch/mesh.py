"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax use.

  single-pod : (16, 16)    axes (data, model)          = 256 chips (one v5e pod)
  multi-pod  : (2, 16, 16) axes (pod, data, model)     = 512 chips
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "batch_axes", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
