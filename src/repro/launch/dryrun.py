import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) cell this lowers and COMPILES
the real step function (train_step / prefill / serve_step) against abstract
ShapeDtypeStruct inputs on the production mesh — 16×16 single-pod and
2×16×16 multi-pod — then records ``memory_analysis()`` (fits?),
``cost_analysis()`` (FLOPs/bytes for §Roofline) and the collective schedule
parsed from the compiled HLO.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh single --out runs/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

The two os.environ lines above MUST run before any other import — jax locks
the device count on first init.  Do not set the flag globally: smoke tests
and benches see 1 device.
"""

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHITECTURES, ALIASES, SHAPES, get_config, \
    shape_applicable
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch.specs import (abstract_caches, abstract_train_state,
                                input_specs, rules_for)
from repro.models import decode_step, loss_fn, prefill
from repro.optim import AdamWConfig, adamw_update
from repro.roofline.analysis import model_flops, roofline
from repro.roofline.hlo_parse import parse_collectives


def _active_params(cfg) -> int:
    """Approximate parameter count (active params for MoE)."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    hd = cfg.hd()
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "ssm":
        per = 4 * d * d + 2 * d * cfg.d_ff + d * d
    elif cfg.family == "hybrid":
        d_in = cfg.ssm_expand * d
        per = 2 * d * d_in + d_in * d          # mamba proj in/out
    else:
        attn = d * cfg.n_heads * hd + 2 * d * cfg.kv_heads * hd + \
            cfg.n_heads * hd * d
        if cfg.use_mla:
            attn = (d * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                    + d * (cfg.kv_lora + cfg.qk_rope_dim)
                    + cfg.kv_lora * cfg.n_heads *
                    (cfg.qk_nope_dim + cfg.v_head_dim)
                    + cfg.n_heads * cfg.v_head_dim * d)
        if cfg.n_experts:
            ff = 3 * d * (cfg.moe_d_ff or cfg.d_ff) * \
                (cfg.top_k + cfg.n_shared_experts)
        else:
            ff = (3 if cfg.mlp_type == "swiglu" else 2) * d * cfg.d_ff
        per = attn + ff
    total = emb + L * per
    if cfg.family == "audio":
        total += cfg.n_enc_layers * (4 * d * d + 2 * d * cfg.d_ff)
    return int(total)


def build_step(cfg, shape, mesh, rules, *, adamw=AdamWConfig()):
    """Returns (jitted fn, example abstract args tuple)."""
    binputs = input_specs(cfg, shape, mesh)

    if shape.kind == "train":
        aparams, astate, pspecs = abstract_train_state(cfg, rules, mesh)

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch, cfg, mesh=mesh, rules=rules),
                has_aux=True)(params)
            new_p, new_s, om = adamw_update(params, grads, opt_state, adamw)
            return new_p, new_s, {"loss": loss, **metrics, **om}

        fn = jax.jit(train_step, donate_argnums=(0, 1))
        return fn, (aparams, astate, binputs)

    serve_rules = rules
    aparams, _, pspecs = abstract_train_state(cfg, serve_rules, mesh)
    # serving deploys low-precision weights (bf16 checkpoint) — no optimizer
    aparams = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape,
            np.dtype(cfg.compute_dtype) if s.dtype == np.float32 else s.dtype,
            sharding=s.sharding),
        aparams)
    if shape.kind == "prefill":
        acaches, _ = abstract_caches(cfg, shape.global_batch, shape.seq_len,
                                     serve_rules, mesh)

        def prefill_step(params, batch, caches):
            return prefill(params, batch, caches, cfg, mesh=mesh,
                           rules=serve_rules)

        fn = jax.jit(prefill_step, donate_argnums=(2,))
        return fn, (aparams, binputs, acaches)

    # decode: one token against a seq_len cache
    acaches, _ = abstract_caches(cfg, shape.global_batch, shape.seq_len,
                                 serve_rules, mesh)

    def serve_step(params, batch, caches):
        return decode_step(params, batch["tokens"], caches, cfg, mesh=mesh,
                           rules=serve_rules,
                           enc_out=batch.get("enc_out"))

    fn = jax.jit(serve_step, donate_argnums=(2,))
    return fn, (aparams, binputs, acaches)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             *, rules_override=None, tag: str = "",
             cfg_override: dict | None = None) -> dict:
    cfg = get_config(arch)
    if cfg_override:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **cfg_override)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = rules_for(mesh, shape.kind, cfg)
    if rules_override:
        rules = rules.replace(**rules_override)
    t0 = time.perf_counter()
    fn, args = build_step(cfg, shape, mesh, rules)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    chips = int(np.prod(list(mesh.shape.values())))
    if isinstance(cost, (list, tuple)):   # older jax returns [dict]
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    mf = model_flops(cfg, shape, _active_params(cfg))

    # loop-aware correction: per-unit body compiles (roofline/costing.py)
    from repro.roofline.costing import cell_units, corrected_costs, unit_costs
    from repro.launch.specs import abstract_caches, abstract_params
    aparams, _ = abstract_params(cfg, rules, mesh)
    acaches = None
    if shape.kind in ("prefill", "decode"):
        acaches, _ = abstract_caches(cfg, shape.global_batch, shape.seq_len,
                                     rules, mesh)
    unit_records = []
    for unit in cell_units(cfg, shape):
        costs = unit_costs(cfg, unit, shape, mesh, rules, aparams, acaches)
        unit_records.append({"unit": unit, **costs})
    corr = corrected_costs({"flops": flops, "bytes": byts,
                            "coll": coll["total_operand_bytes"]},
                           unit_records)

    rep = roofline(arch=arch, shape=shape_name, mesh=mesh_kind, chips=chips,
                   hlo_flops=corr["flops"], hlo_bytes=corr["bytes"],
                   collective_bytes=corr["coll"], model_flops_=mf)
    rec.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0) +
                          (getattr(mem, "argument_size_in_bytes", 0) or 0),
        },
        cost={"flops_per_device_raw": flops, "bytes_per_device_raw": byts,
              "flops_per_device": corr["flops"],
              "bytes_per_device": corr["bytes"],
              "collective_bytes": corr["coll"]},
        units=[{"kind": u["unit"].kind, "count": u["unit"].count,
                "trips": u["unit"].trips,
                "total_flops": u["total"]["flops"],
                "once_flops": u["once"]["flops"]} for u in unit_records],
        collectives={k: v for k, v in coll.items()
                     if not isinstance(v, dict) or v["count"]},
        roofline=rep.as_dict(),
    )
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{ALIASES.get(arch, arch).replace('-', '_')}_{shape_name}_{mesh_kind}"
    if tag:
        name += f"_{tag}"
    (out_dir / f"{name}.json").write_text(json.dumps(rec, indent=2))
    return rec


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="")
    p.add_argument("--shape", default="")
    p.add_argument("--mesh", default="single", choices=["single", "multi",
                                                        "both"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="runs/dryrun")
    p.add_argument("--tag", default="")
    p.add_argument("--rules", default="",
                   help="logical=axis1+axis2,... rule overrides")
    p.add_argument("--cfg", default="",
                   help="field=value,... ModelConfig overrides (int/bool)")
    args = p.parse_args(argv)

    overrides = None
    if args.rules:
        overrides = {}
        for kv in args.rules.split(","):
            k, v = kv.split("=")
            overrides[k] = tuple(a for a in v.split("+") if a)

    out = Path(args.out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = list(ARCHITECTURES) if args.all or not args.arch \
        else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                label = f"{arch} × {shape} × {mk}"
                cfg_over = None
                if args.cfg:
                    cfg_over = {}
                    for kv in args.cfg.split(","):
                        k, v = kv.split("=")
                        cfg_over[k] = (v == "true" if v in ("true", "false")
                                       else int(v) if v.isdigit() else v)
                try:
                    rec = run_cell(arch, shape, mk, out,
                                   rules_override=overrides, tag=args.tag,
                                   cfg_override=cfg_over)
                    if rec["status"] == "ok":
                        r = rec["roofline"]
                        print(f"[dryrun] OK  {label}: compile={rec['compile_s']}s "
                              f"peak={rec['memory']['peak_bytes']/1e9:.2f}GB/dev "
                              f"bottleneck={r['bottleneck']}", flush=True)
                    else:
                        print(f"[dryrun] SKIP {label}: {rec['reason']}",
                              flush=True)
                except Exception as e:   # noqa: BLE001
                    failures += 1
                    print(f"[dryrun] FAIL {label}: {type(e).__name__}: {e}",
                          flush=True)
                    traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
