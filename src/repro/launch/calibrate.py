"""Install-time calibration driver (paper Fig. 1a) — the ADSALA "installation".

Runs the full pipeline per BLAS L3 subroutine × precision:

    Halton sampling → wall-clock timing sweep of the host's black-box BLAS
    (kernels.cpu_blocked on CPU hosts; kernels.ops on TPU) → features →
    LOF → Yeo-Johnson/standardize/corr-prune → per-model hyper-tuning →
    estimated-speedup model selection → persist artifacts + datasets.

Usage:
    PYTHONPATH=src python -m repro.launch.calibrate \
        --out runs/adsala --samples 100 --ops gemm,symm --precisions s,d \
        --backend cpu_blocked

``--backend`` selects the execution backend being calibrated (the paper's
MKL-vs-BLIS axis): each artifact is backend-tagged, so one store can hold
the model sets of several backends side by side.

Precisions: s = float32, d = float64 (paper's SGEMM/DGEMM pairing; on TPU
targets the pair maps to bf16/f32 — DESIGN.md §2).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.backends import get_backend
from repro.core import (ModelRegistry, install_subroutine)

PRECISIONS = {"s": np.float32, "d": np.float64}
DEFAULT_BACKEND = "cpu_blocked"


def calibrate_one(op: str, prec: str, out: Path, *, backend: str, samples: int,
                  dim_lo: int, dim_hi: int, footprint_mb: float,
                  sizes: tuple[int, ...], tune_trials: int, seed: int,
                  candidates=None, log=print) -> dict:
    dtype = PRECISIONS[prec]
    dtype_bytes = np.dtype(dtype).itemsize
    be = get_backend(backend)
    space = be.knob_space(op, sizes=sizes)
    timer = be.timer_fn(op, dtype)
    t0 = time.perf_counter()
    kw = {}
    if candidates:
        kw["candidates"] = candidates
    sub = install_subroutine(
        op, space, timer, n_samples=samples, dim_lo=dim_lo, dim_hi=dim_hi,
        max_footprint_bytes=int(footprint_mb * 1e6), dtype_bytes=dtype_bytes,
        tune_trials=tune_trials, seed=seed, backend=be.name,
        progress=lambda i, n: (log(f"  [{op}/{prec}] gathered {i}/{n}")
                               if i % 25 == 0 else None), **kw)
    wall = time.perf_counter() - t0
    reg = ModelRegistry(out / "models")
    path = reg.save(sub)

    # persist the training dataset for the heatmap figures (Fig. 4/5);
    # the default backend keeps the legacy untagged filename
    ds_dir = out / "datasets"
    ds_dir.mkdir(parents=True, exist_ok=True)
    ds_name = (f"{op}_{prec}.npz" if be.name == DEFAULT_BACKEND
               else f"{be.name}__{op}_{prec}.npz")
    np.savez(ds_dir / ds_name, dims=sub.dataset.dims,
             times=sub.dataset.times,
             knobs=json.dumps([k.dict for k in sub.dataset.knob_space]),
             default_idx=sub.dataset.default_knob_index())

    report = {
        "op": op, "prec": prec, "backend": be.name,
        "best_model": sub.model_name,
        "wall_seconds": round(wall, 1),
        "gather_seconds": round(sub.dataset.gather_seconds, 1),
        "n_samples": int(sub.dataset.n_samples),
        "n_knobs": len(space),
        "artifact": str(path),
        "models": [r.row() for r in sub.reports],
    }
    log(f"  [{be.name}:{op}/{prec}] done in {wall:.0f}s; "
        f"best={sub.model_name}")
    return report


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="runs/adsala")
    p.add_argument("--backend", default=DEFAULT_BACKEND)
    p.add_argument("--ops", default="gemm,symm,syrk,syr2k,trmm,trsm")
    p.add_argument("--precisions", default="s,d")
    p.add_argument("--samples", type=int, default=100)
    p.add_argument("--dim-lo", type=int, default=32)
    p.add_argument("--dim-hi", type=int, default=512)
    p.add_argument("--footprint-mb", type=float, default=6.0)
    p.add_argument("--sizes", default="64,128,256")
    p.add_argument("--tune-trials", type=int, default=3)
    p.add_argument("--candidates", default="")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    sizes = tuple(int(s) for s in args.sizes.split(","))
    cands = tuple(c for c in args.candidates.split(",") if c) or None
    # merge with any prior report (partial recalibrations replace their rows)
    report_path = out / "calibration_report.json"
    reports = []
    if report_path.exists():
        reports = json.loads(report_path.read_text())
    for op in args.ops.split(","):
        for prec in args.precisions.split(","):
            print(f"[calibrate] {args.backend}:{op}/{prec} ...",
                  flush=True)
            entry = calibrate_one(
                op, prec, out, backend=args.backend,
                samples=args.samples, dim_lo=args.dim_lo,
                dim_hi=args.dim_hi, footprint_mb=args.footprint_mb,
                sizes=sizes, tune_trials=args.tune_trials, seed=args.seed,
                candidates=cands,
                log=lambda m: print(m, flush=True))
            reports = [r for r in reports
                       if not (r["op"] == op and r["prec"] == prec
                               and r.get("backend",
                                         DEFAULT_BACKEND) == args.backend)]
            reports.append(entry)
            (out / "calibration_report.json").write_text(
                json.dumps(reports, indent=2))
    print(f"[calibrate] all done → {out}/calibration_report.json", flush=True)


if __name__ == "__main__":
    sys.exit(main())
