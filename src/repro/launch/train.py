"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt runs/ckpt

Composes the full substrate: model zoo (--arch), deterministic resumable
data pipeline, AdamW (+ optional int8 gradient compression with error
feedback), sharded async atomic checkpointing with auto-resume, preemption
guard, straggler detection, and bounded retry with elastic re-mesh.  On the
CPU container use --smoke (reduced config); the same driver drives the
production mesh on real hardware.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticLMDataset, make_global_batch
from repro.distributed import (PreemptionGuard, RetryPolicy,
                               StragglerDetector, best_mesh)
from repro.launch.mesh import batch_axes
from repro.launch.specs import (abstract_train_state, param_specs,
                                rules_for, tree_shardings)
from repro.models import init_params, loss_fn
from repro.optim import (AdamWConfig, adamw_update, compress_decompress,
                         init_adamw, init_error_feedback)

__all__ = ["TrainLoop", "main"]


@dataclasses.dataclass
class TrainLoop:
    cfg: object
    adamw: AdamWConfig
    mesh: object
    ckpt: Checkpointer
    dataset: object
    grad_compression: bool = False
    ckpt_every: int = 50
    log_every: int = 10
    straggler: StragglerDetector = dataclasses.field(
        default_factory=StragglerDetector)

    def __post_init__(self):
        self.rules = rules_for(self.mesh, "train")
        self._build_step()

    def _build_step(self):
        cfg, mesh, rules, adamw = self.cfg, self.mesh, self.rules, self.adamw
        compress = self.grad_compression

        def train_step(params, opt_state, ef, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch, cfg, mesh=mesh, rules=rules),
                has_aux=True)(params)
            if compress:
                grads, ef = compress_decompress(grads, ef)
            params, opt_state, om = adamw_update(params, grads, opt_state,
                                                 adamw)
            return params, opt_state, ef, {"loss": loss, **metrics, **om}

        self.step_fn = jax.jit(train_step, donate_argnums=(0, 1, 2))

    # -- state ------------------------------------------------------------
    def init_state(self, seed: int = 0):
        with self.mesh:
            aparams, _, pspecs = abstract_train_state(self.cfg, self.rules,
                                                      self.mesh)
            shardings = tree_shardings(aparams, pspecs, self.mesh)
            params = jax.jit(
                partial(init_params, cfg=self.cfg),
                out_shardings=shardings)(jax.random.PRNGKey(seed))
            opt_state = init_adamw(params)
            ef = (init_error_feedback(params) if self.grad_compression
                  else {"_": jnp.zeros(())})
        return {"params": params, "opt": opt_state, "ef": ef}

    def restore_or_init(self, seed: int = 0):
        state = self.init_state(seed)
        step = self.ckpt.latest_step()
        if step is None:
            return 0, state
        restored = self.ckpt.restore(step, state)
        print(f"[train] resumed from step {step}", flush=True)
        return step, restored

    # -- loop ----------------------------------------------------------------
    def run(self, steps: int, *, guard: PreemptionGuard | None = None,
            start_step: int | None = None, state=None) -> dict:
        guard = guard or PreemptionGuard(install_handlers=False)
        if state is None:
            start_step, state = self.restore_or_init()
        step = start_step or 0
        history = []
        baxes = batch_axes(self.mesh)
        while step < steps:
            t0 = time.perf_counter()
            batch = make_global_batch(self.dataset.batch_at(step), self.mesh,
                                      baxes)
            with self.mesh:
                p, o, ef, metrics = self.step_fn(state["params"],
                                                 state["opt"], state["ef"],
                                                 batch)
            state = {"params": p, "opt": o, "ef": ef}
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if self.straggler.observe(dt):
                print(f"[train] straggler: step {step} took {dt:.2f}s "
                      f"(ewma {self.straggler.expected_step_seconds:.2f}s)",
                      flush=True)
            step += 1
            if step % self.log_every == 0 or step == steps:
                loss = float(metrics["loss"])
                history.append({"step": step, "loss": loss,
                                "sec_per_step": dt})
                print(f"[train] step {step} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)", flush=True)
            if step % self.ckpt_every == 0 or step == steps:
                self.ckpt.save_async(step, state)
            if guard.preempted:
                print("[train] preemption signal — checkpoint + clean exit",
                      flush=True)
                self.ckpt.save(step, state)
                break
        self.ckpt.wait()
        return {"final_step": step, "history": history, "state": state}


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="llama3-8b")
    p.add_argument("--smoke", action="store_true",
                   help="reduced same-family config (CPU-runnable)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt", default="runs/ckpt")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--model-parallel", type=int, default=1)
    p.add_argument("--grad-compression", action="store_true")
    p.add_argument("--max-retries", type=int, default=2)
    args = p.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dataset = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq,
                                 global_batch=args.batch)
    adamw = AdamWConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 5))
    guard = PreemptionGuard()

    def attempt(retry_i: int):
        # elastic: rebuild the mesh from live devices on every (re)try
        mesh = best_mesh(model_parallel=args.model_parallel)
        loop = TrainLoop(cfg=cfg, adamw=adamw, mesh=mesh,
                         ckpt=Checkpointer(args.ckpt),
                         dataset=dataset,
                         grad_compression=args.grad_compression,
                         ckpt_every=args.ckpt_every)
        return loop.run(args.steps, guard=guard)

    result = RetryPolicy(max_retries=args.max_retries).run(
        attempt,
        on_retry=lambda i, e, d: print(
            f"[train] attempt {i} failed ({e}); re-meshing in {d:.0f}s",
            flush=True))
    print(f"[train] done at step {result['final_step']}")
    return result


if __name__ == "__main__":
    main()
