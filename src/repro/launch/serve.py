"""Batched serving driver: continuous-batching prefill + decode.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --requests 8 --max-new 32

A minimal production-shaped server core: a request queue, batched prefill
(padded to the batch's max prompt), then step-synchronous batched decode
with greedy/temperature sampling and per-sequence stop handling.  The same
``prefill`` / ``decode_step`` functions are what the dry-run lowers for the
``prefill_32k`` / ``decode_32k`` / ``long_500k`` cells.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import (decode_step, init_decode_state, init_params,
                          prefill)
from repro.models.transformer import _run_encoder
from repro.models.layers import Ctx

__all__ = ["ServeSession", "main"]


@dataclasses.dataclass
class ServeSession:
    cfg: object
    params: dict
    max_len: int
    mesh: object = None
    rules: object = None

    def __post_init__(self):
        cfg = self.cfg
        self._prefill = jax.jit(
            lambda p, b, c: prefill(p, b, c, cfg, mesh=self.mesh,
                                    rules=self.rules),
            donate_argnums=(2,))
        self._decode = jax.jit(
            lambda p, t, c, e: decode_step(p, t, c, cfg, mesh=self.mesh,
                                           rules=self.rules, enc_out=e),
            donate_argnums=(2,), static_argnums=())

    def generate(self, prompts: np.ndarray, *, max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 frames: np.ndarray | None = None,
                 vision: np.ndarray | None = None) -> np.ndarray:
        """prompts: (B, S_prompt) int32 → (B, max_new) int32."""
        cfg = self.cfg
        B = prompts.shape[0]
        caches = init_decode_state(cfg, B, self.max_len,
                                   dtype=jnp.dtype(cfg.compute_dtype))
        batch = {"tokens": jnp.asarray(prompts)}
        enc_out = None
        if cfg.family == "audio":
            batch["frames"] = jnp.asarray(frames)
            enc_out = _run_encoder(self.params, batch["frames"], Ctx(cfg))
        if cfg.family == "vlm":
            batch["vision"] = jnp.asarray(vision)
        logits, caches = self._prefill(self.params, batch, caches)
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits[:, -1], temperature, key)
        for i in range(max_new):
            out.append(np.asarray(tok))
            logits, caches = self._decode(self.params, tok, caches, enc_out)
            key, sub = jax.random.split(key)
            tok = self._sample(logits[:, -1], temperature, sub)
        return np.concatenate(out, axis=1)

    @staticmethod
    def _sample(logits, temperature, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1)[:, None].astype(jnp.int32)


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="llama3-8b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    args = p.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    sess = ServeSession(cfg=cfg, params=params,
                        max_len=args.prompt_len + args.max_new + 8)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           size=(args.requests, args.prompt_len),
                           dtype=np.int32)
    kw = {}
    if cfg.family == "audio":
        kw["frames"] = rng.standard_normal(
            (args.requests, cfg.enc_seq, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        kw["vision"] = rng.standard_normal(
            (args.requests, cfg.vision_tokens, cfg.d_model)).astype(np.float32)
    t0 = time.perf_counter()
    out = sess.generate(prompts, max_new=args.max_new,
                        temperature=args.temperature, **kw)
    dt = time.perf_counter() - t0
    toks = args.requests * args.max_new
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. prefill+compile)")
    print(out[:, :12])
    return out


if __name__ == "__main__":
    main()
