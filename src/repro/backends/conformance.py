"""Cross-backend numeric conformance harness.

One reusable implementation of the check "does backend X compute op Y
correctly", shared by the pytest suite (``tests/test_backend_conformance.py``)
and the CLI gate (``scripts/check_backends.py``).

The oracle here is *pure numpy in float64* — deliberately independent of
every registered backend (including ``ref``, which is itself jnp-based and
therefore also under test).  Tolerances are per dtype: float32 absorbs
accumulation-order differences across blocked/stacked implementations;
float64 is held tight (backends that cannot execute f64 at full precision —
e.g. jax paths under the default no-x64 config — report it via
``Backend.supports_dtype`` and are skipped, not excused).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .registry import available_backends, get_backend

__all__ = ["DEFAULT_DIMS", "RAGGED_DIMS", "TOLERANCES", "ConformanceResult",
           "check_backend_op", "oracle", "run_conformance", "tolerance_for"]

#: tiny, deliberately non-block-aligned dims (exercise the padding paths)
DEFAULT_DIMS = {"gemm": (48, 32, 40), "symm": (48, 40), "syrk": (48, 32),
                "syr2k": (48, 32), "trmm": (48, 40), "trsm": (48, 40)}

#: ragged dims spanning a ragged *last* tile behind full tiles (129, 257),
#: a degenerate single-row problem (1, ...), and an off-multiple square
#: (300) — the edge-tile masks of the zero-copy kernels at their corners
#: (DEFAULT_DIMS never exceeds one block, so the last-tile masking with
#: full tiles before it was previously unexercised)
RAGGED_DIMS = {
    "gemm": ((129, 65, 257), (1, 300, 384), (300, 300, 300)),
    "symm": ((129, 257), (1, 384), (300, 300)),
    "syrk": ((129, 257), (1, 384), (300, 300)),
    "syr2k": ((129, 257), (1, 384), (300, 300)),
    "trmm": ((129, 257), (1, 384), (300, 300)),
    "trsm": ((129, 257), (1, 384), (300, 300)),
}

#: max relative error vs the f64 numpy oracle, keyed by operand dtype bytes
TOLERANCES = {4: 5e-4, 8: 1e-10}


def tolerance_for(dtype) -> float:
    return TOLERANCES[int(np.dtype(dtype).itemsize)]


def _sym_lower(a: np.ndarray) -> np.ndarray:
    lo = np.tril(a)
    return lo + np.tril(a, -1).T


def oracle(op: str, operands: tuple) -> np.ndarray:
    """BLAS semantics (paper Table I) in plain numpy at float64."""
    xs = [np.asarray(x, np.float64) for x in operands]
    if op == "gemm":
        return xs[0] @ xs[1]
    if op == "symm":
        return _sym_lower(xs[0]) @ xs[1]
    if op == "syrk":
        return xs[0] @ xs[0].T
    if op == "syr2k":
        return xs[0] @ xs[1].T + xs[1] @ xs[0].T
    if op == "trmm":
        return np.tril(xs[0]) @ xs[1]
    if op == "trsm":
        return np.linalg.solve(np.tril(xs[0]), xs[1])
    raise ValueError(op)


@dataclasses.dataclass
class ConformanceResult:
    backend: str
    op: str
    dtype: str
    dims: tuple[int, ...]
    stacked: int            # 0 = single 2-D call, >0 = stack width
    rel_err: float = float("nan")
    ok: bool = False
    skipped: str | None = None      # reason, when not executed
    error: str | None = None        # exception repr, when execution raised

    def line(self) -> str:
        tag = f"{self.backend}:{self.op}:{self.dtype}" + \
            (f":x{self.stacked}" if self.stacked else "")
        if self.skipped:
            return f"{tag} SKIP ({self.skipped})"
        if self.error:
            return f"{tag} ERROR {self.error}"
        return (f"{tag} dims={self.dims} relerr={self.rel_err:.2e} "
                f"{'ok' if self.ok else 'MISMATCH'}")


def check_backend_op(backend: str, op: str, dtype=np.float32, *,
                     dims: tuple[int, ...] | None = None,
                     tol: float | None = None, stacked: int = 0,
                     seed: int = 0) -> ConformanceResult:
    """Run one (backend, op, dtype) instance against the numpy oracle.

    ``stacked > 0`` exercises ``Backend.execute_stacked`` with that stack
    width (each slice gets distinct operands) instead of a single 2-D call.
    """
    be = get_backend(backend)
    dims = tuple(dims) if dims is not None else DEFAULT_DIMS[op]
    dtype = np.dtype(dtype)
    res = ConformanceResult(backend=backend, op=op, dtype=dtype.name,
                            dims=dims, stacked=stacked)
    if not be.is_available():
        res.skipped = "backend unavailable on host"
        return res
    if not be.supports_dtype(dtype):
        res.skipped = f"{dtype.name} unsupported"
        return res
    tol = tol if tol is not None else tolerance_for(dtype)
    try:
        knob = be.default_knob(op)
        if stacked:
            items = [be.make_operands(op, dims, dtype, seed=seed + i)
                     for i in range(stacked)]
            operands = tuple(np.stack([it[i] for it in items])
                             for i in range(len(items[0])))
            got = np.asarray(be.execute_stacked(
                op, be.prepare(operands), knob))
            want = np.stack([oracle(op, it) for it in items])
        else:
            operands = be.make_operands(op, dims, dtype, seed=seed)
            got = np.asarray(be.execute(op, be.prepare(operands), knob))
            want = oracle(op, operands)
    except Exception as e:   # noqa: BLE001 — report, don't crash the sweep
        res.error = f"{type(e).__name__}: {e}"
        return res
    if got.shape != want.shape:     # before the subtraction: a wrong shape
        res.error = f"shape {got.shape} != {want.shape}"    # may not even
        return res                                          # broadcast
    res.rel_err = float(np.max(np.abs(np.asarray(got, np.float64) - want)) /
                        (np.max(np.abs(want)) + 1e-9))
    res.ok = res.rel_err < tol
    return res


def run_conformance(backends=None, ops=None, dtypes=(np.float32, np.float64),
                    *, tol: float | None = None, stacked_width: int = 0,
                    ragged: bool = False) -> list[ConformanceResult]:
    """The full sweep: every backend × its ops × dtypes (+ optionally the
    stacked path at ``stacked_width``); ``ragged`` additionally sweeps every
    cell over :data:`RAGGED_DIMS` (non-block-multiple shapes, stacked and
    unstacked).  Returns one result per cell."""
    names = tuple(backends) if backends else available_backends()
    results = []
    for name in names:
        be = get_backend(name)
        for op in (tuple(ops) if ops else be.ops()):
            for dtype in dtypes:
                dims_sweep = [None]
                if ragged:
                    dims_sweep += list(RAGGED_DIMS[op])
                for dims in dims_sweep:
                    results.append(check_backend_op(name, op, dtype,
                                                    dims=dims, tol=tol))
                    if stacked_width:
                        results.append(check_backend_op(
                            name, op, dtype, dims=dims, tol=tol,
                            stacked=stacked_width))
    return results
