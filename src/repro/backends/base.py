"""The ``Backend`` protocol — one pluggable "black-box BLAS" implementation.

The paper demonstrates the same ML runtime-selection mechanism on two baseline
BLAS libraries (MKL and BLIS); this repo generalises that to any executable
L3 implementation.  A backend bundles everything the ADSALA pipeline needs to
treat an implementation as a tunable black box:

  * ``ops()``          — the subroutines it can execute,
  * ``knob_space(op)`` — its discrete per-op runtime-config candidates
                         (the ``nt`` analogue; here Pallas/cache block shapes),
  * ``default_knob(op)`` — the paper's baseline config (max parallelism),
  * ``timer_fn(op, dtype)`` — a wall-clock timer for install-time calibration,
  * ``execute(op, operands, knob)`` — run the op under a chosen config.

Install-time tuning (:func:`repro.core.tuner.install_backend`), persistence
(:class:`repro.core.registry.ModelRegistry`), runtime decisions
(:class:`repro.core.runtime.AdsalaRuntime`) and dispatch
(:func:`repro.kernels.ops.run_op`) are all keyed by ``backend.name``, so one
process can hold tuned model sets for several implementations side by side —
the repo analogue of the paper's MKL-vs-BLIS comparison on a single harness.
"""

from __future__ import annotations

import abc
from typing import Callable

import numpy as np

from repro.core.features import SUBROUTINE_NDIMS
from repro.core.knobs import Knob, KnobSpace
from repro.core.timing import time_callable

__all__ = ["Backend", "L3_OPS"]

#: the six BLAS L3 subroutines of paper Table I
L3_OPS = ("gemm", "symm", "syrk", "syr2k", "trmm", "trsm")

#: dims used to rank candidate parallelism when picking the baseline knob
_BASELINE_DIMS = (4096, 4096, 4096)


class Backend(abc.ABC):
    """One executable BLAS L3 implementation with a tunable knob space."""

    #: registry key; also the tag on persisted artifacts and runtime caches
    name: str = "abstract"

    #: True for backends whose executors resolve their own knob (e.g. at jit
    #: trace time) when ``execute`` receives ``knob=None`` — generic dispatch
    #: then skips its pre-selection and forwards the runtime through
    selects_own_knob: bool = False

    #: True when execute_stacked compiles one executable per batch width —
    #: the serving layer then pads buckets to canonical widths to bound the
    #: compile set.  Loop-based backends gain nothing from padding (the
    #: filler rows would just run as extra full ops), so they leave it False.
    jit_stacked: bool = False

    # -- capability ----------------------------------------------------------
    def ops(self) -> tuple[str, ...]:
        return L3_OPS

    def is_available(self) -> bool:
        """Whether this backend can execute on the current host."""
        return True

    def supports_dtype(self, dtype) -> bool:
        """Whether this backend executes ``dtype`` at full precision (the
        conformance gate skips unsupported combinations instead of holding
        them to a tolerance they cannot meet)."""
        return True

    # -- knob space ----------------------------------------------------------
    @abc.abstractmethod
    def knob_space(self, op: str, *,
                   sizes: tuple[int, ...] | None = None) -> KnobSpace:
        """Candidate execution configs for ``op`` on this backend."""

    def default_knob(self, op: str) -> Knob:
        """Baseline config (paper: max threads) = max parallelism."""
        space = self.knob_space(op)
        dims = _BASELINE_DIMS[: SUBROUTINE_NDIMS[op]]
        return space.candidates[int(np.argmax(
            [space.parallelism(c, dims) for c in space.candidates]))]

    # -- execution -----------------------------------------------------------
    @abc.abstractmethod
    def execute(self, op: str, operands: tuple, knob: Knob | None = None,
                **kw):
        """Run ``op`` on ``operands`` under ``knob`` (backend default if
        ``None``); returns the result array."""

    def execute_stacked(self, op: str, operands: tuple,
                        knob: Knob | None = None, **kw):
        """Run ``op`` over operands carrying a leading batch axis — the
        serving layer's bucket-execution primitive (all requests in a bucket
        share dims/dtype, so one knob covers the whole stack).

        Operands of one-lower rank than the stack are *shared* across it
        (a 2-D weight against batched activations) and pass through whole.

        The base implementation unstacks, loops :meth:`execute`, and
        restacks; backends that can execute a stack natively (vmap, batched
        BLAS, strided GEMM) override this with the one-call version.
        """
        batch = int(operands[0].shape[0])
        rank = operands[0].ndim
        outs = [self.execute(op,
                             tuple(x[i] if getattr(x, "ndim", rank) == rank
                                   else x for x in operands), knob, **kw)
                for i in range(batch)]
        return np.stack([np.asarray(o) for o in outs])

    def make_operands(self, op: str, dims: tuple[int, ...],
                      dtype=np.float32, seed: int = 0) -> tuple:
        """Random operands of the right shapes (calibration inputs).  Seeded
        identically across backends so cross-backend checks compare the same
        problem instance."""
        from repro.kernels.cpu_blocked import make_operands
        return make_operands(op, dims, dtype, seed)

    def prepare(self, operands: tuple) -> tuple:
        """Convert operands to this backend's native array type (hook so
        timers exclude one-time host↔device transfer)."""
        return operands

    # -- calibration ---------------------------------------------------------
    def timer_fn(self, op: str, dtype=np.float32, *, warmup: int = 1,
                 repeats: int = 2) -> Callable[[tuple, Knob], float]:
        """``timer(dims, knob) -> seconds`` for the install-time sweep, with
        operand caching across the per-dims knob sweep."""
        cache: dict = {"dims": None, "operands": None}

        def timer(dims: tuple, knob: Knob) -> float:
            if cache["dims"] != dims:
                cache["dims"] = dims
                cache["operands"] = self.prepare(self.make_operands(
                    op, dims, dtype, seed=hash(dims) % (2 ** 31)))
            operands = cache["operands"]
            return time_callable(lambda: self.execute(op, operands, knob),
                                 warmup=warmup, repeats=repeats)

        return timer

    def __repr__(self) -> str:
        return f"{type(self).__name__}(name={self.name!r})"
