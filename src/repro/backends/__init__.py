"""Pluggable multi-backend execution layer (paper: MKL-vs-BLIS generality).

The :class:`Backend` protocol abstracts one BLAS L3 implementation; the
module-level registry holds the process's backends and implements the
requested→ref fallback chain.  The three built-ins are registered on import:

  pallas       — Pallas TPU kernels (interpret mode on CPU hosts)
  cpu_blocked  — numpy blocked BLAS (the host-measurable black box)
  ref          — pure-jnp oracle (always available; fallback terminal)
"""

from .base import Backend, L3_OPS
from .cpu import CpuBlockedBackend
from .pallas import PallasBackend
from .ref import RefBackend
from .registry import (FALLBACK_BACKEND, available_backends,
                       degradation_chain, fallback_chain, fallback_counts,
                       get_backend, register_backend, reset_fallback_counts,
                       resolve_backend, unregister_backend)

__all__ = [
    "Backend", "L3_OPS", "RefBackend", "CpuBlockedBackend", "PallasBackend",
    "register_backend", "unregister_backend", "get_backend",
    "available_backends", "resolve_backend", "fallback_chain",
    "degradation_chain", "fallback_counts", "reset_fallback_counts",
    "FALLBACK_BACKEND",
]


def _install_builtins() -> None:
    for cls in (RefBackend, CpuBlockedBackend, PallasBackend):
        be = cls()
        if be.name not in available_backends():
            register_backend(be)


_install_builtins()
