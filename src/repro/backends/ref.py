"""The ``ref`` backend: pure-jnp oracle semantics, always available.

It is the terminal element of every fallback chain and the ground truth for
``scripts/check_backends.py``.  Its knob space is a single no-op candidate so
the tuner/runtime machinery stays total over it (a registered but knob-free
backend exercises the same code paths with K=1).
"""

from __future__ import annotations

import numpy as np

from repro.core.knobs import Knob, KnobSpace, _grid_parallelism

from .base import Backend

__all__ = ["RefBackend"]


def _jax_supports(dtype) -> bool:
    """64-bit floats silently degrade to f32 under jax's default config —
    report them unsupported rather than serving degraded precision."""
    if np.dtype(dtype).itemsize < 8:
        return True
    try:
        import jax
        return bool(jax.config.jax_enable_x64)
    except Exception:
        return False


class RefBackend(Backend):
    name = "ref"
    jit_stacked = True      # one jitted executable per (shape, width)

    def __init__(self) -> None:
        # jitted executors keyed (op, scalar kwargs); jax.jit then re-caches
        # per operand shape.  One XLA dispatch per call instead of one per
        # jnp expression — this is what makes the serving path's "one stacked
        # launch per bucket" an actual single launch.
        self._jitted: dict = {}

    def knob_space(self, op: str, *,
                   sizes: tuple[int, ...] | None = None) -> KnobSpace:
        edge = (sizes or (128,))[0]
        return KnobSpace("blocks",
                         [{"bm": edge, "bk": edge, "bn": edge,
                           "variant": "full"}],
                         parallelism_fn=_grid_parallelism)

    def supports_dtype(self, dtype) -> bool:
        return _jax_supports(dtype)

    #: bound on distinct (op, scalar-kwargs) executables kept around —
    #: per-request scaling factors must not grow the cache without limit
    _JIT_CACHE_MAX = 256

    def _executor(self, op: str, kw: dict):
        key = (op, tuple(sorted(kw.items())))
        fn = self._jitted.get(key)
        if fn is None:
            import jax
            from repro.kernels.ref import REFS
            ref_fn = REFS[op]
            if len(self._jitted) >= self._JIT_CACHE_MAX:
                self._jitted.clear()
            fn = self._jitted.setdefault(
                key, jax.jit(lambda *xs: ref_fn(*xs, **kw)))
        return fn

    def execute(self, op: str, operands: tuple, knob: Knob | None = None,
                **kw):
        kw.pop("interpret", None)   # oracle has no kernel-mode switch
        return self._executor(op, kw)(*operands)

    def execute_stacked(self, op: str, operands: tuple,
                        knob: Knob | None = None, **kw):
        # the jnp oracles broadcast over leading axes (matmul/tril/solve are
        # all batch-aware), so a stack executes as one jitted XLA call
        return self.execute(op, operands, knob, **kw)
