"""The ``ref`` backend: pure-jnp oracle semantics, always available.

It is the terminal element of every fallback chain and the ground truth for
``scripts/check_backends.py``.  Its knob space is a single no-op candidate so
the tuner/runtime machinery stays total over it (a registered but knob-free
backend exercises the same code paths with K=1).
"""

from __future__ import annotations

from repro.core.knobs import Knob, KnobSpace, _grid_parallelism

from .base import Backend

__all__ = ["RefBackend"]


class RefBackend(Backend):
    name = "ref"

    def knob_space(self, op: str, *,
                   sizes: tuple[int, ...] | None = None) -> KnobSpace:
        edge = (sizes or (128,))[0]
        return KnobSpace("blocks",
                         [{"bm": edge, "bk": edge, "bn": edge,
                           "variant": "full"}],
                         parallelism_fn=_grid_parallelism)

    def execute(self, op: str, operands: tuple, knob: Knob | None = None,
                **kw):
        from repro.kernels.ref import REFS
        kw.pop("interpret", None)   # oracle has no kernel-mode switch
        return REFS[op](*operands, **kw)
