"""Process-global backend registry with a graceful fallback chain.

``resolve_backend(name)`` implements the dispatch policy used by
:func:`repro.kernels.ops.run_op`: the requested backend if registered and
available on this host, otherwise the ``ref`` backend (numpy/jnp reference —
always executable), so a caller asking for an absent accelerator path still
gets a correct result instead of a crash.
"""

from __future__ import annotations

import threading

from .base import Backend

__all__ = ["register_backend", "unregister_backend", "get_backend",
           "available_backends", "resolve_backend", "fallback_chain",
           "degradation_chain", "fallback_counts", "count_fallback",
           "reset_fallback_counts", "FALLBACK_BACKEND"]

#: terminal element of every fallback chain — must always be registered
FALLBACK_BACKEND = "ref"

#: execution-time degradation preference (the serving resilience ladder):
#: a failing backend is retried down this order, requested backend first,
#: then every *later* entry, then the always-executable ``ref`` terminal —
#: pallas degrades through cpu_blocked before giving up the knobs entirely
DEGRADE_ORDER = ("pallas", "cpu_blocked")

_REGISTRY: dict[str, Backend] = {}

#: registry mutation counter: resolve_backend memoizes (name → backend)
#: stamped with the generation it was computed under, so registering or
#: unregistering any backend invalidates every memoized resolution without
#: a scan.  Resolution sits on the per-call BLAS dispatch path — the memo
#: turns the chain walk + is_available() probe into one dict hit.
_GENERATION = 0
_RESOLVE_MEMO: dict[str, tuple[int, Backend]] = {}
_MUTATE_LOCK = threading.Lock()

#: resolve-time fallback accounting: (requested, resolved) -> count.  A
#: request silently degrading pallas→ref at resolution used to be invisible
#: in production — the numbers are surfaced through
#: ``AdsalaRuntime.stats.resolve_fallbacks`` so a fleet dashboard can tell
#: "pallas is serving" from "pallas is gone and ref is covering for it".
_FALLBACK_COUNTS: dict[tuple[str, str], int] = {}
_FALLBACK_LOCK = threading.Lock()


def count_fallback(requested: str, resolved: str) -> None:
    with _FALLBACK_LOCK:
        key = (requested, resolved)
        _FALLBACK_COUNTS[key] = _FALLBACK_COUNTS.get(key, 0) + 1


def fallback_counts() -> dict[tuple[str, str], int]:
    """Snapshot of resolve-time fallbacks per (requested, resolved) pair."""
    with _FALLBACK_LOCK:
        return dict(_FALLBACK_COUNTS)


def reset_fallback_counts() -> None:
    with _FALLBACK_LOCK:
        _FALLBACK_COUNTS.clear()


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    global _GENERATION
    with _MUTATE_LOCK:        # dict insert + generation bump move together
        if not overwrite and backend.name in _REGISTRY:
            raise ValueError(f"backend {backend.name!r} already registered")
        _REGISTRY[backend.name] = backend
        _GENERATION += 1
    return backend


def unregister_backend(name: str) -> None:
    global _GENERATION
    with _MUTATE_LOCK:
        _REGISTRY.pop(name, None)
        _GENERATION += 1


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"no backend {name!r}; registered: "
                       f"{available_backends()}") from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def fallback_chain(name: str) -> tuple[str, ...]:
    """The dispatch order for a requested backend name."""
    return (name,) if name == FALLBACK_BACKEND else (name, FALLBACK_BACKEND)


def degradation_chain(name: str) -> tuple[str, ...]:
    """The *execution-time* retry order for a backend whose launch failed:
    the requested backend, then every registered :data:`DEGRADE_ORDER`
    backend strictly after it, then the ``ref`` terminal.  A backend
    outside the order (``ref`` itself, custom plugins) degrades straight to
    ``ref`` — never *up* onto an accelerator path it did not ask for.
    Unlike the resolve-time :func:`fallback_chain` (availability at
    dispatch), this chain is walked only by the serving resilience ladder
    after a launch *crashed*."""
    order = [b for b in DEGRADE_ORDER if b in _REGISTRY]
    tail = order[order.index(name) + 1:] if name in order else []
    chain = [name] + [b for b in tail if b != name]
    if FALLBACK_BACKEND not in chain:
        chain.append(FALLBACK_BACKEND)
    return tuple(chain)


def resolve_backend(backend: str | Backend | None) -> Backend:
    """Requested backend → ref fallback; raises only if even ``ref`` is gone.

    Exact resolutions (requested backend registered and available) are
    memoized per name until the next registry mutation (``_GENERATION``);
    fallback resolutions and failures are never cached, and a memo hit
    still re-probes ``is_available()`` — availability that flips at
    runtime, in either direction, must change the outcome on the next
    call, exactly as the unmemoized chain walk would."""
    if isinstance(backend, Backend):
        return backend
    requested = backend or FALLBACK_BACKEND
    # snapshot the generation BEFORE walking the chain: a registration
    # racing the walk bumps the counter, and a result computed against the
    # older registry must not be stamped with the newer generation
    gen = _GENERATION
    memo = _RESOLVE_MEMO.get(requested)
    if memo is not None and memo[0] == gen and memo[1].is_available():
        return memo[1]
    for name in fallback_chain(requested):
        be = _REGISTRY.get(name)
        if be is not None and be.is_available():
            if name == requested:
                _RESOLVE_MEMO[requested] = (gen, be)
            else:
                # silent degradation made visible: every resolve-time
                # fallback is counted per (requested, resolved) pair
                count_fallback(requested, name)
            return be
    raise KeyError(f"no executable backend for {backend!r} "
                   f"(registered: {available_backends()})")
