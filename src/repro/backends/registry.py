"""Process-global backend registry with a graceful fallback chain.

``resolve_backend(name)`` implements the dispatch policy used by
:func:`repro.kernels.ops.run_op`: the requested backend if registered and
available on this host, otherwise the ``ref`` backend (numpy/jnp reference —
always executable), so a caller asking for an absent accelerator path still
gets a correct result instead of a crash.
"""

from __future__ import annotations

import threading

from .base import Backend

__all__ = ["register_backend", "unregister_backend", "get_backend",
           "available_backends", "resolve_backend", "fallback_chain",
           "FALLBACK_BACKEND"]

#: terminal element of every fallback chain — must always be registered
FALLBACK_BACKEND = "ref"

_REGISTRY: dict[str, Backend] = {}

#: registry mutation counter: resolve_backend memoizes (name → backend)
#: stamped with the generation it was computed under, so registering or
#: unregistering any backend invalidates every memoized resolution without
#: a scan.  Resolution sits on the per-call BLAS dispatch path — the memo
#: turns the chain walk + is_available() probe into one dict hit.
_GENERATION = 0
_RESOLVE_MEMO: dict[str, tuple[int, Backend]] = {}
_MUTATE_LOCK = threading.Lock()


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    global _GENERATION
    with _MUTATE_LOCK:        # dict insert + generation bump move together
        if not overwrite and backend.name in _REGISTRY:
            raise ValueError(f"backend {backend.name!r} already registered")
        _REGISTRY[backend.name] = backend
        _GENERATION += 1
    return backend


def unregister_backend(name: str) -> None:
    global _GENERATION
    with _MUTATE_LOCK:
        _REGISTRY.pop(name, None)
        _GENERATION += 1


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"no backend {name!r}; registered: "
                       f"{available_backends()}") from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def fallback_chain(name: str) -> tuple[str, ...]:
    """The dispatch order for a requested backend name."""
    return (name,) if name == FALLBACK_BACKEND else (name, FALLBACK_BACKEND)


def resolve_backend(backend: str | Backend | None) -> Backend:
    """Requested backend → ref fallback; raises only if even ``ref`` is gone.

    Exact resolutions (requested backend registered and available) are
    memoized per name until the next registry mutation (``_GENERATION``);
    fallback resolutions and failures are never cached, and a memo hit
    still re-probes ``is_available()`` — availability that flips at
    runtime, in either direction, must change the outcome on the next
    call, exactly as the unmemoized chain walk would."""
    if isinstance(backend, Backend):
        return backend
    requested = backend or FALLBACK_BACKEND
    # snapshot the generation BEFORE walking the chain: a registration
    # racing the walk bumps the counter, and a result computed against the
    # older registry must not be stamped with the newer generation
    gen = _GENERATION
    memo = _RESOLVE_MEMO.get(requested)
    if memo is not None and memo[0] == gen and memo[1].is_available():
        return memo[1]
    for name in fallback_chain(requested):
        be = _REGISTRY.get(name)
        if be is not None and be.is_available():
            if name == requested:
                _RESOLVE_MEMO[requested] = (gen, be)
            return be
    raise KeyError(f"no executable backend for {backend!r} "
                   f"(registered: {available_backends()})")
