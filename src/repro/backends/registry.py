"""Process-global backend registry with a graceful fallback chain.

``resolve_backend(name)`` implements the dispatch policy used by
:func:`repro.kernels.ops.run_op`: the requested backend if registered and
available on this host, otherwise the ``ref`` backend (numpy/jnp reference —
always executable), so a caller asking for an absent accelerator path still
gets a correct result instead of a crash.
"""

from __future__ import annotations

from .base import Backend

__all__ = ["register_backend", "unregister_backend", "get_backend",
           "available_backends", "resolve_backend", "fallback_chain",
           "FALLBACK_BACKEND"]

#: terminal element of every fallback chain — must always be registered
FALLBACK_BACKEND = "ref"

_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    if not overwrite and backend.name in _REGISTRY:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def unregister_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"no backend {name!r}; registered: "
                       f"{available_backends()}") from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def fallback_chain(name: str) -> tuple[str, ...]:
    """The dispatch order for a requested backend name."""
    return (name,) if name == FALLBACK_BACKEND else (name, FALLBACK_BACKEND)


def resolve_backend(backend: str | Backend | None) -> Backend:
    """Requested backend → ref fallback; raises only if even ``ref`` is gone."""
    if isinstance(backend, Backend):
        return backend
    for name in fallback_chain(backend or FALLBACK_BACKEND):
        be = _REGISTRY.get(name)
        if be is not None and be.is_available():
            return be
    raise KeyError(f"no executable backend for {backend!r} "
                   f"(registered: {available_backends()})")
