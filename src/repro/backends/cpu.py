"""The ``cpu_blocked`` backend: numpy blocked BLAS L3 (kernels.cpu_blocked).

This is the host-measurable black box of the original calibration path — the
same blocked algorithms the Pallas kernels run on TPU, expressed in numpy,
where the (bm, bk, bn) knob has real cache-hierarchy effects.  In the paper's
MKL-vs-BLIS comparison this plays the role of the second baseline library.
"""

from __future__ import annotations

import numpy as np

from repro.core.knobs import Knob, KnobSpace

from .base import Backend

__all__ = ["CpuBlockedBackend"]


class CpuBlockedBackend(Backend):
    name = "cpu_blocked"

    #: cache-scale block edges (vs the TPU backend's MXU-aligned 128..512)
    DEFAULT_SIZES = (64, 128, 256)

    def knob_space(self, op: str, *,
                   sizes: tuple[int, ...] | None = None) -> KnobSpace:
        from repro.kernels.ops import knob_space_for
        return knob_space_for(op, sizes=tuple(sizes or self.DEFAULT_SIZES))

    def prepare(self, operands: tuple) -> tuple:
        return tuple(np.asarray(x) for x in operands)

    def execute(self, op: str, operands: tuple, knob: Knob | None = None,
                **kw):
        from repro.kernels.cpu_blocked import run_blocked
        if knob is None:
            knob = self.default_knob(op)
        kw.pop("interpret", None)   # numpy path has no kernel-mode switch
        return run_blocked(op, self.prepare(operands), knob, **kw)
