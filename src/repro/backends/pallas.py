"""The ``pallas`` backend: the repo's Pallas TPU kernels (kernels.ops).

On a TPU host the kernels run compiled; on CPU-only hosts they run in
interpret mode (still jit-compiled, so post-warmup wall-clock is meaningful
for calibration at small scales).  The mode is auto-detected and can be
forced via the constructor.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.knobs import Knob, KnobSpace

from .base import Backend

__all__ = ["PallasBackend"]


def _host_has_tpu() -> bool:
    try:
        import jax
        return any(d.platform == "tpu" for d in jax.devices())
    except Exception:
        return False


class PallasBackend(Backend):
    name = "pallas"
    selects_own_knob = True     # ops.py selects at jit trace time
    jit_stacked = True          # vmap compiles per (shape, width)

    def __init__(self, *, interpret: bool | None = None) -> None:
        self.interpret = (not _host_has_tpu()) if interpret is None \
            else interpret

    def knob_space(self, op: str, *,
                   sizes: tuple[int, ...] | None = None) -> KnobSpace:
        from repro.kernels.ops import knob_space_for
        return knob_space_for(op, sizes=tuple(sizes) if sizes else None)

    def supports_dtype(self, dtype) -> bool:
        from .ref import _jax_supports
        return _jax_supports(dtype)

    def default_knob(self, op: str) -> Knob:
        from repro.kernels.ops import default_knob
        return default_knob(op)

    def prepare(self, operands: tuple) -> tuple:
        return tuple(jnp.asarray(x) for x in operands)

    def execute(self, op: str, operands: tuple, knob: Knob | None = None,
                **kw):
        from repro.kernels.ops import PALLAS_OPS
        kw.setdefault("interpret", self.interpret)
        return PALLAS_OPS[op](*operands, knob=knob, **kw)

    def execute_stacked(self, op: str, operands: tuple,
                        knob: Knob | None = None, **kw):
        from repro.kernels.ops import PALLAS_OPS
        kw.setdefault("interpret", self.interpret)
        # the kernels take the leading batch axis natively — it becomes the
        # leading (parallel) grid dimension of ONE pallas_call, replacing
        # the old jax.vmap lift; the knob decision still runs once at trace
        # time for the whole stack
        return PALLAS_OPS[op](*(jnp.asarray(x) for x in operands),
                              knob=knob, **kw)
