"""Distributed runtime: fault tolerance, elastic re-mesh, pipeline parallel."""
from .fault_tolerance import PreemptionGuard, RetryPolicy, StragglerDetector
from .elastic import FleetMembership, abstract_like, best_mesh, reshard
from .pipeline import bubble_fraction, gpipe_forward
__all__ = ["PreemptionGuard", "RetryPolicy", "StragglerDetector",
           "FleetMembership", "abstract_like", "best_mesh", "reshard",
           "bubble_fraction", "gpipe_forward"]
