"""Elastic scaling: rebuild the mesh from whatever devices are alive and
reshard state onto it.

Checkpoints are mesh-agnostic (checkpoint/checkpointer.py saves gathered
values + logical structure), so elasticity is:

    mesh' = best_mesh(available_devices)
    target' = abstract state tree with shardings from mesh'
    state' = checkpointer.restore(step, target')

``best_mesh`` picks the largest (data, model) factorisation with model ≤
requested TP degree; ``reshard`` moves live (non-checkpoint) pytrees between
meshes directly via device_put (for downsizing without a restart).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

__all__ = ["best_mesh", "reshard", "abstract_like"]


def best_mesh(devices=None, *, model_parallel: int = 1,
              axis_names=("data", "model")) -> Mesh:
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    tp = model_parallel
    while tp > 1 and n % tp != 0:
        tp //= 2
    dp = n // tp
    arr = np.array(devices[: dp * tp]).reshape(dp, tp)
    return Mesh(arr, axis_names)


def abstract_like(tree, mesh: Mesh, spec_fn):
    """ShapeDtypeStruct tree with shardings on ``mesh``; ``spec_fn(path,
    leaf) -> PartitionSpec``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        spec = spec_fn(path, leaf)
        out.append(jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                        sharding=NamedSharding(mesh, spec)))
    return treedef.unflatten(out)


def reshard(tree, mesh: Mesh, spec_fn):
    """Move a live pytree onto a (different) mesh."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        spec = spec_fn(path, leaf)
        out.append(jax.device_put(leaf, NamedSharding(mesh, spec)))
    return treedef.unflatten(out)
