"""Elastic scaling: rebuild the mesh from whatever devices are alive and
reshard state onto it — plus the file-based membership registry the
multi-process serving fleet coordinates through.

Checkpoints are mesh-agnostic (checkpoint/checkpointer.py saves gathered
values + logical structure), so elasticity is:

    mesh' = best_mesh(available_devices)
    target' = abstract state tree with shardings from mesh'
    state' = checkpointer.restore(step, target')

``best_mesh`` picks the largest (data, model) factorisation with model ≤
requested TP degree; ``reshard`` moves live (non-checkpoint) pytrees between
meshes directly via device_put (for downsizing without a restart).

jax is imported lazily inside the mesh helpers: serving-fleet executor
processes import this module only for :class:`FleetMembership`, and paying
a jax import (seconds) per spawned executor for a membership file would
dominate fleet startup.
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

from repro.core.durable import atomic_write_bytes

__all__ = ["best_mesh", "reshard", "abstract_like", "FleetMembership"]


def best_mesh(devices=None, *, model_parallel: int = 1,
              axis_names=("data", "model")):
    import jax
    from jax.sharding import Mesh
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    tp = model_parallel
    while tp > 1 and n % tp != 0:
        tp //= 2
    dp = n // tp
    arr = np.array(devices[: dp * tp]).reshape(dp, tp)
    return Mesh(arr, axis_names)


def abstract_like(tree, mesh, spec_fn):
    """ShapeDtypeStruct tree with shardings on ``mesh``; ``spec_fn(path,
    leaf) -> PartitionSpec``."""
    import jax
    from jax.sharding import NamedSharding
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        spec = spec_fn(path, leaf)
        out.append(jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                        sharding=NamedSharding(mesh, spec)))
    return treedef.unflatten(out)


def reshard(tree, mesh, spec_fn):
    """Move a live pytree onto a (different) mesh."""
    import jax
    from jax.sharding import NamedSharding
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        spec = spec_fn(path, leaf)
        out.append(jax.device_put(leaf, NamedSharding(mesh, spec)))
    return treedef.unflatten(out)


class FleetMembership:
    """File-based membership registry for a serving fleet.

    One JSON file per member under ``root`` (conventionally
    ``<registry>/members/``), written atomically so a reader never sees a
    torn record.  Liveness is heartbeat-based: a member rewrites its file
    (fresh wall-clock stamp) on its poll tick, and :meth:`members` treats
    anything older than ``stale_s`` as dead — a SIGKILLed executor
    disappears from the roster without anyone cleaning up after it.  This
    is deliberately the weakest coordination primitive that works on a
    shared filesystem (local fleet today, NFS-mounted multi-host registry
    tomorrow): no daemon, no locks, idempotent registration.
    """

    def __init__(self, root: str | Path, *, stale_s: float = 30.0) -> None:
        if stale_s <= 0:
            raise ValueError("stale_s must be > 0")
        self.root = Path(root)
        self.stale_s = float(stale_s)

    def _path(self, name: str) -> Path:
        safe = "".join(c if c.isalnum() or c in "-_." else "_"
                       for c in str(name)) or "member"
        return self.root / f"{safe}.json"

    def register(self, name: str, **meta) -> Path:
        """(Re)announce a member; extra keyword facts (pid, fingerprint
        slug, ...) ride along in its record."""
        record = {"name": str(name), "pid": os.getpid(),
                  "t": time.time(), **meta}
        path = self._path(name)
        atomic_write_bytes(path, json.dumps(
            record, sort_keys=True).encode("utf-8"))
        return path

    def heartbeat(self, name: str, **meta) -> None:
        """Refresh the member's liveness stamp (same write as register)."""
        self.register(name, **meta)

    def members(self, *, live_only: bool = True) -> list[dict]:
        """Current roster, sorted by name; with ``live_only`` (default)
        members whose heartbeat is older than ``stale_s`` are dropped.
        Torn/corrupt records are skipped, never raised."""
        if not self.root.is_dir():
            return []
        now = time.time()
        out = []
        for path in sorted(self.root.glob("*.json")):
            try:
                rec = json.loads(path.read_text())
            except (ValueError, OSError):
                continue
            if not isinstance(rec, dict):
                continue
            if live_only and now - float(rec.get("t", 0)) > self.stale_s:
                continue
            out.append(rec)
        return out

    def deregister(self, name: str) -> None:
        try:
            self._path(name).unlink()
        except FileNotFoundError:
            pass
