"""Pipeline parallelism: GPipe-style microbatch schedule over a stage axis.

For the assigned model sizes on a 256-chip pod, DP×TP(×EP) is the efficient
regime (stage bubbles would waste >10% at these depths), so the dry-runs use
DP×TP; this module provides the PP schedule as a first-class option for
deeper-than-memory models and is exercised by tests on a small mesh.

Implementation: the layer stack is split into S stages; each microbatch
flows stage-by-stage under ``shard_map`` over the ``stage`` mesh axis with
``jax.lax.ppermute`` moving activations to the next stage.  The classic
GPipe schedule runs S + M - 1 ticks for M microbatches; bubble fraction
(S-1)/(S+M-1).
"""

from __future__ import annotations

import functools
import inspect

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:                       # older jax: pre-promotion API
    from jax.experimental.shard_map import shard_map as _shard_map

# the replication-check kwarg was renamed check_rep → check_vma across jax
_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")

__all__ = ["gpipe_forward", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_stages + n_microbatches - 1)


def gpipe_forward(stage_fn, params_per_stage, x, *, mesh: Mesh,
                  n_microbatches: int, stage_axis: str = "stage"):
    """Run ``stage_fn(stage_params, x)`` through S pipeline stages.

    params_per_stage: pytree with leading stage axis (sharded over
    ``stage_axis``).  x: (B, ...) global batch; B must divide into
    ``n_microbatches``.  Returns the pipeline output (same shape as x).
    """
    S = mesh.shape[stage_axis]
    B = x.shape[0]
    assert B % n_microbatches == 0
    mb = B // n_microbatches
    micro = x.reshape(n_microbatches, mb, *x.shape[1:])

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        **{_CHECK_KW: False})
    def run(stage_params, micro_all):
        stage_params = jax.tree.map(lambda t: t[0], stage_params)
        sid = jax.lax.axis_index(stage_axis)
        n_ticks = S + n_microbatches - 1
        buf = jnp.zeros((mb,) + micro_all.shape[2:], micro_all.dtype)
        outs = jnp.zeros_like(micro_all)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if in range)
            mb_idx = jnp.clip(t, 0, n_microbatches - 1)
            inject = jnp.where(t < n_microbatches,
                               micro_all[mb_idx],
                               jnp.zeros_like(buf))
            cur = jnp.where(sid == 0, inject, buf)
            y = stage_fn(stage_params, cur)
            # last stage emits microbatch t-(S-1)
            out_idx = jnp.clip(t - (S - 1), 0, n_microbatches - 1)
            emit = (sid == S - 1) & (t >= S - 1)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, out_idx, 0),
                lambda o: o, outs)
            # rotate activations to the next stage
            nxt = jax.lax.ppermute(
                y, stage_axis, [(i, (i + 1) % S) for i in range(S)])
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs),
                                    jnp.arange(n_ticks))
        # every stage holds `outs`; only the last stage's copy is real —
        # broadcast it (psum of masked copies)
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), stage_axis)
        return outs

    out = run(params_per_stage, micro)
    return out.reshape(B, *x.shape[1:])
