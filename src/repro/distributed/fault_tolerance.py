"""Fault-tolerance runtime: preemption handling, straggler detection,
bounded retry with re-mesh — the glue that makes the training loop survive
node failures on 1000+-node clusters.

Components:
  * PreemptionGuard — SIGTERM/SIGINT sets a flag; the train loop checkpoints
    and exits cleanly at the next step boundary (standard TPU preemption
    contract: ~30 s grace).
  * StragglerDetector — EWMA of step wall-time; a step exceeding
    ``threshold × ewma`` is flagged.  On real multi-host deployments the
    flag triggers the re-mesh path (here it is logged and counted; the
    decision logic is what is being exercised).
  * RetryPolicy — bounded restarts with exponential backoff; each retry
    re-enters the elastic re-mesh + restore-latest-checkpoint path.
"""

from __future__ import annotations

import dataclasses
import signal
import time

__all__ = ["PreemptionGuard", "StragglerDetector", "RetryPolicy"]


class PreemptionGuard:
    def __init__(self, install_handlers: bool = True) -> None:
        self._preempted = False
        self._prev = {}
        if install_handlers:
            for sig in (signal.SIGTERM,):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:   # non-main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self._preempted = True

    @property
    def preempted(self) -> bool:
        return self._preempted

    def simulate(self) -> None:    # test hook
        self._preempted = True


@dataclasses.dataclass
class StragglerDetector:
    threshold: float = 3.0
    ewma_alpha: float = 0.2
    min_steps: int = 5

    _ewma: float = 0.0
    _n: int = 0
    stragglers: int = 0

    def observe(self, step_seconds: float) -> bool:
        """Feed one step time; returns True if this step straggled."""
        self._n += 1
        if self._n <= self.min_steps:
            self._ewma = (step_seconds if self._ewma == 0.0 else
                          (1 - self.ewma_alpha) * self._ewma +
                          self.ewma_alpha * step_seconds)
            return False
        is_straggler = step_seconds > self.threshold * self._ewma
        if is_straggler:
            self.stragglers += 1
        else:   # don't poison the EWMA with straggler samples
            self._ewma = (1 - self.ewma_alpha) * self._ewma + \
                self.ewma_alpha * step_seconds
        return is_straggler

    @property
    def expected_step_seconds(self) -> float:
        return self._ewma


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 3
    backoff_base_s: float = 1.0
    backoff_cap_s: float = 60.0

    def run(self, fn, *, on_retry=None, sleep=time.sleep):
        """Run ``fn()``; on exception, back off and retry (fn re-enters via
        restore-latest, so work is never lost beyond the last checkpoint)."""
        attempt = 0
        while True:
            try:
                return fn(attempt)
            except Exception as e:      # noqa: BLE001 — deliberate catch-all
                attempt += 1
                if attempt > self.max_retries:
                    raise
                delay = min(self.backoff_base_s * 2 ** (attempt - 1),
                            self.backoff_cap_s)
                if on_retry is not None:
                    on_retry(attempt, e, delay)
                sleep(delay)
