"""repro — ADSALA-JAX: ML-driven runtime optimization of BLAS Level 3,
reproduced and extended as a TPU-native JAX training/serving framework."""

__version__ = "0.1.0"
