"""Fault-tolerant checkpointing: sharded, async, atomic, mesh-agnostic.

Layout: ``<root>/step_<n>/`` containing one raw-bytes file per pytree leaf
plus a msgpack ``manifest`` (tree structure, dtypes, shapes, logical specs).

Guarantees:
  * **atomicity** — written to ``step_<n>.tmp`` then os.replace'd; a crash
    mid-write can never yield a directory that ``latest_step`` will pick up;
  * **async** — ``save_async`` snapshots device arrays to host then writes
    on a background thread (training continues; ``wait()`` joins);
  * **mesh-agnostic restore** — leaves are saved *unsharded by value*
    (gathered) with their logical shape; ``restore`` device_puts each leaf
    with the sharding of a caller-supplied abstract target, so a checkpoint
    taken on a 512-chip mesh restores onto 8 chips or vice-versa: this is
    the elastic-rescale path;
  * **GC** — keep the newest ``keep`` checkpoints.

For 1000+-node scale the value-gather becomes per-host shard files keyed by
process index — the manifest format already carries the spec needed for
that; single-process here, so the gather path is exact and testable.
"""

from __future__ import annotations

import os
import shutil
import threading
from pathlib import Path

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

__all__ = ["Checkpointer"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class Checkpointer:
    def __init__(self, root: str | Path, *, keep: int = 3) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- discovery -----------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.root.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp"):
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save ------------------------------------------------------------------
    def save(self, step: int, tree) -> Path:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host_tree)

    def save_async(self, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> Path:
        final = self.root / f"step_{step}"
        tmp = self.root / f"step_{step}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        paths, leaves, _ = _flatten_with_paths(host_tree)
        manifest = []
        for i, (p, leaf) in enumerate(zip(paths, leaves)):
            arr = np.asarray(leaf)
            fname = f"leaf_{i:05d}.bin"
            (tmp / fname).write_bytes(arr.tobytes())
            manifest.append({"path": p, "file": fname,
                             "dtype": str(arr.dtype),
                             "shape": list(arr.shape)})
        (tmp / "manifest").write_bytes(msgpack.packb(
            {"step": step, "leaves": manifest}))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.root / f"step_{s}", ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def restore(self, step: int, target):
        """``target``: pytree of arrays or ShapeDtypeStructs (with .sharding
        set for resharded restore).  Returns the restored pytree."""
        d = self.root / f"step_{step}"
        manifest = msgpack.unpackb((d / "manifest").read_bytes(), raw=False)
        by_path = {m["path"]: m for m in manifest["leaves"]}
        paths, leaves, treedef = _flatten_with_paths(target)
        out = []
        for p, tgt in zip(paths, leaves):
            m = by_path[p]
            arr = np.frombuffer((d / m["file"]).read_bytes(),
                                dtype=m["dtype"]).reshape(m["shape"])
            sharding = getattr(tgt, "sharding", None)
            if sharding is not None and not isinstance(
                    sharding, jax.sharding.SingleDeviceSharding):
                out.append(jax.device_put(arr, sharding))
            else:
                out.append(jnp.asarray(arr))
        return treedef.unflatten(out)

    def restore_latest(self, target):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, target)
