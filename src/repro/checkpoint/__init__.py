"""Fault-tolerant checkpointing: sharded, async, atomic, mesh-agnostic."""
from .checkpointer import Checkpointer
__all__ = ["Checkpointer"]
