"""Three-term roofline from a compiled dry-run artifact (DESIGN.md §6).

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_operand_bytes / (chips × link_bw × links)

Hardware constants (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  ``model_flops`` = 6·N·D (dense) or 6·N_active·D (MoE)
for train; 2·N(_active)·D for inference — the MODEL_FLOPS/HLO_FLOPs ratio
flags remat/redundant compute.
"""

from __future__ import annotations

import dataclasses

__all__ = ["HW", "RooflineReport", "roofline", "model_flops"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 / chip
    hbm_bw: float = 819e9             # B/s / chip
    ici_bw: float = 50e9              # B/s / link
    ici_links: int = 4                # 2D-torus links per chip (v5e: 4)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops_: float
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(cfg, shape, active_params: int) -> float:
    """6·N·D for train, 2·N·D for inference steps (D = processed tokens)."""
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * active_params * tokens


def roofline(*, arch: str, shape: str, mesh: str, chips: int,
             hlo_flops: float, hlo_bytes: float, collective_bytes: float,
             model_flops_: float, hw: HW = HW()) -> RooflineReport:
    r = RooflineReport(arch=arch, shape=shape, mesh=mesh, chips=chips,
                       hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
                       collective_bytes=collective_bytes,
                       model_flops_=model_flops_)
    # cost_analysis numbers are per-partition (per-device program) under
    # SPMD; callers pass per-device numbers and chips for totals.
    r.t_compute = hlo_flops / hw.peak_flops
    r.t_memory = hlo_bytes / hw.hbm_bw
    r.t_collective = collective_bytes / (hw.ici_bw * hw.ici_links)
    terms = {"compute": r.t_compute, "memory": r.t_memory,
             "collective": r.t_collective}
    r.bottleneck = max(terms, key=terms.get)
    r.useful_ratio = (model_flops_ / (hlo_flops * chips)
                      if hlo_flops else 0.0)
    return r
