"""Parse collective-communication bytes out of (S)HLO text.

``cost_analysis()`` reports FLOPs and memory bytes but NOT collective
traffic, so we scan the compiled module text, build an id → shape table
from every instruction definition, and sum operand sizes of

    all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute

Returned per-kind operand bytes feed the roofline's collective term.  Wire
bytes per chip differ from operand bytes by a ring factor (×2(n−1)/n for
all-reduce, ×(n−1)/n for gather/scatter); we report both.
"""

from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["parse_collectives", "COLLECTIVE_KINDS", "wire_bytes"]

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

# `%name = f32[128,256]{1,0} op-name(...)` — also matches tuple defs loosely
_DEF_RE = re.compile(r"%?([\w.\-]+) = \(?(\w+)\[([\d,]*)\]")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Returns {kind: {"count": int, "operand_bytes": int}} plus totals.

    Operand bytes are taken from the shapes that appear *inside the
    collective instruction's own line*: for every collective, HLO prints the
    full typed signature of its result; the operand shapes equal the result
    shape for all-reduce/permute/all-to-all, result/groupsize for
    all-gather, and result*groupsize for reduce-scatter.  Group size is
    parsed from replica_groups when present.
    """
    out: dict = {k: {"count": 0, "operand_bytes": 0, "result_bytes": 0}
                 for k in COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _DEF_RE.match(stripped.lstrip("%"))
        if m is None:
            continue
        opname_match = re.search(r"\)?\s*=\s*[^ ]+\s+([\w\-]+)\(", stripped)
        # find which collective (fused names like all-reduce-start count)
        kind = None
        for k in COLLECTIVE_KINDS:
            if re.search(rf"\b{k}(-start|-done)?\(", stripped):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in stripped:
            continue   # avoid double counting start/done pairs
        # result shapes on this line (first parenthesised tuple or scalar def)
        header = stripped.split("(")[0]
        shapes = _SHAPE_RE.findall(header)
        rbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        # replica group size
        gsize = 0
        gm = re.search(r"replica_groups=\{\{([\d,]+)\}", stripped)
        if gm:
            gsize = len(gm.group(1).split(","))
        else:
            gm2 = re.search(r"replica_groups=\[\d+,(\d+)\]", stripped)
            if gm2:
                gsize = int(gm2.group(1))
        gsize = max(gsize, 1)
        if kind == "all-gather":
            obytes = rbytes // gsize
        elif kind == "reduce-scatter":
            obytes = rbytes * gsize
        else:
            obytes = rbytes
        out[kind]["count"] += 1
        out[kind]["operand_bytes"] += obytes
        out[kind]["result_bytes"] += rbytes
    out["total_operand_bytes"] = sum(
        out[k]["operand_bytes"] for k in COLLECTIVE_KINDS)
    out["total_count"] = sum(out[k]["count"] for k in COLLECTIVE_KINDS)
    return out


def wire_bytes(kind: str, operand_bytes: int, group: int) -> float:
    """Ring-algorithm bytes actually crossing links, per participant."""
    if group <= 1:
        return 0.0
    if kind == "all-reduce":
        return operand_bytes * 2.0 * (group - 1) / group
    if kind in ("all-gather", "reduce-scatter"):
        return operand_bytes * (group - 1) / group * (
            group if kind == "all-gather" else 1)
    return float(operand_bytes)
