"""Loop-aware cost correction for the roofline.

XLA's ``compiled.cost_analysis()`` counts each ``while``-loop body ONCE, so
a 32-layer scanned stack reports ~1/32 of the real FLOPs, and intra-block
chunk loops (flash kv blocks, SSD/WKV chunks) are likewise under-counted.

Correction scheme (every costing compile stays tiny):

  1. Per *unit* (one block of each kind; the whisper encoder block; the
     fused-CE chunk) compile the body under the cell's exact sharding:
       once     — all loops counted once,
       partial  — the unit's chunk-loop family partially inlined
                  (``lax.scan(unroll=2)`` → two trips counted).
  2. Chunk loops have uniform per-trip cost (each flash kv step / SSD chunk
     does identical work), so the per-trip marginal is exactly
     ``partial − once``, and

       unit_total = once + (trips − n_instances) · (partial − once) / n_inst

     with ``trips`` known analytically (nq·nk for flash, ⌈S/L⌉ for SSD/WKV).
  3. Cell total = production cost + Σ_units (count·unit_total −
     prod_copies·unit_once): the full program already contains each unit
     body ``prod_copies`` times (loops-once form).

The zamba2 super-block is decomposed into (mamba2 × 6·supers + tail) and
(shared-attn × supers) so each unit has a single loop family.  Whisper's
dec_cross has two flash instances (self S×S, cross S×enc); their chunk
steps have equal shapes, handled by the n_instances divisor.  The flash
q-loop overhead (an O(Cq·D) divide per q block) is folded into the
marginal — noted approximation, ≪1% of the kv-step einsums.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, Shape
from repro.models.layers import Ctx
from repro.models.sharding import ShardingRules, logical_spec
from .hlo_parse import parse_collectives

__all__ = ["cell_units", "unit_costs", "corrected_costs", "Unit",
           "prune_dominated_candidates"]

_COST_KEYS = ("flops", "bytes", "coll")


@dataclasses.dataclass
class Unit:
    kind: str                 # block kind | 'zamba_shared' | 'ce'
    count: int                # executions per step across the model
    prod_copies: int          # loop-body copies already in the full program
    loop_family: str          # 'attn' | 'ssm' | 'none'
    trips: int                # total chunk-loop trips per execution
    n_instances: int = 1      # loop instances sharing the marginal


def _ceil(a, b):
    return -(-a // b)


def _attn_trips(cfg: ModelConfig, S: int, T: int) -> int:
    nq = _ceil(S, min(cfg.attn_q_chunk, S))
    nk = _ceil(T, min(cfg.attn_k_chunk, T))
    return nq * nk


def cell_units(cfg: ModelConfig, shape: Shape) -> list[Unit]:
    S = 1 if shape.kind == "decode" else shape.seq_len
    decode = shape.kind == "decode"
    units: list[Unit] = []
    mamba_count, mamba_copies = 0, 0
    for kind, repeat in cfg.segments():
        if kind == "zamba_super":
            mamba_count += repeat * cfg.shared_attn_every
            mamba_copies += 1
            units.append(Unit("zamba_shared", repeat, 1,
                              "none" if decode else "attn",
                              0 if decode else _attn_trips(cfg, S, S)))
        elif kind == "mamba2":
            mamba_count += repeat
            mamba_copies += 1
        else:
            if kind in ("attn", "moe", "enc"):
                fam = "none" if decode else "attn"
                trips = 0 if decode else _attn_trips(cfg, S, S)
                units.append(Unit(kind, repeat, 1, fam, trips))
            elif kind == "rwkv6":
                fam = "none" if decode else "ssm"
                trips = 0 if decode else _ceil(S, cfg.rwkv_chunk)
                units.append(Unit(kind, repeat, 1, fam, trips))
            elif kind == "dec_cross":
                fam = "none" if decode else "attn"
                trips = (0 if decode else
                         _attn_trips(cfg, S, S) +
                         _attn_trips(cfg, S, cfg.enc_seq))
                units.append(Unit(kind, repeat, 1, fam, trips,
                                  n_instances=1 if decode else 2))
            else:
                raise ValueError(kind)
    if mamba_count:
        fam = "none" if decode else "ssm"
        trips = 0 if decode else _ceil(S, cfg.ssm_chunk)
        units.append(Unit("mamba2", mamba_count, mamba_copies, fam, trips))
    if cfg.family == "audio" and not decode:
        units.append(Unit("enc", cfg.n_enc_layers, 1, "attn",
                          _attn_trips(cfg, cfg.enc_seq, cfg.enc_seq)))
    if shape.kind == "train" and cfg.ce_chunk:
        units.append(Unit("ce", _ceil(shape.seq_len, cfg.ce_chunk), 1,
                          "none", 0))
    return units


# ---------------------------------------------------------------------------
# abstract-input builders
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _act(cfg, B, S, mesh, rules):
    spec = logical_spec(rules, mesh, ("batch", "seq", "embed"),
                        dims=(B, S, cfg.d_model))
    return _sds((B, S, cfg.d_model), np.dtype(cfg.compute_dtype), mesh, spec)


def _with_specs(tree_abstract, rules, mesh, spec_builder):
    shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree_abstract)
    specs = spec_builder(shapes)
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes, specs)


def _strip_leading(tree_abstract, rules, mesh, spec_builder):
    stripped = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), tree_abstract)
    return _with_specs(stripped, rules, mesh, spec_builder)


def _compile_cost(fn, args, mesh) -> dict:
    t0 = time.perf_counter()
    with mesh:
        compiled = jax.jit(fn).lower(*args).compile()
        ca = compiled.cost_analysis()
        coll = parse_collectives(compiled.as_text())
    if isinstance(ca, (list, tuple)):   # older jax returns [dict]
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total_operand_bytes"]),
            "compile_s": time.perf_counter() - t0}


# ---------------------------------------------------------------------------
# unit cost
# ---------------------------------------------------------------------------

def unit_costs(cfg: ModelConfig, unit: Unit, shape: Shape, mesh,
               rules: ShardingRules, params_abstract,
               caches_abstract) -> dict:
    """Returns {"once": cost, "total": per-execution corrected cost}."""
    from repro.launch.specs import param_specs, cache_specs
    from repro.models.transformer import (_apply_block, _shared_attn_block)
    from repro.models.layers import chunked_cross_entropy

    B = shape.global_batch
    S = 1 if shape.kind == "decode" else shape.seq_len
    train = shape.kind == "train"
    seg_kinds = [k for k, _ in cfg.segments()]

    def build(unroll2: bool):
        over = {}
        if unroll2 and unit.loop_family == "attn":
            over["unroll_attn"] = 2
        if unroll2 and unit.loop_family == "ssm":
            over["unroll_ssm"] = 2
        cfg_u = dataclasses.replace(cfg, **over) if over else cfg
        ctx = Ctx(cfg_u, mesh, rules)

        if unit.kind == "ce":
            Sx = min(cfg.ce_chunk, shape.seq_len)
            x = _act(cfg, B, Sx, mesh, rules)
            wshape = (cfg.d_model, cfg.vocab)
            wspec = logical_spec(rules, mesh, ("embed_fsdp", "vocab"),
                                 dims=wshape)
            w = _sds(wshape, np.dtype(cfg.compute_dtype), mesh, wspec)
            lbl = _sds((B, Sx), np.int32, mesh,
                       logical_spec(rules, mesh, ("batch", None),
                                    dims=(B, Sx)))

            def ce_fn(xv, wv, lv):
                f = lambda xx, ww: chunked_cross_entropy(xx, ww, lv, chunk=Sx)
                if train:
                    return jax.grad(f, argnums=(0, 1))(xv, wv)
                return f(xv, wv)

            return ce_fn, (x, w, lbl)

        if unit.kind == "zamba_shared":
            shared = _with_specs(params_abstract["shared_attn"], rules, mesh,
                                 lambda t: param_specs(t, rules, mesh))
            seg_i = seg_kinds.index("zamba_super")
            in_proj = _strip_leading(
                params_abstract["segments"][seg_i]["in_proj"], rules, mesh,
                lambda t: param_specs(t, rules, mesh))
            x = _act(cfg, B, S, mesh, rules)
            cc = None
            if shape.kind in ("prefill", "decode"):
                cc = _strip_leading(
                    caches_abstract[seg_i]["attn"], rules, mesh,
                    lambda t: cache_specs(cfg, t, rules, mesh))

            def sh_fn(sh_v, ip_v, x_v, *rest):
                cc_v = rest[0] if cc is not None else None

                def f(sh_i, ip_i, x_i):
                    h, _ = _shared_attn_block(sh_i, ip_i, x_i, x_i, ctx, cc_v)
                    return jnp.sum(h.astype(jnp.float32))

                if train:
                    return jax.grad(jax.checkpoint(f), argnums=(0, 1, 2))(
                        sh_v, ip_v, x_v)
                return _shared_attn_block(sh_v, ip_v, x_v, x_v, ctx, cc_v)

            args = [shared, in_proj, x] + ([cc] if cc is not None else [])
            return sh_fn, tuple(args)

        # ordinary block units ------------------------------------------------
        if unit.kind == "enc" and cfg.family == "audio":
            seg_p = params_abstract["encoder"]["blocks"]
            Sx = cfg.enc_seq
            seg_i = None
        elif unit.kind == "mamba2" and "zamba_super" in seg_kinds:
            seg_i = seg_kinds.index("zamba_super")
            seg_p = jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                s.shape[1:], s.dtype),
                params_abstract["segments"][seg_i]["mamba"])
            Sx = S
        else:
            seg_i = seg_kinds.index(unit.kind)
            seg_p = params_abstract["segments"][seg_i]
            Sx = S
        pp = _strip_leading(seg_p, rules, mesh,
                            lambda t: param_specs(t, rules, mesh))
        x = _act(cfg, B, Sx, mesh, rules)
        extras = []
        if unit.kind == "dec_cross":
            extras.append(_act(cfg, B, cfg.enc_seq, mesh, rules))
        cc = None
        if shape.kind in ("prefill", "decode") and unit.kind != "enc":
            if unit.kind == "mamba2" and "zamba_super" in seg_kinds:
                cache_sub = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
                    caches_abstract[seg_i]["mamba"])
            else:
                cache_sub = caches_abstract[seg_i]
            cc = _strip_leading(cache_sub, rules, mesh,
                                lambda t: cache_specs(cfg, t, rules, mesh))

        def block_fn(pp_v, x_v, *rest):
            it = list(rest)
            cc_v = it.pop(0) if cc is not None else None
            enc_v = it.pop(0) if unit.kind == "dec_cross" else None

            def f(pp_i, x_i):
                h, _, aux = _apply_block(unit.kind, pp_i, x_i, ctx, cc_v,
                                         enc_out=enc_v)
                return jnp.sum(h.astype(jnp.float32)) + 0.0 * aux

            if train:
                return jax.grad(jax.checkpoint(f), argnums=(0, 1))(pp_v, x_v)
            h, nc2, _ = _apply_block(unit.kind, pp_v, x_v, ctx, cc_v,
                                     enc_out=enc_v)
            return (h, nc2) if nc2 is not None else h

        args = [pp, x]
        if cc is not None:
            args.append(cc)
        args.extend(extras)
        return block_fn, tuple(args)

    fn_o, args_o = build(unroll2=False)
    once = _compile_cost(fn_o, args_o, mesh)
    total = dict(once)
    if unit.loop_family != "none" and unit.trips > unit.n_instances:
        fn_p, args_p = build(unroll2=True)
        partial = _compile_cost(fn_p, args_p, mesh)
        for k in _COST_KEYS:
            marginal = (partial[k] - once[k]) / unit.n_instances
            total[k] = once[k] + (unit.trips - unit.n_instances) * \
                max(marginal, 0.0)
    return {"once": once, "total": total}


def prune_dominated_candidates(op: str, space, dims_list,
                               *, dtype_bytes: int = 4,
                               slack: float = 0.15):
    """Drop knob candidates the analytic roofline proves dominated at every
    harvested call site.

    For each dims in ``dims_list`` (e.g. the output of
    ``roofline.harvest.harvest_decision_keys``), score every candidate with
    the deterministic v5e cost oracle and keep the union of all candidates
    within ``(1 + slack)`` of that dims' best.  A candidate outside the band
    at *every* site cannot win under any model whose error is below the
    slack, so install-time calibration need not sample it — the dominant
    cost of ahead-of-time tuning.  Returns a new
    :class:`~repro.core.knobs.KnobSpace` preserving the parallelism
    definition (never empty: each site contributes at least its argmin).
    """
    from repro.core.knobs import KnobSpace
    from repro.core.oracle import oracle_time

    dims_list = [tuple(d) for d in dims_list]
    if not dims_list:
        return space
    keep: set[int] = set()
    for dims in dims_list:
        times = np.array([oracle_time(op, dims, c, dtype_bytes=dtype_bytes)
                          for c in space.candidates])
        band = times.min() * (1.0 + slack)
        keep.update(int(i) for i in np.flatnonzero(times <= band))
    cands = [space.candidates[i].dict for i in sorted(keep)]
    return KnobSpace(space.name, cands,
                     parallelism_fn=space._parallelism_fn)


def corrected_costs(prod: dict, unit_records: list[dict]) -> dict:
    """prod: {"flops","bytes","coll"}; records carry Unit + costs."""
    out = {k: prod[k] for k in _COST_KEYS}
    for rec in unit_records:
        u: Unit = rec["unit"]
        for k in _COST_KEYS:
            out[k] += u.count * rec["total"][k] - \
                u.prod_copies * rec["once"][k]
    return out
