"""Ahead-of-time BLAS3 call-site harvest from jitted model programs.

The routed model (``use_pallas_gemm=True``) resolves every GEMM knob at jit
*trace* time through ``AdsalaRuntime.select_or_default``.  That makes the
complete set of decision-cache keys a *static* property of the traced
program — so it can be harvested offline, with zero FLOPs, by tracing the
model under :func:`jax.eval_shape` with a recording runtime:

  * :func:`harvest_decision_keys` — abstractly evaluate ``forward``,
    ``prefill`` and ``decode_step`` for a config and return every distinct
    ``(backend, op, dtype_bytes, dims)`` key the routed matmuls will ask
    for.  ``scripts/prewarm_model.py`` feeds these through
    ``select_many`` + ``ModelRegistry.save_decision_cache`` so the first
    real request pays **zero** runtime model evaluations.

  * :func:`dot_call_sites` — the jaxpr-level complement: walk the traced
    program for ``dot_general`` equations (routed *or* unrouted) and report
    each as ``(op, dims, dtype_bytes)``.  This sees the matmuls that do
    not dispatch through ``run_op`` (attention scores, absorbed MLA
    einsums, the router), which is exactly the coverage map the roofline
    costing needs to prune calibration candidates.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.runtime import DEFAULT_BACKEND, AdsalaRuntime

__all__ = ["HarvestedKey", "Recorder", "harvest_decision_keys",
           "dot_call_sites", "abstract_batch"]

#: one decision-cache key: (backend, op, dtype_bytes, dims)
HarvestedKey = tuple


class Recorder(AdsalaRuntime):
    """An :class:`AdsalaRuntime` that *records* decision keys instead of
    evaluating models.  Every ``select_or_default`` logs its key and returns
    the caller's default knob — no artifacts consulted, no model evals, so
    tracing a routed program under it is pure bookkeeping."""

    def __init__(self) -> None:
        super().__init__()
        self.keys: list[HarvestedKey] = []
        self._seen: set[HarvestedKey] = set()

    def select_or_default(self, op, dims, dtype_bytes, default, *,
                          backend=DEFAULT_BACKEND):
        key = (backend, op, int(dtype_bytes),
               tuple(int(d) for d in dims))
        if key not in self._seen:
            self._seen.add(key)
            self.keys.append(key)
        return default


def abstract_batch(cfg, batch_size: int, seq_len: int) -> dict:
    """ShapeDtypeStructs for one input batch of ``cfg``'s modality mix."""
    batch = {"tokens": jax.ShapeDtypeStruct((batch_size, seq_len),
                                            jnp.int32)}
    if cfg.vision_tokens:
        batch["vision"] = jax.ShapeDtypeStruct(
            (batch_size, cfg.vision_tokens, 32), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (batch_size, cfg.enc_seq, 80), jnp.float32)
    return batch


def harvest_decision_keys(cfg, *, batch_size: int = 1, seq_len: int = 128,
                          programs: tuple[str, ...] = ("forward", "prefill",
                                                       "decode")
                          ) -> list[HarvestedKey]:
    """Every distinct decision-cache key the routed model will request.

    Traces the requested programs under :func:`jax.eval_shape` with a
    :class:`Recorder` runtime — abstract evaluation only, so this is cheap
    enough to run at deploy time for every (config, batch, seq) the server
    will see.  The config is forced onto the routed path; an un-routed
    config would trivially harvest nothing.
    """
    from repro.models import transformer as tf

    rcfg = dataclasses.replace(cfg, use_pallas_gemm=True)
    rec = Recorder()
    params = jax.eval_shape(lambda k: tf.init_params(k, rcfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    batch = abstract_batch(rcfg, batch_size, seq_len)

    if "forward" in programs:
        jax.eval_shape(lambda p, b: tf.forward(p, b, rcfg, runtime=rec),
                       params, batch)
    if "prefill" in programs or "decode" in programs:
        caches = jax.eval_shape(
            lambda: tf.init_decode_state(rcfg, batch_size, seq_len + 1))
        if "prefill" in programs:
            jax.eval_shape(
                lambda p, b, c: tf.prefill(p, b, c, rcfg, runtime=rec),
                params, batch, caches)
        if "decode" in programs:
            token = jax.ShapeDtypeStruct((batch_size, 1), jnp.int32)
            jax.eval_shape(
                lambda p, t, c: tf.decode_step(p, t, c, rcfg, runtime=rec),
                params, token, caches)
    return rec.keys


# ---------------------------------------------------------------------------
# jaxpr-level call-site map (routed or not)
# ---------------------------------------------------------------------------

def _dot_sites(jaxpr, sites: list) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "pallas_call":
            continue                      # kernel bodies are the dispatch
        if name == "dot_general":
            (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
            la, ra = eqn.invars[0].aval, eqn.invars[1].aval
            m = math.prod(la.shape[d] for d in range(la.ndim)
                          if d not in tuple(lc) + tuple(lb))
            k = math.prod(la.shape[d] for d in lc) if lc else 1
            n = math.prod(ra.shape[d] for d in range(ra.ndim)
                          if d not in tuple(rc) + tuple(rb))
            sites.append(("gemm", (int(m), int(k), int(n)),
                          int(jnp.dtype(la.dtype).itemsize)))
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None:
                _dot_sites(sub, sites)


def dot_call_sites(fn, *args, **kwargs) -> list[tuple]:
    """``(op, (m, k, n), dtype_bytes)`` for every ``dot_general`` reached
    when tracing ``fn(*args, **kwargs)`` (batch dims folded into ``m``/
    ``n``; pallas kernel bodies excluded — those are already dispatched)."""
    sites: list = []
    _dot_sites(jax.make_jaxpr(lambda *xs: fn(*xs, **kwargs))(*args).jaxpr,
               sites)
    return sites
