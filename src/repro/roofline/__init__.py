"""Roofline analysis: HLO collective parsing + three-term roofline,
plus ahead-of-time BLAS3 call-site harvest for offline prewarm."""
from .analysis import HW, RooflineReport, model_flops, roofline
from .harvest import dot_call_sites, harvest_decision_keys
from .hlo_parse import COLLECTIVE_KINDS, parse_collectives, wire_bytes
__all__ = ["HW", "RooflineReport", "model_flops", "roofline",
           "COLLECTIVE_KINDS", "parse_collectives", "wire_bytes",
           "harvest_decision_keys", "dot_call_sites"]
