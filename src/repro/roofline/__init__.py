"""Roofline analysis: HLO collective parsing + three-term roofline."""
from .analysis import HW, RooflineReport, model_flops, roofline
from .hlo_parse import COLLECTIVE_KINDS, parse_collectives, wire_bytes
__all__ = ["HW", "RooflineReport", "model_flops", "roofline",
           "COLLECTIVE_KINDS", "parse_collectives", "wire_bytes"]
