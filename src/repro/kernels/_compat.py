"""jax version-compatibility shims for the Pallas TPU kernels.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` across
jax releases; resolve whichever this jax provides once, here, so the kernel
modules stay version-agnostic.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
