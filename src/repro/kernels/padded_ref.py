"""The FROZEN pre-PR-5 dispatch path, kept only as a verification oracle.

Before the zero-copy rework, ``ops.py`` zero-padded every operand to block
multiples (identity-padding the TRSM diagonal), ran the kernels on aligned
shapes, and sliced the result back.  The masked kernels promise to be
bit-identical to that path (the masked zeros occupy exactly the lanes the
padding filled), so the old behavior is preserved here verbatim — in ONE
place — and both the CI smoke gate (``benchmarks/kernel_bench.py --smoke``)
and the unit contract (``tests/test_zero_copy_kernels.py``) assert against
it.  Never used on any execution path.
"""

from __future__ import annotations

import jax.numpy as jnp

from .gemm import gemm_pallas
from .symm import symm_pallas
from .syrk import syr2k_pallas, syrk_pallas
from .trmm import trmm_pallas
from .trsm import trsm_pallas

__all__ = ["padded_run"]


def _rup(v: int, b: int) -> int:
    return ((v + b - 1) // b) * b


def _pad(x, r: int, c: int):
    m, n = x.shape
    return jnp.pad(x, ((0, r - m), (0, c - n)))


def padded_run(op: str, operands: tuple, *, variant: str = "full",
               block: int = 128, interpret: bool = True):
    """Run ``op`` the pre-PR-5 way: pad to ``block`` multiples, execute
    aligned (where the masks are no-ops), slice back."""
    B = block
    if op == "gemm":
        a, b = operands
        (m, k), n = a.shape, b.shape[1]
        M, K, N = _rup(m, B), _rup(k, B), _rup(n, B)
        return gemm_pallas(_pad(a, M, K), _pad(b, K, N),
                           bm=B, bk=B, bn=B, interpret=interpret)[:m, :n]
    if op == "symm":
        a, b = operands
        m, n = a.shape[0], b.shape[1]
        M, N = _rup(m, B), _rup(n, B)
        return symm_pallas(_pad(a, M, M), _pad(b, M, N),
                           bm=B, bn=B, interpret=interpret)[:m, :n]
    if op == "syrk":
        (a,) = operands
        n, k = a.shape
        return syrk_pallas(_pad(a, _rup(n, B), _rup(k, B)), bm=B, bk=B,
                           variant=variant, interpret=interpret)[:n, :n]
    if op == "syr2k":
        a, b = operands
        n, k = a.shape
        N, K = _rup(n, B), _rup(k, B)
        return syr2k_pallas(_pad(a, N, K), _pad(b, N, K), bm=B, bk=B,
                            variant=variant, interpret=interpret)[:n, :n]
    if op == "trmm":
        a, b = operands
        m, n = a.shape[0], b.shape[1]
        M, N = _rup(m, B), _rup(n, B)
        return trmm_pallas(_pad(a, M, M), _pad(b, M, N), bm=B, bn=B,
                           variant=variant, interpret=interpret)[:m, :n]
    if op == "trsm":
        a, b = operands
        m, n = a.shape[0], b.shape[1]
        M, N = _rup(m, B), _rup(n, B)
        ap = _pad(a, M, M)
        if M > m:  # identity-pad the diagonal (the old well-posedness trick)
            ap = ap + jnp.eye(M, dtype=a.dtype).at[:m, :m].set(0)
        return trsm_pallas(ap, _pad(b, M, N), bm=B, bn=B,
                           interpret=interpret)[:m, :n]
    raise ValueError(op)
