"""The measurable "black-box BLAS" for this host: numpy blocked BLAS L3.

ADSALA treats the BLAS implementation as a black box and tunes its runtime
knob with *measured wall-clock* data (paper §III-A).  On this CPU-only
container the Pallas TPU kernels cannot be wall-clock-timed meaningfully
(interpret mode measures Python, not hardware), so install-time calibration
times THIS implementation instead: the identical blocked algorithms the
Pallas kernels run on TPU, expressed in numpy, where the (bm, bk, bn) knob
has real cache-hierarchy effects.  On a real TPU deployment the calibration
timer points at ``kernels.ops`` instead — one-line swap, same pipeline.
"""

from __future__ import annotations

import numpy as np

__all__ = ["run_blocked", "make_operands"]


def _gemm(a, b, c, alpha, beta, bm, bk, bn, variant):
    m, k = a.shape
    _, n = b.shape
    out = np.empty((m, n), dtype=np.promote_types(a.dtype, np.float32))
    for i0 in range(0, m, bm):
        i1 = min(i0 + bm, m)
        for j0 in range(0, n, bn):
            j1 = min(j0 + bn, n)
            acc = np.zeros((i1 - i0, j1 - j0), dtype=out.dtype)
            for l0 in range(0, k, bk):
                l1 = min(l0 + bk, k)
                acc += a[i0:i1, l0:l1] @ b[l0:l1, j0:j1]
            if beta != 0.0 and c is not None:
                acc = alpha * acc + beta * c[i0:i1, j0:j1]
            elif alpha != 1.0:
                acc = alpha * acc
            out[i0:i1, j0:j1] = acc
    return out


def _symm(a, b, c, alpha, beta, bm, bk, bn, variant):
    m = a.shape[0]
    n = b.shape[1]
    out = np.empty((m, n), dtype=np.promote_types(a.dtype, np.float32))
    for i0 in range(0, m, bm):
        i1 = min(i0 + bm, m)
        for j0 in range(0, n, bn):
            j1 = min(j0 + bn, n)
            acc = np.zeros((i1 - i0, j1 - j0), dtype=out.dtype)
            for l0 in range(0, m, bm):
                l1 = min(l0 + bm, m)
                if i0 > l0:
                    blk = a[i0:i1, l0:l1]
                elif i0 < l0:
                    blk = a[l0:l1, i0:i1].T
                else:
                    d = a[i0:i1, l0:l1]
                    blk = np.tril(d) + np.tril(d, -1).T
                acc += blk @ b[l0:l1, j0:j1]
            if beta != 0.0 and c is not None:
                acc = alpha * acc + beta * c[i0:i1, j0:j1]
            elif alpha != 1.0:
                acc = alpha * acc
            out[i0:i1, j0:j1] = acc
    return out


def _syrk(a, b, c, alpha, beta, bm, bk, bn, variant):
    # b is None for syrk, =B for syr2k.  'tri_packed' is a launch-grid
    # notion (packed vs masked-out dead cells) — on the numpy path both
    # triangle variants execute the identical packed loop below.
    n, k = a.shape
    out = np.zeros((n, n), dtype=np.promote_types(a.dtype, np.float32))
    tri = variant in ("tri", "tri_packed")
    for i0 in range(0, n, bm):
        i1 = min(i0 + bm, n)
        for j0 in range(0, n, bm):
            j1 = min(j0 + bm, n)
            if tri and j0 > i0:
                continue
            acc = np.zeros((i1 - i0, j1 - j0), dtype=out.dtype)
            for l0 in range(0, k, bn):
                l1 = min(l0 + bn, k)
                if b is None:
                    acc += a[i0:i1, l0:l1] @ a[j0:j1, l0:l1].T
                else:
                    acc += a[i0:i1, l0:l1] @ b[j0:j1, l0:l1].T
                    acc += b[i0:i1, l0:l1] @ a[j0:j1, l0:l1].T
            if beta != 0.0 and c is not None:
                cl = np.tril(c) + np.tril(c, -1).T
                acc = alpha * acc + beta * cl[i0:i1, j0:j1]
            elif alpha != 1.0:
                acc = alpha * acc
            out[i0:i1, j0:j1] = acc
    if tri:
        out = np.tril(out) + np.tril(out, -1).T
    return out


def _trmm(a, b, c, alpha, beta, bm, bk, bn, variant):
    m = a.shape[0]
    n = b.shape[1]
    out = np.zeros((m, n), dtype=np.promote_types(a.dtype, np.float32))
    tri = variant in ("tri", "tri_packed")
    for i0 in range(0, m, bm):
        i1 = min(i0 + bm, m)
        for j0 in range(0, n, bn):
            j1 = min(j0 + bn, n)
            acc = np.zeros((i1 - i0, j1 - j0), dtype=out.dtype)
            for l0 in range(0, m, bm):
                l1 = min(l0 + bm, m)
                if l0 > i0:
                    if tri:
                        continue
                    blk = np.zeros((i1 - i0, l1 - l0), dtype=out.dtype)
                elif l0 == i0:
                    blk = np.tril(a[i0:i1, l0:l1])
                else:
                    blk = a[i0:i1, l0:l1]
                acc += blk @ b[l0:l1, j0:j1]
            out[i0:i1, j0:j1] = alpha * acc
    return out


def _trsm(a, b, c, alpha, beta, bm, bk, bn, variant):
    m = a.shape[0]
    n = b.shape[1]
    x = np.zeros((m, n), dtype=np.promote_types(a.dtype, np.float32))
    for i0 in range(0, m, bm):
        i1 = min(i0 + bm, m)
        r = alpha * b[i0:i1, :].astype(x.dtype)
        for l0 in range(0, i0, bm):
            l1 = min(l0 + bm, i0)
            r = r - a[i0:i1, l0:l1] @ x[l0:l1, :]
        dinv = np.linalg.inv(np.tril(a[i0:i1, i0:i1]).astype(np.float64))
        x[i0:i1, :] = (dinv @ r.astype(np.float64)).astype(x.dtype)
    return x


_IMPLS = {"gemm": _gemm, "symm": _symm, "syrk": _syrk, "syr2k": _syrk,
          "trmm": _trmm, "trsm": _trsm}


def make_operands(op: str, dims: tuple[int, ...], dtype=np.float32,
                  seed: int = 0) -> tuple:
    """Random operands of the right shapes for ``op`` (calibration inputs)."""
    rng = np.random.default_rng(seed)

    def rand(*shape):
        return rng.standard_normal(shape).astype(dtype)

    if op == "gemm":
        m, k, n = dims
        return (rand(m, k), rand(k, n))
    if op == "symm":
        m, n = dims
        return (rand(m, m), rand(m, n))
    if op == "syrk":
        n, k = dims
        return (rand(n, k),)
    if op == "syr2k":
        n, k = dims
        return (rand(n, k), rand(n, k))
    if op in ("trmm", "trsm"):
        m, n = dims
        a = rand(m, m)
        if op == "trsm":  # diagonally dominant → well-conditioned solve
            a = a + m * np.eye(m, dtype=dtype)
        return (a, rand(m, n))
    raise ValueError(op)


def run_blocked(op: str, operands: tuple, knob, *, alpha: float = 1.0,
                beta: float = 0.0) -> np.ndarray:
    """Execute the blocked numpy implementation under a block-config knob."""
    kd = knob.dict if hasattr(knob, "dict") else dict(knob)
    a = operands[0]
    b = operands[1] if len(operands) > 1 and op != "syrk" else None
    c = None
    return _IMPLS[op](a, b, c, alpha, beta, kd["bm"], kd["bk"], kd["bn"],
                      kd.get("variant", "full"))
