"""TRMM Pallas TPU kernel: B := alpha * tril(A) @ B (left, lower, non-unit).

The contraction over l only references A's lower triangle, so block rows
truncate at the diagonal:

    l < i : dense block A[i,l]
    l = i : diagonal block, masked to its lower triangle in-kernel
    l > i : structurally zero

Three variants, selectable by the ADSALA knob:

  'full'       — rectangular (i, j, l) grid; l > i steps multiply by an
                 explicitly zeroed tile (uniform pipeline, no branch
                 divergence).
  'tri'        — same grid, l > i MXU work skipped with ``pl.when``
                 (≈½ FLOPs, same output); the dead cells still pay
                 grid/DMA overhead.
  'tri_packed' — only the live (i, l<=i) contraction pairs are launched:
                 grid (⌈n/bn⌉, T) with T = nb(nb+1)/2, the packed pair
                 index de-triangularized to (i, l) inside the index maps
                 (j outermost so each output block's k-steps stay
                 consecutive).  No dead grid cells at all.

Which wins depends on the (m, n) shape — the ADSALA model's job to learn.

Zero-copy: ⌈·⌉-sized grids over the unpadded operands, ragged contraction
tail masked in-kernel, leading batch axis as a leading grid dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._batching import with_batch_axis
from ._compat import CompilerParams
from .gemm import mask_cols, mask_rows
from .syrk import detri, tri_count

__all__ = ["trmm_pallas"]


def _tril_block(a, i, l, m, bm):
    """A[i,l] truncated at the diagonal (tril on the diag block, zeros
    above it) with the ragged contraction tail masked."""
    a = jnp.where(l < i, a, jnp.where(l == i, jnp.tril(a),
                                      jnp.zeros_like(a)))
    if m % bm:
        a = mask_cols(a, bm, l, m)
    return a


def _trmm_kernel(a_ref, b_ref, o_ref, acc_ref, *, alpha, m, bm, tri, off):
    i = pl.program_id(off + 0)
    l = pl.program_id(off + 2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    compute = (l <= i) if tri else (l == l)

    @pl.when(compute)
    def _acc():
        a = a_ref[0] if off else a_ref[...]
        b = b_ref[0] if off else b_ref[...]
        a = _tril_block(a, i, l, m, bm)
        if m % bm:
            b = mask_rows(b, bm, l, m)
        acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(l == pl.num_programs(off + 2) - 1)
    def _flush():
        res = (alpha * acc_ref[...]).astype(o_ref.dtype)
        if off:
            o_ref[0] = res
        else:
            o_ref[...] = res


def _trmm_packed_kernel(a_ref, b_ref, o_ref, acc_ref, *, alpha, m, bm, off):
    """Packed (j, t) grid: t enumerates the live (i, l<=i) contraction
    pairs, l innermost within each i, so every output block's accumulation
    steps are consecutive."""
    t = pl.program_id(off + 1)
    i, l = detri(t)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0] if off else a_ref[...]
    b = b_ref[0] if off else b_ref[...]
    a = _tril_block(a, i, l, m, bm)
    if m % bm:
        b = mask_rows(b, bm, l, m)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(l == i)
    def _flush():
        res = (alpha * acc_ref[...]).astype(o_ref.dtype)
        if off:
            o_ref[0] = res
        else:
            o_ref[...] = res


@functools.partial(jax.jit, static_argnames=("bm", "bn", "alpha", "variant",
                                             "interpret"))
def trmm_pallas(a, b, *, bm: int = 128, bn: int = 128, alpha: float = 1.0,
                variant: str = "full", interpret: bool = False):
    *lead, m, m2 = a.shape
    mb, n = b.shape[-2:]
    assert m == m2 == mb
    assert len(lead) <= 1 and b.shape[:-2] == tuple(lead)
    batch = lead[0] if lead else None
    off = 1 if batch is not None else 0
    nbm = pl.cdiv(m, bm)

    if variant == "tri_packed":
        grid2 = (pl.cdiv(n, bn), tri_count(nbm))
        in_maps = [lambda j, t: detri(t),               # A[i, l]
                   lambda j, t: (detri(t)[1], j)]       # B[l, j]
        out_map = lambda j, t: (detri(t)[0], j)         # noqa: E731
        kernel = functools.partial(_trmm_packed_kernel, alpha=alpha, m=m,
                                   bm=bm, off=off)
        semantics = ("parallel", "arbitrary")
    else:
        grid2 = (nbm, pl.cdiv(n, bn), nbm)
        in_maps = [lambda i, j, l: (i, l), lambda i, j, l: (l, j)]
        out_map = lambda i, j, l: (i, j)                # noqa: E731
        kernel = functools.partial(_trmm_kernel, alpha=alpha, m=m, bm=bm,
                                   tri=(variant == "tri"), off=off)
        semantics = ("parallel", "parallel", "arbitrary")

    grid, in_maps, in_blocks, out_map, out_block, semantics, out_shape = \
        with_batch_axis(batch, grid2, in_maps, [(bm, bm), (bm, bn)],
                        out_map, (bm, bn), semantics, (m, n))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(blk, f)
                  for blk, f in zip(in_blocks, in_maps)],
        out_specs=pl.BlockSpec(out_block, out_map),
        out_shape=jax.ShapeDtypeStruct(out_shape, a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(dimension_semantics=semantics),
        interpret=interpret,
    )(a, b)
