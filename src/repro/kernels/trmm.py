"""TRMM Pallas TPU kernel: B := alpha * tril(A) @ B (left, lower, non-unit).

The contraction over l only references A's lower triangle, so block rows
truncate at the diagonal:

    l < i : dense block A[i,l]
    l = i : diagonal block, masked to its lower triangle in-kernel
    l > i : structurally zero

'tri' variant skips l > i MXU work with ``pl.when`` (≈½ FLOPs, same output);
'full' multiplies by an explicitly zeroed tile (uniform pipeline, no branch
divergence).  Which wins depends on the (m, n) shape — the ADSALA model's
job to learn.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["trmm_pallas"]


def _trmm_kernel(a_ref, b_ref, o_ref, acc_ref, *, alpha, tri):
    i, l = pl.program_id(0), pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    compute = (l <= i) if tri else (l == l)

    @pl.when(compute)
    def _acc():
        a = a_ref[...]
        a = jnp.where(l < i, a, jnp.where(l == i, jnp.tril(a),
                                          jnp.zeros_like(a)))
        acc_ref[...] += jnp.dot(a, b_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(l == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = (alpha * acc_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "alpha", "variant",
                                             "interpret"))
def trmm_pallas(a, b, *, bm: int = 128, bn: int = 128, alpha: float = 1.0,
                variant: str = "full", interpret: bool = False):
    m, m2 = a.shape
    mb, n = b.shape
    assert m == m2 == mb
    assert m % bm == 0 and n % bn == 0
    grid = (m // bm, n // bn, m // bm)
    return pl.pallas_call(
        functools.partial(_trmm_kernel, alpha=alpha,
                          tri=(variant == "tri")),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bm), lambda i, j, l: (i, l)),   # A[i,l]
            pl.BlockSpec((bm, bn), lambda i, j, l: (l, j)),   # B[l,j]
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
