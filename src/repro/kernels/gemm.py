"""GEMM Pallas TPU kernel: C := alpha*A@B + beta*C with (bm, bk, bn) VMEM
tiling — the op whose block config ADSALA tunes at runtime.

Grid is (⌈m/bm⌉, ⌈n/bn⌉, ⌈k/bk⌉) with the contraction dim innermost and
marked ``arbitrary`` (sequential revisits of the same output block); the two
output dims are ``parallel``.  A float32 VMEM scratch accumulator holds the
partial C tile across k steps so low-precision inputs (bf16) accumulate at
full precision in the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["gemm_pallas"]


def _gemm_kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *, alpha, beta):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        out = alpha * acc_ref[...]
        if beta != 0.0:
            out = out + beta * c_ref[...].astype(jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "alpha",
                                             "beta", "interpret"))
def gemm_pallas(a, b, c=None, *, bm: int = 128, bk: int = 128, bn: int = 128,
                alpha: float = 1.0, beta: float = 0.0,
                interpret: bool = False):
    """alpha*A@B + beta*C. Shapes must divide the block config (ops.py pads)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        f"(m,k,n)=({m},{k},{n}) not divisible by blocks ({bm},{bk},{bn})"
    if c is None:
        c = jnp.zeros((m, n), a.dtype)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, alpha=alpha, beta=beta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, c)
