"""GEMM Pallas TPU kernel: C := alpha*A@B + beta*C with (bm, bk, bn) VMEM
tiling — the op whose block config ADSALA tunes at runtime.

Zero-copy execution: the grid is (⌈m/bm⌉, ⌈n/bn⌉, ⌈k/bk⌉) over the *unpadded*
operands.  Ragged edge tiles are handled in-kernel — out-of-bounds reads of
the last contraction tile return undefined values (NaN in interpret mode),
so both dot operands mask their ragged k-columns/rows to zero with an iota
bound check; out-of-bounds output rows/cols are dropped by Pallas on the
store.  When every dim divides its block the masks vanish at trace time, so
the aligned path compiles to exactly the pre-masking kernel.  The masked
zeros occupy the same lanes as the old zero-padded operands, so masked and
padded execution are bit-identical.

The contraction dim is innermost and marked ``arbitrary`` (sequential
revisits of the same output block); the two output dims are ``parallel``.  A
float32 VMEM scratch accumulator holds the partial C tile across k steps so
low-precision inputs (bf16) accumulate at full precision in the MXU.

A leading batch axis on the operands (``(B, m, k)``) becomes a leading
``parallel`` grid dimension — one pallas_call executes the whole stack (the
serving layer's bucket primitive), replacing the old ``jax.vmap`` lift.
The C operand is only an input when ``beta != 0`` and a C was given; the
old path materialised a ``jnp.zeros`` C (and DMA'd it) even for the
``beta == 0`` common case.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._batching import with_batch_axis
from ._compat import CompilerParams

__all__ = ["gemm_pallas"]


def mask_cols(x, block: int, step, dim: int):
    """Zero the columns of tile ``x`` whose global index (``step``-th block
    of width ``block``) falls at or beyond ``dim`` — the ragged tail mask."""
    ids = block * step + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    return jnp.where(ids < dim, x, jnp.zeros_like(x))


def mask_rows(x, block: int, step, dim: int):
    """Row-axis twin of :func:`mask_cols`."""
    ids = block * step + jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    return jnp.where(ids < dim, x, jnp.zeros_like(x))


def _gemm_kernel(*refs, alpha, beta, k, bk, has_c, off, shared_b):
    """``refs`` = (a, b[, c], o, acc); ``off`` = 1 when a leading batch grid
    dim is present (refs then carry a leading length-1 block axis).
    ``shared_b`` — B is a single 2-D weight shared across the stack (its ref
    never gained the batch block axis)."""
    if has_c:
        a_ref, b_ref, c_ref, o_ref, acc_ref = refs
    else:
        a_ref, b_ref, o_ref, acc_ref = refs
    l = pl.program_id(off + 2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0] if off else a_ref[...]
    b = b_ref[0] if (off and not shared_b) else b_ref[...]
    if k % bk:
        # ragged contraction tail: both operands masked (OOB reads are
        # undefined, and 0 * garbage is still garbage when garbage is NaN)
        a = mask_cols(a, bk, l, k)
        b = mask_rows(b, bk, l, k)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(l == pl.num_programs(off + 2) - 1)
    def _flush():
        out = alpha * acc_ref[...]
        if has_c:
            c = c_ref[0] if off else c_ref[...]
            out = out + beta * c.astype(jnp.float32)
        if off:
            o_ref[0] = out.astype(o_ref.dtype)
        else:
            o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "alpha",
                                             "beta", "interpret"))
def gemm_pallas(a, b, c=None, *, bm: int = 128, bk: int = 128, bn: int = 128,
                alpha: float = 1.0, beta: float = 0.0,
                interpret: bool = False):
    """alpha*A@B + beta*C for arbitrary (ragged) shapes; a leading batch
    axis executes as one batched grid.  A 2-D B against a batched A is
    treated as a weight shared across the stack (the model-serving linear:
    ``(B, S, d) @ (d, n)`` with no host reshape)."""
    *lead, m, k = a.shape
    k2, n = b.shape[-2:]
    assert k == k2, (a.shape, b.shape)
    assert len(lead) <= 1 and b.shape[:-2] in (tuple(lead), ()), \
        (a.shape, b.shape)
    batch = lead[0] if lead else None
    shared_b = batch is not None and b.ndim == 2
    has_c = c is not None and beta != 0.0
    off = 1 if batch is not None else 0

    grid, in_maps, in_blocks, out_map, out_block, semantics, out_shape = \
        with_batch_axis(
            batch, (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk)),
            [lambda i, j, l: (i, l), lambda i, j, l: (l, j),
             lambda i, j, l: (i, j)],
            [(bm, bk), (bk, bn), (bm, bn)],
            lambda i, j, l: (i, j), (bm, bn),
            ("parallel", "parallel", "arbitrary"), (m, n),
            broadcast=(False, shared_b, False))

    operands = [a, b] + ([c] if has_c else [])
    in_specs = [pl.BlockSpec(blk, f)
                for blk, f in zip(in_blocks, in_maps)][: len(operands)]
    return pl.pallas_call(
        functools.partial(_gemm_kernel, alpha=alpha, beta=beta, k=k, bk=bk,
                          has_c=has_c, off=off, shared_b=shared_b),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(out_block, out_map),
        out_shape=jax.ShapeDtypeStruct(out_shape, a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(dimension_semantics=semantics),
        interpret=interpret,
    )(*operands)
