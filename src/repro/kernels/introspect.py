"""Jaxpr introspection for the zero-copy execution contract.

The masked kernels promise two structural properties that numerics alone
cannot witness:

  * **zero-copy** — no operand pad / result slice-back materializes outside
    the pallas_call (the old path allocated padded copies of every operand
    on non-block-multiple shapes);
  * **packed grids** — the ``tri_packed`` variant launches exactly the
    n(n+1)/2 live lower-triangle blocks (plus the write-only mirror step for
    the rank-k updates) instead of a full n² grid.

Both are facts about the *traced program*, so this module walks jaxprs:
``pallas_grids`` extracts every pallas_call grid and ``copy_op_counts``
counts the data-movement primitives at every level outside kernel bodies.
Used by ``tests/test_zero_copy_kernels.py`` and ``benchmarks/kernel_bench.py``
(the BENCH_kernels.json trajectory is built from these deterministic
structural metrics, so the CI gate is immune to timing jitter).
"""

from __future__ import annotations

import math

import jax

__all__ = ["pallas_grids", "copy_op_counts", "grid_slots",
           "packed_grid_for", "full_grid_for"]

#: the data-movement primitives the zero-copy contract forbids on the
#: dispatch path (pad = operand padding, slice = result slice-back,
#: gather covers jnp-advanced-indexing forms of the same copy)
COPY_PRIMITIVES = ("pad", "slice", "dynamic_slice", "gather")


def _walk(jaxpr, grids, counts):
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "pallas_call":
            gm = eqn.params.get("grid_mapping")
            if gm is not None:
                grids.append(tuple(int(g) for g in gm.grid))
            # kernel bodies are never descended into — they are allowed
            # any masking ops they like; the contract is host-side copies
            continue
        if name in COPY_PRIMITIVES:
            counts[name] = counts.get(name, 0) + 1
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None:
                _walk(sub, grids, counts)


def _trace(fn, *args, **kwargs):
    return jax.make_jaxpr(lambda *xs: fn(*xs, **kwargs))(*args)


def pallas_grids(fn, *args, **kwargs) -> list[tuple[int, ...]]:
    """Grids of every pallas_call reached when tracing ``fn(*args)``."""
    grids: list = []
    _walk(_trace(fn, *args, **kwargs).jaxpr, grids, {})
    return grids


def copy_op_counts(fn, *args, **kwargs) -> dict[str, int]:
    """Counts of :data:`COPY_PRIMITIVES` outside pallas kernel bodies."""
    counts: dict = {}
    _walk(_trace(fn, *args, **kwargs).jaxpr, [], counts)
    return counts


def grid_slots(grid: tuple[int, ...]) -> int:
    return math.prod(grid)


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def full_grid_for(op: str, dims: tuple[int, ...], bm: int, bk: int,
                  bn: int | None = None) -> tuple[int, ...]:
    """The rectangular grid the 'full'/'tri' variants launch."""
    if op == "gemm":
        m, k, n = dims
        return (_cdiv(m, bm), _cdiv(n, bn), _cdiv(k, bk))
    if op == "symm":
        m, n = dims
        return (_cdiv(m, bm), _cdiv(n, bn), _cdiv(m, bm))
    if op in ("syrk", "syr2k"):
        n, k = dims
        return (_cdiv(n, bm), _cdiv(n, bm), _cdiv(k, bk))
    if op == "trmm":
        m, n = dims
        return (_cdiv(m, bm), _cdiv(n, bn), _cdiv(m, bm))
    raise ValueError(op)


def packed_grid_for(op: str, dims: tuple[int, ...], bm: int, bk: int,
                    bn: int | None = None) -> tuple[int, ...]:
    """The packed grid the 'tri_packed' variant launches: T = nb(nb+1)/2
    live blocks — times (k-steps + the write-only mirror step) for the
    rank-k updates, times the n-blocks for trmm."""
    if op in ("syrk", "syr2k"):
        n, k = dims
        nb = _cdiv(n, bm)
        return (nb * (nb + 1) // 2, _cdiv(k, bk) + 1)
    if op == "trmm":
        m, n = dims
        nb = _cdiv(m, bm)
        return (_cdiv(n, bn), nb * (nb + 1) // 2)
    raise ValueError(op)
