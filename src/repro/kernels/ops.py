"""Public BLAS L3 API with ADSALA runtime block selection.

Each op:
  1. asks the :class:`~repro.core.runtime.AdsalaRuntime` (if provided or
     globally installed) for the argmin-predicted block config at the call's
     dims — at *trace* time, so the decision costs nothing per executed step
     and is memoized across identical shapes (paper Fig. 1b);
  2. dispatches to the Pallas kernel *zero-copy*: grids are ⌈dim/block⌉ over
     the unpadded operands and ragged edge tiles are masked in-kernel, so no
     operand copy, pad, or result slice-back ever materializes (the old
     pad-to-block-multiple path is gone).  Operands carrying a leading batch
     axis execute as one batched grid — one pallas_call per stack.

The knob spaces used by install-time calibration live here too, so the tuner
and the executor can never disagree about the candidate set.
"""

from __future__ import annotations

import contextlib
import functools
import threading
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.knobs import Knob, KnobSpace, block_knob_space
from repro.core.runtime import AdsalaRuntime, global_runtime

from .gemm import gemm_pallas
from .symm import symm_pallas
from .syrk import syr2k_pallas, syrk_pallas
from .trmm import trmm_pallas
from .trsm import trsm_pallas

__all__ = [
    "gemm", "symm", "syrk", "syr2k", "trmm", "trsm",
    "knob_space_for", "default_knob", "dims_of", "run_op", "DTYPE_BYTES",
    "PALLAS_OPS", "trace_batching", "enable_trace_batching",
    "disable_trace_batching",
]


@functools.lru_cache(maxsize=None)
def DTYPE_BYTES(dtype) -> int:
    return int(jnp.dtype(dtype).itemsize)


#: lazily bound repro.backends.resolve_backend (the backends package imports
#: this module's knob spaces, so a top-level import would be circular; the
#: per-call `from ... import` was measurable constant overhead on the
#: cache-hit path)
_resolve_backend = None


def _backend_resolver():
    global _resolve_backend
    if _resolve_backend is None:
        from repro.backends import resolve_backend
        _resolve_backend = resolve_backend
    return _resolve_backend


# ---------------------------------------------------------------------------
# knob spaces (shared between calibration and execution)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def knob_space_for(op: str, *, small: bool = False,
                   sizes: tuple[int, ...] | None = None) -> KnobSpace:
    """Candidate block configs per subroutine.

    GEMM tunes (bm, bk, bn); the 2-dim subroutines tune (bm, bn) with the
    A-dimension block tied to bm (square A tiles), plus the kernel variant
    for the triangular/symmetric-output ops: 'full' (every block computed),
    'tri' (dead blocks skip MXU work but still occupy grid cells), and
    'tri_packed' (only the n(n+1)/2 live blocks are launched, mirror done
    in-kernel) — three genuinely different execution strategies for the
    model to discriminate between.

    ``sizes`` overrides the block-edge candidates: TPU targets default to
    MXU-aligned (128, 256, 512); CPU-host calibration passes cache-scale
    edges (e.g. 64, 128, 256).
    """
    if sizes is None:
        sizes = (128, 256) if small else (128, 256, 512)
    if op == "gemm":
        return block_knob_space(bms=sizes, bks=sizes, bns=sizes)
    variants = ("full", "tri", "tri_packed") \
        if op in ("syrk", "syr2k", "trmm") else ("full",)
    space = block_knob_space(bms=sizes, bks=(128,), bns=sizes,
                             variants=variants)
    # collapse bk (unused for 2-dim ops) out of the candidate identity
    seen, cands = set(), []
    for k in space:
        d = k.dict
        key = (d["bm"], d["bn"], d["variant"])
        if key not in seen:
            seen.add(key)
            cands.append({"bm": d["bm"], "bk": d["bm"], "bn": d["bn"],
                          "variant": d["variant"]})
    from repro.core.knobs import _grid_parallelism
    return KnobSpace("blocks", cands, parallelism_fn=_grid_parallelism)


@functools.lru_cache(maxsize=None)
def default_knob(op: str) -> Knob:
    """Baseline config (paper: max threads) = maximum grid parallelism =
    smallest blocks.  Cached: the parallelism argmax over the whole knob
    space used to recompute on every call — including every cache-hit
    call, where it dominated the remaining decision latency."""
    space = knob_space_for(op)
    return space.candidates[int(np.argmax(
        [space.parallelism(c, (4096, 4096, 4096)[: 3 if op == "gemm" else 2])
         for c in space.candidates]))]


def dims_of(op: str, shapes: tuple[tuple[int, ...], ...]) -> tuple[int, ...]:
    """The subroutine's free dims (paper Table I) from operand shapes.

    Leading batch axes are ignored: a stacked ``(B, m, k)`` operand yields
    the same dims as its per-item ``(m, k)`` slice, so stacked and unstacked
    calls share one decision-cache key.
    """
    if op == "gemm":
        (m, k), (_, n) = shapes[0][-2:], shapes[1][-2:]
        return (m, k, n)
    if op == "symm":
        (m, _), (_, n) = shapes[0][-2:], shapes[1][-2:]
        return (m, n)
    if op in ("syrk", "syr2k"):
        (n, k) = shapes[0][-2:]
        return (n, k)
    (m, _), (_, n) = shapes[0][-2:], shapes[1][-2:]   # trmm/trsm
    return (m, n)


# ---------------------------------------------------------------------------
# trace-time decision batching (jit-friendly hook)
# ---------------------------------------------------------------------------

class _TraceBatcher:
    """Cross-thread combining window for trace-time knob decisions.

    The pallas executors resolve their knob at jit *trace* time with
    concrete dims, one key at a time.  When several shapes trace
    concurrently (serving warmup, multi-threaded jit, vmapped model
    stacks), each tracer used to pay its own full model evaluation.  With a
    batcher installed, cache hits and untuned ops stay on the direct
    lock-free path, but true misses park in a shared window for a sub-ms
    linger; the first thread in becomes the leader, drains the window
    through ONE :meth:`AdsalaRuntime.select_many` call (a single fused
    feature-build + model-predict for all distinct keys), and wakes the
    rest.  Followers then re-read their now-cached key through the normal
    hit path, so statistics stay faithful: one model eval per distinct key,
    everything else a hit.

    Purely trace-time Python — nothing jax sees changes, so jit tracing and
    AOT caching behave exactly as without the hook.  Any failure or timeout
    falls back to the direct per-key path; the batcher can only ever add
    latency (bounded by the linger), never wrong decisions.
    """

    def __init__(self, linger_ms: float = 0.25, max_keys: int = 64) -> None:
        self.linger_s = max(linger_ms, 0.01) / 1000.0
        self.max_keys = max(int(max_keys), 1)
        self._lock = threading.Lock()
        self._pending: dict[tuple, threading.Event] = {}
        self._leader_active = False
        self.batches = 0          # introspection: flushes performed
        self.batched_keys = 0     # keys resolved through select_many

    def select_or_default(self, rt: AdsalaRuntime, op: str, dims: tuple,
                          dtype_bytes: int, default: Knob,
                          backend: str) -> Knob:
        if not rt.has(op, dtype_bytes, backend) \
                or rt.peek(op, dims, dtype_bytes, backend) is not None:
            # untuned op or cache hit: the direct lock-free path
            return rt.select_or_default(op, dims, dtype_bytes, default,
                                        backend=backend)
        key = (backend, op, dtype_bytes, dims)
        with self._lock:
            event = self._pending.get(key)
            if event is None:
                event = self._pending[key] = threading.Event()
            leader = not self._leader_active
            if leader:
                self._leader_active = True
        if leader:
            owned = True
            try:
                while True:
                    self._drain(rt)
                    with self._lock:
                        if not self._pending:
                            # hand the leader role off atomically with the
                            # emptiness check: late arrivals either saw a
                            # live leader AND are in a batch this loop will
                            # drain, or they elect themselves
                            self._leader_active = False
                            owned = False
                            break
            finally:
                if owned:                  # exception safety — but never
                    with self._lock:       # clear a successor's leadership
                        self._leader_active = False
        else:
            event.wait(timeout=max(0.25, self.linger_s * 100))
        # the key is (almost surely) cached now, so this records a hit —
        # the same accounting shape as the serving layer's select_many
        # prewarm (one fused eval per distinct key, each caller a hit); on
        # any timeout/failure it is a normal single-key miss instead
        return rt.select_or_default(op, dims, dtype_bytes, default,
                                    backend=backend)

    def _drain(self, rt: AdsalaRuntime) -> None:
        deadline = time.perf_counter() + self.linger_s
        while time.perf_counter() < deadline:
            with self._lock:
                if len(self._pending) >= self.max_keys:
                    break
            time.sleep(self.linger_s / 5.0)       # yields the GIL to peers
        with self._lock:
            batch = self._pending
            self._pending = {}
        try:
            if batch:
                rt.select_many([(op, dims, db, be)
                                for (be, op, db, dims) in batch],
                               record_hits=False)
                self.batches += 1
                self.batched_keys += len(batch)
        finally:
            for event in batch.values():
                event.set()


_TRACE_BATCHER: Optional[_TraceBatcher] = None


def enable_trace_batching(linger_ms: float = 0.25,
                          max_keys: int = 64) -> _TraceBatcher:
    """Install a process-wide trace-time decision batcher (see
    :class:`_TraceBatcher`); returns it for introspection."""
    global _TRACE_BATCHER
    _TRACE_BATCHER = _TraceBatcher(linger_ms=linger_ms, max_keys=max_keys)
    return _TRACE_BATCHER


def disable_trace_batching() -> None:
    global _TRACE_BATCHER
    _TRACE_BATCHER = None


@contextlib.contextmanager
def trace_batching(linger_ms: float = 0.25, max_keys: int = 64):
    """Scoped :func:`enable_trace_batching` — concurrently-traced shapes
    inside the block batch their uncached knob decisions through
    ``select_many``::

        with ops.trace_batching():
            pool.map(lambda s: ops.run_op("gemm", mk(s)), shapes)
    """
    global _TRACE_BATCHER
    prev = _TRACE_BATCHER
    batcher = _TraceBatcher(linger_ms=linger_ms, max_keys=max_keys)
    _TRACE_BATCHER = batcher
    try:
        yield batcher
    finally:
        _TRACE_BATCHER = prev


def _select(op: str, dims: tuple[int, ...], dtype,
            knob: Optional[Knob], runtime: Optional[AdsalaRuntime]) -> Knob:
    if knob is not None:
        return knob
    rt = runtime if runtime is not None else global_runtime()
    batcher = _TRACE_BATCHER
    if batcher is not None:
        return batcher.select_or_default(rt, op, dims, DTYPE_BYTES(dtype),
                                         default_knob(op), "pallas")
    return rt.select_or_default(op, dims, DTYPE_BYTES(dtype),
                                default_knob(op), backend="pallas")


def _rup(v: int, b: int) -> int:
    return ((v + b - 1) // b) * b


# ---------------------------------------------------------------------------
# public ops (zero-copy: masked kernels take the unpadded operands directly;
# a leading batch axis on every operand executes as one batched grid)
# ---------------------------------------------------------------------------

def gemm(a, b, c=None, *, alpha=1.0, beta=0.0, knob=None, runtime=None,
         interpret: bool = False):
    m, k = a.shape[-2:]
    n = b.shape[-1]
    kb = _select("gemm", (m, k, n), a.dtype, knob, runtime).dict
    bm, bk, bn = (min(kb["bm"], _rup(m, 128)), min(kb["bk"], _rup(k, 128)),
                  min(kb["bn"], _rup(n, 128)))
    return gemm_pallas(a, b, c, bm=bm, bk=bk, bn=bn, alpha=alpha, beta=beta,
                       interpret=interpret)


def symm(a, b, c=None, *, alpha=1.0, beta=0.0, knob=None, runtime=None,
         interpret: bool = False):
    m, n = a.shape[-2], b.shape[-1]
    kb = _select("symm", (m, n), a.dtype, knob, runtime).dict
    bm, bn = min(kb["bm"], _rup(m, 128)), min(kb["bn"], _rup(n, 128))
    return symm_pallas(a, b, c, bm=bm, bn=bn, alpha=alpha, beta=beta,
                       interpret=interpret)


def syrk(a, c=None, *, alpha=1.0, beta=0.0, knob=None, runtime=None,
         interpret: bool = False):
    n, k = a.shape[-2:]
    kb = _select("syrk", (n, k), a.dtype, knob, runtime).dict
    bm, bk = min(kb["bm"], _rup(n, 128)), min(kb["bn"], _rup(k, 128))
    return syrk_pallas(a, c, bm=bm, bk=bk, alpha=alpha, beta=beta,
                       variant=kb.get("variant", "full"), interpret=interpret)


def syr2k(a, b, c=None, *, alpha=1.0, beta=0.0, knob=None, runtime=None,
          interpret: bool = False):
    n, k = a.shape[-2:]
    kb = _select("syr2k", (n, k), a.dtype, knob, runtime).dict
    bm, bk = min(kb["bm"], _rup(n, 128)), min(kb["bn"], _rup(k, 128))
    return syr2k_pallas(a, b, c, bm=bm, bk=bk, alpha=alpha, beta=beta,
                        variant=kb.get("variant", "full"),
                        interpret=interpret)


def trmm(a, b, *, alpha=1.0, knob=None, runtime=None,
         interpret: bool = False):
    m, n = a.shape[-2], b.shape[-1]
    kb = _select("trmm", (m, n), a.dtype, knob, runtime).dict
    bm, bn = min(kb["bm"], _rup(m, 128)), min(kb["bn"], _rup(n, 128))
    return trmm_pallas(a, b, bm=bm, bn=bn, alpha=alpha,
                       variant=kb.get("variant", "full"), interpret=interpret)


def trsm(a, b, *, alpha=1.0, knob=None, runtime=None,
         interpret: bool = False):
    m, n = a.shape[-2], b.shape[-1]
    kb = _select("trsm", (m, n), a.dtype, knob, runtime).dict
    bm, bn = min(kb["bm"], _rup(m, 128)), min(kb["bn"], _rup(n, 128))
    return trsm_pallas(a, b, bm=bm, bn=bn, alpha=alpha, interpret=interpret)


#: the pallas-path executors (what the ``pallas`` backend dispatches to)
PALLAS_OPS = {"gemm": gemm, "symm": symm, "syrk": syrk, "syr2k": syr2k,
              "trmm": trmm, "trsm": trsm}
_OPS = PALLAS_OPS   # back-compat alias


def run_op(op: str, operands: tuple, *, backend: str = "pallas",
           knob: Optional[Knob] = None,
           runtime: Optional[AdsalaRuntime] = None,
           stacked: Optional[bool] = None, **kw):
    """Execute ``op`` through the backend registry.

    Dispatch resolves the requested backend with a graceful fallback chain
    (requested → ref), so an unregistered or host-unavailable backend still
    yields a correct result.  When no ``knob`` is given the ADSALA runtime
    selects one under the *resolved* backend's key, falling back to that
    backend's default config if it has no tuned model.

    Operands carrying a leading batch axis (``(B, m, k)`` instead of
    ``(m, k)``) execute as one stacked call via ``Backend.execute_stacked``
    — all items share dims/dtype, so a single knob decision covers the whole
    stack.  Trailing operands of one-lower rank (a shared 2-D weight against
    batched activations — the model-serving linear) broadcast across the
    stack without a host reshape or copy.  ``stacked`` forces the
    interpretation when auto-detection by rank is ambiguous.
    """
    be = _backend_resolver()(backend)
    if stacked is None:
        stacked = getattr(operands[0], "ndim", 2) == 3
    # chaos seam: a fault plan on the runtime can crash the dispatch exactly
    # where a real kernel launch would fail (guarded so the default path
    # costs two attribute checks and nothing else)
    faults = getattr(runtime, "_faults", None) if runtime is not None else None
    if be.selects_own_knob:
        # the backend's executors resolve the knob themselves (pallas: at
        # jit trace time) — forward the runtime instead of pre-selecting
        if faults is not None:
            faults.fire("kernel_execute", backend=be.name, op=op,
                        stacked=bool(stacked), knob=knob)
        if stacked:
            return be.execute_stacked(op, operands, knob, runtime=runtime,
                                      **kw)
        return be.execute(op, operands, knob, runtime=runtime, **kw)
    if knob is None:
        rt = runtime if runtime is not None else global_runtime()
        dims = dims_of(op, tuple(x.shape for x in operands))
        knob = rt.select_or_default(op, dims, DTYPE_BYTES(operands[0].dtype),
                                    be.default_knob(op), backend=be.name)
    if faults is not None:
        faults.fire("kernel_execute", backend=be.name, op=op,
                    stacked=bool(stacked), knob=knob)
    if stacked:
        return be.execute_stacked(op, operands, knob, **kw)
    return be.execute(op, operands, knob, **kw)
