"""Leading-batch-axis grid transformation, shared by every Pallas kernel.

A stack of problems (operands carrying a leading batch axis) executes as
ONE pallas_call whose leading grid dimension is the batch width: every
BlockSpec gains a length-1 leading block indexed by the batch coordinate,
the grid/out_shape are prefixed with the width, and the new dimension is
``parallel`` (items are independent).  Kernels detect the extra axis via
their ``off`` parameter (grid-axis indices shift by one) and read/write
``ref[0]`` instead of ``ref[...]``.

Operands *shared* across the stack (a 2-D weight against a batched
activation — the model-serving linear) keep their original index map and
block via the per-operand ``broadcast`` flags: every batch grid step reads
the same weight tile, so the stack executes without materialising a
broadcast copy of the weight.

One implementation — gemm, symm, syrk/syr2k, and trmm all apply the same
transformation, and a divergent copy would compile but mis-index.
"""

from __future__ import annotations

__all__ = ["with_batch_axis"]


def with_batch_axis(batch, grid, in_maps, in_blocks, out_map, out_block,
                    semantics, out_shape, broadcast=None):
    """Prefix a leading batch grid dimension; identity when ``batch`` is
    None.  ``broadcast`` optionally flags, per input, operands shared
    (unbatched) across the stack — their maps/blocks pass through
    untouched.  Returns the transformed ``(grid, in_maps, in_blocks,
    out_map, out_block, semantics, out_shape)`` tuple."""
    if batch is None:
        return (grid, in_maps, in_blocks, out_map, out_block, semantics,
                out_shape)
    if broadcast is None:
        broadcast = (False,) * len(in_maps)
    in_maps = [(lambda bt, *gi, f=f: tuple(f(*gi))) if bc
               else (lambda bt, *gi, f=f: (bt,) + tuple(f(*gi)))
               for f, bc in zip(in_maps, broadcast)]
    in_blocks = [tuple(blk) if bc else (1,) + tuple(blk)
                 for blk, bc in zip(in_blocks, broadcast)]
    inner_out = out_map

    def batched_out(bt, *gi):
        return (bt,) + tuple(inner_out(*gi))

    return ((batch,) + tuple(grid), in_maps, in_blocks, batched_out,
            (1,) + tuple(out_block), ("parallel",) + tuple(semantics),
            (batch,) + tuple(out_shape))
