"""Leading-batch-axis grid transformation, shared by every Pallas kernel.

A stack of problems (operands carrying a leading batch axis) executes as
ONE pallas_call whose leading grid dimension is the batch width: every
BlockSpec gains a length-1 leading block indexed by the batch coordinate,
the grid/out_shape are prefixed with the width, and the new dimension is
``parallel`` (items are independent).  Kernels detect the extra axis via
their ``off`` parameter (grid-axis indices shift by one) and read/write
``ref[0]`` instead of ``ref[...]``.

One implementation — gemm, symm, syrk/syr2k, and trmm all apply the same
transformation, and a divergent copy would compile but mis-index.
"""

from __future__ import annotations

__all__ = ["with_batch_axis"]


def with_batch_axis(batch, grid, in_maps, in_blocks, out_map, out_block,
                    semantics, out_shape):
    """Prefix a leading batch grid dimension; identity when ``batch`` is
    None.  Returns the transformed ``(grid, in_maps, in_blocks, out_map,
    out_block, semantics, out_shape)`` tuple."""
    if batch is None:
        return (grid, in_maps, in_blocks, out_map, out_block, semantics,
                out_shape)
    in_maps = [lambda bt, *gi, f=f: (bt,) + tuple(f(*gi)) for f in in_maps]
    in_blocks = [(1,) + tuple(blk) for blk in in_blocks]
    inner_out = out_map

    def batched_out(bt, *gi):
        return (bt,) + tuple(inner_out(*gi))

    return ((batch,) + tuple(grid), in_maps, in_blocks, batched_out,
            (1,) + tuple(out_block), ("parallel",) + tuple(semantics),
            (batch,) + tuple(out_shape))
