"""Pure-jnp reference oracles for all six BLAS L3 subroutines.

Semantics follow the BLAS standard (paper Table I), specialised to the
variants this library implements on TPU:

  gemm : C := alpha*A@B + beta*C                      A(m,k) B(k,n) C(m,n)
  symm : C := alpha*sym(A)@B + beta*C  (left, lower)  A(m,m) B(m,n) C(m,n)
  syrk : C := alpha*A@A^T + beta*C     (lower)        A(n,k) C(n,n)
  syr2k: C := alpha*(A@B^T + B@A^T) + beta*C (lower)  A,B(n,k) C(n,n)
  trmm : B := alpha*tril(A)@B          (left, lower, non-unit)  A(m,m) B(m,n)
  trsm : solve tril(A)@X = alpha*B     (left, lower, non-unit)

Symmetric operands are *stored* in the lower triangle (the upper triangle of
the input array is ignored, as a real BLAS would).  Outputs of syrk/syr2k are
returned as full symmetric matrices (both triangles valid) — the kernels'
``tri`` variants compute only the lower triangle and mirror.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["gemm", "symm", "syrk", "syr2k", "trmm", "trsm", "REFS"]


def _sym_lower(a):
    lo = jnp.tril(a)
    return lo + jnp.tril(a, -1).swapaxes(-1, -2)


def gemm(a, b, c=None, *, alpha=1.0, beta=0.0):
    out = alpha * (a @ b)
    if c is not None and beta != 0.0:
        out = out + beta * c
    return out.astype(a.dtype)


def symm(a, b, c=None, *, alpha=1.0, beta=0.0):
    out = alpha * (_sym_lower(a) @ b)
    if c is not None and beta != 0.0:
        out = out + beta * c
    return out.astype(a.dtype)


def syrk(a, c=None, *, alpha=1.0, beta=0.0):
    out = alpha * (a @ a.swapaxes(-1, -2))
    if c is not None and beta != 0.0:
        out = out + beta * _sym_lower(c)
    return out.astype(a.dtype)


def syr2k(a, b, c=None, *, alpha=1.0, beta=0.0):
    out = alpha * (a @ b.swapaxes(-1, -2) + b @ a.swapaxes(-1, -2))
    if c is not None and beta != 0.0:
        out = out + beta * _sym_lower(c)
    return out.astype(a.dtype)


def trmm(a, b, *, alpha=1.0):
    return (alpha * (jnp.tril(a) @ b)).astype(a.dtype)


def trsm(a, b, *, alpha=1.0):
    import jax
    x = jax.lax.linalg.triangular_solve(
        jnp.tril(a), alpha * b, left_side=True, lower=True)
    return x.astype(a.dtype)


REFS = {"gemm": gemm, "symm": symm, "syrk": syrk, "syr2k": syr2k,
        "trmm": trmm, "trsm": trsm}
