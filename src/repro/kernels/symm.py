"""SYMM Pallas TPU kernel: C := alpha*sym(A)@B + beta*C (left side, lower
storage).

A is stored in its lower triangle only.  The kernel receives **two views of
the same array** with mirrored index maps — block (i,l) and block (l,i) — and
reconstructs the symmetric block on the fly:

    i > l : A[i,l] is in the stored lower triangle           → use view 1
    i < l : sym(A)[i,l] = A[l,i]^T, A[l,i] stored            → use view 2^T
    i = l : diagonal block, mirror its own lower triangle

A-blocks are square (bm × bm) so the mirrored view has the same block shape.
Loading two views costs ≤2× A-tile traffic; the ADSALA tuner sees that cost
in its measured/For-oracle timings and sizes blocks accordingly.

Zero-copy: the grid is ⌈·⌉-sized over the unpadded operands; the ragged
contraction tail masks both dot operands in-kernel (see ``gemm.mask_cols``),
OOB output rows/cols are dropped on store, and the C operand only exists
when ``beta != 0``.  A leading batch axis becomes a leading grid dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._batching import with_batch_axis
from ._compat import CompilerParams
from .gemm import mask_cols, mask_rows

__all__ = ["symm_pallas"]


def _symm_kernel(*refs, alpha, beta, m, bm, has_c, off):
    if has_c:
        a_il_ref, a_li_ref, b_ref, c_ref, o_ref, acc_ref = refs
    else:
        a_il_ref, a_li_ref, b_ref, o_ref, acc_ref = refs
    i = pl.program_id(off + 0)
    l = pl.program_id(off + 2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_il = a_il_ref[0] if off else a_il_ref[...]
    a_li = a_li_ref[0] if off else a_li_ref[...]
    b = b_ref[0] if off else b_ref[...]
    diag = jnp.tril(a_il) + jnp.tril(a_il, -1).T
    a = jnp.where(i > l, a_il, jnp.where(i < l, a_li.T, diag))
    if m % bm:
        # ragged contraction tail (the contraction dim of symm is m itself)
        a = mask_cols(a, bm, l, m)
        b = mask_rows(b, bm, l, m)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(l == pl.num_programs(off + 2) - 1)
    def _flush():
        out = alpha * acc_ref[...]
        if has_c:
            c = c_ref[0] if off else c_ref[...]
            out = out + beta * c.astype(jnp.float32)
        if off:
            o_ref[0] = out.astype(o_ref.dtype)
        else:
            o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "alpha", "beta",
                                             "interpret"))
def symm_pallas(a, b, c=None, *, bm: int = 128, bn: int = 128,
                alpha: float = 1.0, beta: float = 0.0,
                interpret: bool = False):
    *lead, m, m2 = a.shape
    mb, n = b.shape[-2:]
    assert m == m2 == mb
    assert len(lead) <= 1 and b.shape[:-2] == tuple(lead)
    batch = lead[0] if lead else None
    has_c = c is not None and beta != 0.0
    off = 1 if batch is not None else 0

    grid, in_maps, in_blocks, out_map, out_block, semantics, out_shape = \
        with_batch_axis(
            batch, (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(m, bm)),
            [lambda i, j, l: (i, l), lambda i, j, l: (l, i),
             lambda i, j, l: (l, j), lambda i, j, l: (i, j)],
            [(bm, bm), (bm, bm), (bm, bn), (bm, bn)],
            lambda i, j, l: (i, j), (bm, bn),
            ("parallel", "parallel", "arbitrary"), (m, n))

    operands = [a, a, b] + ([c] if has_c else [])
    in_specs = [pl.BlockSpec(blk, f)
                for blk, f in zip(in_blocks, in_maps)][: len(operands)]
    return pl.pallas_call(
        functools.partial(_symm_kernel, alpha=alpha, beta=beta, m=m, bm=bm,
                          has_c=has_c, off=off),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(out_block, out_map),
        out_shape=jax.ShapeDtypeStruct(out_shape, a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(dimension_semantics=semantics),
        interpret=interpret,
    )(*operands)
