"""SYMM Pallas TPU kernel: C := alpha*sym(A)@B + beta*C (left side, lower
storage).

A is stored in its lower triangle only.  The kernel receives **two views of
the same array** with mirrored index maps — block (i,l) and block (l,i) — and
reconstructs the symmetric block on the fly:

    i > l : A[i,l] is in the stored lower triangle           → use view 1
    i < l : sym(A)[i,l] = A[l,i]^T, A[l,i] stored            → use view 2^T
    i = l : diagonal block, mirror its own lower triangle

A-blocks are square (bm × bm) so the mirrored view has the same block shape.
Loading two views costs ≤2× A-tile traffic; the ADSALA tuner sees that cost
in its measured/For-oracle timings and sizes blocks accordingly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["symm_pallas"]


def _symm_kernel(a_il_ref, a_li_ref, b_ref, c_ref, o_ref, acc_ref, *,
                 alpha, beta):
    i = pl.program_id(0)
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_il = a_il_ref[...]
    a_li = a_li_ref[...]
    diag = jnp.tril(a_il) + jnp.tril(a_il, -1).T
    a = jnp.where(i > l, a_il, jnp.where(i < l, a_li.T, diag))
    acc_ref[...] += jnp.dot(a, b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(l == pl.num_programs(2) - 1)
    def _flush():
        out = alpha * acc_ref[...]
        if beta != 0.0:
            out = out + beta * c_ref[...].astype(jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "alpha", "beta",
                                             "interpret"))
def symm_pallas(a, b, c=None, *, bm: int = 128, bn: int = 128,
                alpha: float = 1.0, beta: float = 0.0,
                interpret: bool = False):
    m, m2 = a.shape
    mb, n = b.shape
    assert m == m2 == mb
    assert m % bm == 0 and n % bn == 0
    if c is None:
        c = jnp.zeros((m, n), a.dtype)
    grid = (m // bm, n // bn, m // bm)
    return pl.pallas_call(
        functools.partial(_symm_kernel, alpha=alpha, beta=beta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bm), lambda i, j, l: (i, l)),   # A[i,l]
            pl.BlockSpec((bm, bm), lambda i, j, l: (l, i)),   # A[l,i]
            pl.BlockSpec((bm, bn), lambda i, j, l: (l, j)),   # B[l,j]
            pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),   # C[i,j]
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, a, b, c)
