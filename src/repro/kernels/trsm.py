"""TRSM on TPU: solve tril(A) @ X = alpha * B (left, lower, non-unit).

A GPU TRSM serialises scalar forward substitution inside the kernel; the
TPU-native formulation (DESIGN.md §2, hardware adaptation) is *blocked
forward substitution driven by GEMM*:

    1. invert the (bm × bm) diagonal blocks once:  Dᵢ⁻¹
       (small triangular solves against I — XLA's triangular_solve, runs on
       the MXU; O(m·bm²) total, negligible vs. the O(m²·n) updates)
    2. for each block row i (sequential, ⌈m/bm⌉ steps):
         Rᵢ = alpha·Bᵢ − A[i, :i] @ X[:i]      ← Pallas GEMM (the hot loop)
         Xᵢ = Dᵢ⁻¹ @ Rᵢ                        ← Pallas GEMM (bm × bm × n)

This keeps >95% of the FLOPs inside the tuned Pallas GEMM; many production
BLAS (cuBLAS, oneMKL) use exactly this inversion-based scheme for large
TRSM.  The sequential loop over block rows is a Python loop at trace time —
the number of blocks is static.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .gemm import gemm_pallas

__all__ = ["trsm_pallas"]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "alpha", "variant",
                                             "interpret"))
def trsm_pallas(a, b, *, bm: int = 128, bn: int = 128, alpha: float = 1.0,
                variant: str = "full", interpret: bool = False):
    del variant  # blocked substitution already does minimal (tri) FLOPs
    m, m2 = a.shape
    mb, n = b.shape
    assert m == m2 == mb
    assert m % bm == 0 and n % bn == 0
    nblk = m // bm

    # 1. diagonal block inverses (batched small triangular solves)
    diag = jnp.stack([jax.lax.dynamic_slice(a, (i * bm, i * bm), (bm, bm))
                      for i in range(nblk)])                     # (nblk,bm,bm)
    eye = jnp.broadcast_to(jnp.eye(bm, dtype=a.dtype), diag.shape)
    dinv = jax.lax.linalg.triangular_solve(
        jnp.tril(diag), eye, left_side=True, lower=True)         # (nblk,bm,bm)

    # 2. blocked forward substitution; X accumulated block-row by block-row
    x = jnp.zeros((m, n), a.dtype)
    for i in range(nblk):
        r = alpha * jax.lax.dynamic_slice(b, (i * bm, 0), (bm, n))
        if i > 0:
            a_row = jax.lax.dynamic_slice(a, (i * bm, 0), (bm, i * bm))
            x_done = jax.lax.dynamic_slice(x, (0, 0), (i * bm, n))
            upd = gemm_pallas(a_row, x_done, bm=bm, bk=bm, bn=bn,
                              interpret=interpret)
            r = r - upd.astype(r.dtype)
        xi = gemm_pallas(dinv[i], r, bm=bm, bk=bm, bn=bn,
                         interpret=interpret)
        x = jax.lax.dynamic_update_slice(x, xi.astype(x.dtype), (i * bm, 0))
    return x
