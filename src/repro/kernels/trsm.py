"""TRSM on TPU: solve tril(A) @ X = alpha * B (left, lower, non-unit).

A GPU TRSM serialises scalar forward substitution inside the kernel; the
TPU-native formulation (DESIGN.md §2, hardware adaptation) is *blocked
forward substitution driven by GEMM*:

    1. invert the diagonal blocks once:  Dᵢ⁻¹
       (small triangular solves against I — XLA's triangular_solve, runs on
       the MXU; O(m·bm²) total, negligible vs. the O(m²·n) updates)
    2. for each block row i (sequential, ⌈m/bm⌉ steps):
         Rᵢ = alpha·Bᵢ − A[i, :i] @ X[:i]      ← Pallas GEMM (the hot loop)
         Xᵢ = Dᵢ⁻¹ @ Rᵢ                        ← Pallas GEMM (bm × bm × n)

This keeps >95% of the FLOPs inside the tuned Pallas GEMM; many production
BLAS (cuBLAS, oneMKL) use exactly this inversion-based scheme for large
TRSM.  The sequential loop over block rows is a Python loop at trace time —
the number of blocks is static, so every slice below is a *static* slice.

Zero-copy: the masked GEMM accepts ragged shapes directly, so no operand is
ever padded — the last (ragged) diagonal block is solved at its true
(r × r) size instead of the old identity-padded (bm × bm) solve, and a
leading batch axis flows through every step natively (batched
triangular_solve + batched GEMM grids), replacing the old ``jax.vmap``
lift.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .gemm import gemm_pallas

__all__ = ["trsm_pallas"]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "alpha", "variant",
                                             "interpret"))
def trsm_pallas(a, b, *, bm: int = 128, bn: int = 128, alpha: float = 1.0,
                variant: str = "full", interpret: bool = False):
    del variant  # blocked substitution already does minimal (tri) FLOPs
    *lead, m, m2 = a.shape
    mb, n = b.shape[-2:]
    assert m == m2 == mb
    assert len(lead) <= 1 and b.shape[:-2] == tuple(lead)
    nblk = -(-m // bm)

    x = jnp.zeros((*lead, m, n), a.dtype)
    for i in range(nblk):
        lo, hi = i * bm, min((i + 1) * bm, m)
        # diagonal block inverse at its true (possibly ragged) size
        d = jnp.tril(a[..., lo:hi, lo:hi])
        eye = jnp.eye(hi - lo, dtype=a.dtype)
        if lead:
            eye = jnp.broadcast_to(eye, d.shape)
        dinv = jax.lax.linalg.triangular_solve(d, eye, left_side=True,
                                               lower=True)
        r = alpha * b[..., lo:hi, :]
        if i > 0:
            upd = gemm_pallas(a[..., lo:hi, :lo], x[..., :lo, :],
                              bm=bm, bk=bm, bn=bn, interpret=interpret)
            r = r - upd.astype(r.dtype)
        xi = gemm_pallas(dinv, r, bm=bm, bk=bm, bn=bn, interpret=interpret)
        x = jax.lax.dynamic_update_slice(
            x, xi.astype(x.dtype), (0,) * len(lead) + (lo, 0))
    return x
