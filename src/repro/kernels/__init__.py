"""BLAS L3 on TPU: Pallas kernels (+ BlockSpec VMEM tiling) with ADSALA
runtime block selection, pure-jnp oracles, and the numpy blocked "black-box
BLAS" used for wall-clock calibration on CPU hosts."""

from . import ops, ref
from .gemm import gemm_pallas
from .symm import symm_pallas
from .syrk import syr2k_pallas, syrk_pallas
from .trmm import trmm_pallas
from .trsm import trsm_pallas

__all__ = ["ops", "ref", "gemm_pallas", "symm_pallas", "syrk_pallas",
           "syr2k_pallas", "trmm_pallas", "trsm_pallas"]
