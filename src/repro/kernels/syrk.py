"""SYRK / SYR2K Pallas TPU kernels (lower-triangle rank-k updates).

  syrk : C := alpha*A@A^T + beta*C          A(n,k), C(n,n)
  syr2k: C := alpha*(A@B^T + B@A^T) + beta*C

Two kernel variants, selectable by the ADSALA knob (DESIGN.md §7.4):

  'full' — every (i, j) output block is computed (both triangles): simple,
           maximally parallel grid, 2× the minimal FLOPs.
  'tri'  — blocks strictly above the diagonal skip the MXU work
           (``pl.when(j <= i)``) and emit zeros; the caller mirrors the lower
           triangle afterwards.  ~half the FLOPs, but the skipped cells still
           pay grid/DMA overhead — which of the two wins is shape- and
           hardware-dependent, exactly the trade-off the ML model learns.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["syrk_pallas", "syr2k_pallas"]


def _syrk_kernel(a_i_ref, a_j_ref, c_ref, o_ref, acc_ref, *,
                 alpha, beta, tri):
    i, j, l = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    compute = (j <= i) if tri else (j == j)  # tri: skip upper blocks

    @pl.when(compute)
    def _acc():
        acc_ref[...] += jnp.dot(a_i_ref[...], a_j_ref[...].T,
                                preferred_element_type=jnp.float32)

    @pl.when(l == pl.num_programs(2) - 1)
    def _flush():
        out = alpha * acc_ref[...]
        if beta != 0.0:
            out = out + beta * c_ref[...].astype(jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)


def _syr2k_kernel(a_i_ref, b_j_ref, b_i_ref, a_j_ref, c_ref, o_ref, acc_ref,
                  *, alpha, beta, tri):
    i, j, l = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    compute = (j <= i) if tri else (j == j)

    @pl.when(compute)
    def _acc():
        acc_ref[...] += jnp.dot(a_i_ref[...], b_j_ref[...].T,
                                preferred_element_type=jnp.float32)
        acc_ref[...] += jnp.dot(b_i_ref[...], a_j_ref[...].T,
                                preferred_element_type=jnp.float32)

    @pl.when(l == pl.num_programs(2) - 1)
    def _flush():
        out = alpha * acc_ref[...]
        if beta != 0.0:
            out = out + beta * c_ref[...].astype(jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)


def _mirror_lower(x):
    return jnp.tril(x) + jnp.tril(x, -1).T


@functools.partial(jax.jit, static_argnames=("bm", "bk", "alpha", "beta",
                                             "variant", "interpret"))
def syrk_pallas(a, c=None, *, bm: int = 128, bk: int = 128,
                alpha: float = 1.0, beta: float = 0.0,
                variant: str = "full", interpret: bool = False):
    n, k = a.shape
    assert n % bm == 0 and k % bk == 0
    if c is None:
        c = jnp.zeros((n, n), a.dtype)
    if variant == "tri":
        c = jnp.tril(c)  # upper blocks emit beta*0; mirrored afterwards
    grid = (n // bm, n // bm, k // bk)
    out = pl.pallas_call(
        functools.partial(_syrk_kernel, alpha=alpha, beta=beta,
                          tri=(variant == "tri")),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),   # A[i,l]
            pl.BlockSpec((bm, bk), lambda i, j, l: (j, l)),   # A[j,l]
            pl.BlockSpec((bm, bm), lambda i, j, l: (i, j)),   # C[i,j]
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bm), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, a, c)
    if variant == "tri":
        out = _mirror_lower(out)
    return out


@functools.partial(jax.jit, static_argnames=("bm", "bk", "alpha", "beta",
                                             "variant", "interpret"))
def syr2k_pallas(a, b, c=None, *, bm: int = 128, bk: int = 128,
                 alpha: float = 1.0, beta: float = 0.0,
                 variant: str = "full", interpret: bool = False):
    n, k = a.shape
    assert a.shape == b.shape
    assert n % bm == 0 and k % bk == 0
    if c is None:
        c = jnp.zeros((n, n), a.dtype)
    if variant == "tri":
        c = jnp.tril(c)
    grid = (n // bm, n // bm, k // bk)
    out = pl.pallas_call(
        functools.partial(_syr2k_kernel, alpha=alpha, beta=beta,
                          tri=(variant == "tri")),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),   # A[i,l]
            pl.BlockSpec((bm, bk), lambda i, j, l: (j, l)),   # B[j,l]
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),   # B[i,l]
            pl.BlockSpec((bm, bk), lambda i, j, l: (j, l)),   # A[j,l]
            pl.BlockSpec((bm, bm), lambda i, j, l: (i, j)),   # C[i,j]
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bm), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, b, a, c)
    if variant == "tri":
        out = _mirror_lower(out)
    return out
