"""SYRK / SYR2K Pallas TPU kernels (lower-triangle rank-k updates).

  syrk : C := alpha*A@A^T + beta*C          A(n,k), C(n,n)
  syr2k: C := alpha*(A@B^T + B@A^T) + beta*C

Three kernel variants, selectable by the ADSALA knob (DESIGN.md §7.4):

  'full'       — every (i, j) output block is computed (both triangles):
                 simple, maximally parallel grid, 2× the minimal FLOPs.
  'tri'        — a full n² grid where blocks strictly above the diagonal
                 skip the MXU work (``pl.when(j <= i)``) and emit zeros;
                 the caller mirrors the lower triangle afterwards as an XLA
                 pass.  ~half the FLOPs, but the skipped cells still pay
                 grid/DMA overhead.
  'tri_packed' — only the n(n+1)/2 lower-triangle blocks are launched: a
                 flattened grid index t de-triangularizes to (i, j) inside
                 the BlockSpec index maps, and the mirror is done in-kernel
                 — after the k loop flushes block (i, j), one extra grid
                 step per tile stores the transposed tile to (j, i) from
                 VMEM scratch (no tril + trilᵀ XLA pass, no dead grid
                 cells).  Grid = (T, nk+1) with T = nb(nb+1)/2: exactly the
                 packed tile count times the k steps, plus the write-only
                 mirror step.

Which variant wins is shape- and hardware-dependent — exactly the trade-off
the ML model learns.

Zero-copy: all grids are ⌈·⌉-sized over the unpadded operands with in-kernel
ragged-tail masking (see gemm.py); C is only an input when ``beta != 0``; a
leading batch axis becomes a leading grid dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._batching import with_batch_axis
from ._compat import CompilerParams
from .gemm import mask_cols

__all__ = ["syrk_pallas", "syr2k_pallas", "detri", "tri_count"]


def tri_count(i):
    """Lower-triangle block count up to row ``i`` (exclusive): i(i+1)/2."""
    return (i * (i + 1)) // 2


def detri(t):
    """Flattened packed index -> (i, j) with j <= i (row-major over the
    lower triangle).  float32 sqrt seed + exact integer correction, so it
    is exact for any block count a real grid could reach."""
    i = ((jnp.sqrt(8.0 * t.astype(jnp.float32) + 1.0) - 1.0) / 2.0) \
        .astype(jnp.int32)
    i = jnp.where(tri_count(i) > t, i - 1, i)
    i = jnp.where(tri_count(i + 1) <= t, i + 1, i)
    return i, t - tri_count(i)


def _sym_lower(x):
    return jnp.tril(x) + jnp.tril(x, -1).T


# ---------------------------------------------------------------------------
# full / tri kernels: rectangular (i, j, l) grid
# ---------------------------------------------------------------------------

def _rank_k_kernel(*refs, alpha, beta, k, bk, tri, two, has_c, off):
    """Shared syrk/syr2k body.  ``two`` adds the B@Aᵀ term (syr2k); refs =
    (a_i, a_j[, b_i, b_j][, c], o, acc)."""
    pos = 2 + (2 if two else 0)
    a_i_ref, a_j_ref = refs[0], refs[1]
    b_i_ref, b_j_ref = (refs[2], refs[3]) if two else (None, None)
    c_ref = refs[pos] if has_c else None
    o_ref, acc_ref = refs[-2], refs[-1]
    i = pl.program_id(off + 0)
    j = pl.program_id(off + 1)
    l = pl.program_id(off + 2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    compute = (j <= i) if tri else (j == j)  # tri: skip upper blocks

    @pl.when(compute)
    def _acc():
        a_i = a_i_ref[0] if off else a_i_ref[...]
        a_j = a_j_ref[0] if off else a_j_ref[...]
        if k % bk:
            a_i = mask_cols(a_i, bk, l, k)
            a_j = mask_cols(a_j, bk, l, k)
        if two:
            b_i = b_i_ref[0] if off else b_i_ref[...]
            b_j = b_j_ref[0] if off else b_j_ref[...]
            if k % bk:
                b_i = mask_cols(b_i, bk, l, k)
                b_j = mask_cols(b_j, bk, l, k)
            acc_ref[...] += jnp.dot(a_i, b_j.T,
                                    preferred_element_type=jnp.float32)
            acc_ref[...] += jnp.dot(b_i, a_j.T,
                                    preferred_element_type=jnp.float32)
        else:
            acc_ref[...] += jnp.dot(a_i, a_j.T,
                                    preferred_element_type=jnp.float32)

    @pl.when(l == pl.num_programs(off + 2) - 1)
    def _flush():
        out = alpha * acc_ref[...]
        if has_c:
            c = c_ref[0] if off else c_ref[...]
            if tri:
                # tri treats C as lower-stored symmetric: zero its strict
                # upper in-kernel (the old path ran a jnp.tril pre-pass)
                c = jnp.where(j < i, c, jnp.tril(c))
            out = out + beta * c.astype(jnp.float32)
        if off:
            o_ref[0] = out.astype(o_ref.dtype)
        else:
            o_ref[...] = out.astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# tri_packed kernel: (T, nk+1) packed grid with in-kernel mirror
# ---------------------------------------------------------------------------

def _rank_k_packed_kernel(*refs, alpha, beta, k, bk, nk, two, has_c, off):
    """Packed lower-triangle grid.  Steps l < nk accumulate block (i, j)
    with j <= i; step l == nk-1 flushes it (diag blocks symmetrized
    in-kernel) and parks the tile in ``mir_ref``; the extra step l == nk
    stores the transposed tile to block (j, i) — the mirror without any
    XLA post-pass."""
    pos = 2 + (2 if two else 0)
    a_i_ref, a_j_ref = refs[0], refs[1]
    b_i_ref, b_j_ref = (refs[2], refs[3]) if two else (None, None)
    c_ref = refs[pos] if has_c else None
    o_ref, acc_ref, mir_ref = refs[-3], refs[-2], refs[-1]
    t = pl.program_id(off + 0)
    l = pl.program_id(off + 1)
    i, j = detri(t)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(l < nk)
    def _acc():
        a_i = a_i_ref[0] if off else a_i_ref[...]
        a_j = a_j_ref[0] if off else a_j_ref[...]
        if k % bk:
            a_i = mask_cols(a_i, bk, l, k)
            a_j = mask_cols(a_j, bk, l, k)
        if two:
            b_i = b_i_ref[0] if off else b_i_ref[...]
            b_j = b_j_ref[0] if off else b_j_ref[...]
            if k % bk:
                b_i = mask_cols(b_i, bk, l, k)
                b_j = mask_cols(b_j, bk, l, k)
            acc_ref[...] += jnp.dot(a_i, b_j.T,
                                    preferred_element_type=jnp.float32)
            acc_ref[...] += jnp.dot(b_i, a_j.T,
                                    preferred_element_type=jnp.float32)
        else:
            acc_ref[...] += jnp.dot(a_i, a_j.T,
                                    preferred_element_type=jnp.float32)

    @pl.when(l == nk - 1)
    def _flush():
        out = alpha * acc_ref[...]
        if has_c:
            c = c_ref[0] if off else c_ref[...]
            c = jnp.where(j < i, c, jnp.tril(c))   # lower-stored C
            out = out + beta * c.astype(jnp.float32)
        # diagonal blocks: keep the lower triangle and mirror it, exactly
        # like the tri variant's tril + trilᵀ post-pass restricted to the
        # block — off-diagonal lower blocks pass through
        out = jnp.where(j < i, out, _sym_lower(out))
        mir_ref[...] = out
        res = out.astype(o_ref.dtype)
        if off:
            o_ref[0] = res
        else:
            o_ref[...] = res

    @pl.when(l == nk)
    def _mirror():
        res = mir_ref[...].T.astype(o_ref.dtype)
        if off:
            o_ref[0] = res
        else:
            o_ref[...] = res


def _rank_k_call(a, b, c, *, bm, bk, alpha, beta, variant, interpret, two):
    *lead, n, k = a.shape
    assert b is None or b.shape == a.shape
    assert len(lead) <= 1
    batch = lead[0] if lead else None
    has_c = c is not None and beta != 0.0
    off = 1 if batch is not None else 0
    nb, nk = pl.cdiv(n, bm), pl.cdiv(k, bk)

    # operand order: A twice (row-i / row-j views), then B twice for syr2k,
    # then the optional C
    ops_ = [a, a] + ([b, b] if two else []) + ([c] if has_c else [])
    ab_blocks = [(bm, bk)] * (4 if two else 2) + [(bm, bm)] * int(has_c)

    if variant == "tri_packed":
        grid2 = (tri_count(nb), nk + 1)

        def row_i(t, l):
            return (detri(t)[0], jnp.minimum(l, nk - 1))

        def row_j(t, l):
            return (detri(t)[1], jnp.minimum(l, nk - 1))

        def c_map(t, l):
            return detri(t)

        def out_map2(t, l):
            i, j = detri(t)
            mirror = l == nk
            return (jnp.where(mirror, j, i), jnp.where(mirror, i, j))

        in_maps = ([row_i, row_j] * (2 if two else 1) +
                   ([c_map] if has_c else []))
        kernel = functools.partial(_rank_k_packed_kernel, alpha=alpha,
                                   beta=beta, k=k, bk=bk, nk=nk, two=two,
                                   has_c=has_c, off=off)
        semantics = ("arbitrary", "arbitrary")
        scratch = [pltpu.VMEM((bm, bm), jnp.float32),
                   pltpu.VMEM((bm, bm), jnp.float32)]
        out_map = out_map2
    else:
        grid2 = (nb, nb, nk)

        def mk(sel):
            return lambda i, j, l: (sel(i, j), l)

        in_maps = ([mk(lambda i, j: i), mk(lambda i, j: j)] *
                   (2 if two else 1) +
                   ([lambda i, j, l: (i, j)] if has_c else []))
        kernel = functools.partial(_rank_k_kernel, alpha=alpha, beta=beta,
                                   k=k, bk=bk, tri=(variant == "tri"),
                                   two=two, has_c=has_c, off=off)
        semantics = ("parallel", "parallel", "arbitrary")
        scratch = [pltpu.VMEM((bm, bm), jnp.float32)]
        out_map = lambda i, j, l: (i, j)              # noqa: E731

    grid, in_maps, ab_blocks, out_map, out_block, semantics, out_shape = \
        with_batch_axis(batch, grid2, in_maps, ab_blocks, out_map,
                        (bm, bm), semantics, (n, n))

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(blk, f)
                  for blk, f in zip(ab_blocks, in_maps)],
        out_specs=pl.BlockSpec(out_block, out_map),
        out_shape=jax.ShapeDtypeStruct(out_shape, a.dtype),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(dimension_semantics=semantics),
        interpret=interpret,
    )(*ops_)
    if variant == "tri":
        out = jnp.tril(out) + jnp.tril(out, -1).swapaxes(-1, -2)
    return out


@functools.partial(jax.jit, static_argnames=("bm", "bk", "alpha", "beta",
                                             "variant", "interpret"))
def syrk_pallas(a, c=None, *, bm: int = 128, bk: int = 128,
                alpha: float = 1.0, beta: float = 0.0,
                variant: str = "full", interpret: bool = False):
    return _rank_k_call(a, None, c, bm=bm, bk=bk, alpha=alpha, beta=beta,
                        variant=variant, interpret=interpret, two=False)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "alpha", "beta",
                                             "variant", "interpret"))
def syr2k_pallas(a, b, c=None, *, bm: int = 128, bk: int = 128,
                 alpha: float = 1.0, beta: float = 0.0,
                 variant: str = "full", interpret: bool = False):
    return _rank_k_call(a, b, c, bm=bm, bk=bk, alpha=alpha, beta=beta,
                        variant=variant, interpret=interpret, two=True)
