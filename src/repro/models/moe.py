"""Mixture-of-Experts FFN with sort-based capacity dispatch (EP-shardable).

Dispatch avoids the O(tokens·E·capacity) one-hot einsums of the classic
Mesh-TF formulation (which would *double* the model's FLOPs at 32k context —
see DESIGN.md roofline notes): tokens are routed by argsort over expert ids,
position-in-expert comes from segment arithmetic on the sorted array, and
dispatch/combine are scatter/gather (data movement, no FLOPs).

Per-sequence grouping keeps dispatch local to the data shard; the expert
einsum's (experts → 'model') sharding constraint induces the all-to-all.
Fixed capacity C = ⌈S·top_k/E · capacity_factor⌉ with token dropping
(standard at scale); the router's load-balance auxiliary loss is returned
for the trainer to add.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import Ctx, init_linear, init_mlp, linear, mlp

__all__ = ["init_moe", "moe_ffn"]


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": init_linear(ks[0], d, e, dtype="float32"),  # router in f32
        "wg": (jax.random.normal(ks[1], (e, d, f)) * scale).astype(cfg.param_dtype),
        "wu": (jax.random.normal(ks[2], (e, d, f)) * scale).astype(cfg.param_dtype),
        "wd": (jax.random.normal(ks[3], (e, f, d)) * (1.0 / jnp.sqrt(f))
               ).astype(cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, cfg.n_shared_experts * f,
                               mlp_type="swiglu", dtype=cfg.param_dtype)
    return p


def _expert_matmul(t, w, ctx: Ctx):
    """Per-expert matmul ``einsum("becd,edf->becf", t, w)`` with optional
    ADSALA dispatch: when the config routes GEMMs, the (B,E,C,·) slab is
    folded to an expert-major stack (E, B·C, ·) and executed as one stacked
    ``run_op("gemm", ...)`` call — one knob decision covers all experts."""
    if not ctx.routes_gemm(t):
        return jnp.einsum("becd,edf->becf", t, w)
    from repro.kernels import ops as kops
    B, E, C, D = t.shape
    kw = {}
    if ctx.cfg.gemm_interpret is not None:
        kw["interpret"] = ctx.cfg.gemm_interpret
    t3 = t.swapaxes(0, 1).reshape(E, B * C, D)
    y = kops.run_op("gemm", (t3, w), backend=ctx.cfg.gemm_backend,
                    runtime=ctx.runtime, stacked=True, **kw)
    return y.reshape(E, B, C, -1).swapaxes(0, 1)


def _positions_in_expert(e_flat: jax.Array) -> jax.Array:
    """For each slot (sorted-stable by expert id), its rank within its
    expert.  e_flat: (G, S*K) int32 → (G, S*K) int32."""
    sk = e_flat.shape[-1]
    order = jnp.argsort(e_flat, axis=-1, stable=True)
    se = jnp.take_along_axis(e_flat, order, axis=-1)
    idx = jnp.arange(sk)[None, :]
    boundary = jnp.concatenate(
        [jnp.ones_like(se[:, :1], bool), se[:, 1:] != se[:, :-1]], axis=-1)
    seg_start = jax.lax.cummax(jnp.where(boundary, idx, 0), axis=1)
    pos_sorted = idx - seg_start
    inv = jnp.argsort(order, axis=-1)
    return jnp.take_along_axis(pos_sorted, inv, axis=-1)


def moe_ffn(p: dict, x, ctx: Ctx):
    """x: (B, S, D) → (out (B, S, D), aux_loss scalar)."""
    cfg = ctx.cfg
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(1, int(-(-S * K // E) * cfg.capacity_factor))
    if S > 1:
        C = -(-C // 64) * 64      # align for capacity ("slot") sharding

    # --- routing (f32) ------------------------------------------------------
    logits = (x.astype(jnp.float32) @ p["router"]["w"])          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                        # (B,S,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E · Σ_e f_e · p̄_e
    density = jnp.mean(jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32),
                       axis=(0, 1))
    p_mean = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(density * p_mean)

    # --- slot bookkeeping ----------------------------------------------------
    e_flat = top_e.reshape(B, S * K)                              # (B, SK)
    w_flat = top_p.reshape(B, S * K)
    pos = _positions_in_expert(e_flat)                            # (B, SK)
    keep = (pos < C)
    dest = jnp.where(keep, e_flat * C + pos, E * C)               # drop → pad row

    # --- dispatch (scatter, batch-local) --------------------------------------
    x_slots = jnp.repeat(x, K, axis=1).reshape(B, S * K, D)       # token s → K slots
    x_slots = ctx.cons(x_slots, "batch", None, "embed")
    dest = ctx.cons(dest, "batch", None)
    buf = jnp.zeros((B, E * C + 1, D), x.dtype)
    buf = ctx.cons(buf, "batch", None, None)
    bidx = jnp.arange(B)[:, None]
    buf = buf.at[bidx, dest].add(x_slots * keep[..., None].astype(x.dtype))
    buf = ctx.cons(buf, "batch", None, None)
    buf = buf[:, : E * C].reshape(B, E, C, D)
    # EP when experts divide the TP axis; otherwise slot-parallel over the
    # capacity dim (expert_cap → 'model') with replicated expert weights
    buf = ctx.cons(buf, "batch", "experts", "expert_cap", None)

    # --- expert FFN (EP over 'model') ----------------------------------------
    wg, wu, wd = (ctx.cast(p["wg"]), ctx.cast(p["wu"]), ctx.cast(p["wd"]))
    h = jax.nn.silu(_expert_matmul(buf, wg, ctx)) * \
        _expert_matmul(buf, wu, ctx)
    y = _expert_matmul(h, wd, ctx)
    y = ctx.cons(y, "batch", "experts", "expert_cap", None)

    # --- combine (gather) ------------------------------------------------------
    y = y.reshape(B, E * C, D)
    y = jnp.concatenate([y, jnp.zeros((B, 1, D), y.dtype)], axis=1)
    gathered = jnp.take_along_axis(y, dest[..., None], axis=1)    # (B,SK,D)
    gathered = gathered * (w_flat * keep)[..., None].astype(y.dtype)
    out = gathered.reshape(B, S, K, D).sum(axis=2)
    out = ctx.cons(out, "batch", "seq", "embed")

    if "shared" in p:
        out = out + mlp(p["shared"], x, ctx)
    return out, aux
