"""Model assembler: segment-scanned layer stacks for all 10 architectures.

The layer stack is a list of (block_kind, repeat) segments (configs/base.py);
per-segment params are stacked along a leading layer axis and consumed by
``jax.lax.scan`` — HLO size stays O(#segments) regardless of depth, which is
what keeps 512-device dry-run compiles tractable.  Decode caches are pytrees
stacked the same way and threaded through the scan as xs/ys.

Block kinds:
  attn        pre-LN GQA attention + MLP            (dense, vlm backbone)
  moe         pre-LN attention (GQA or MLA) + MoE   (granite-moe, deepseek)
  mamba2      pre-LN Mamba2 mixer                   (zamba2 tail)
  zamba_super k× mamba2 + one SHARED attn+MLP block (zamba2)
  rwkv6       self-contained RWKV6 block            (rwkv6)
  enc         bidirectional attention + MLP          (whisper encoder)
  dec_cross   causal self-attn + cross-attn + MLP    (whisper decoder)
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from .layers import (Ctx, attention, cross_entropy, embed, init_attention,
                     init_embedding, init_mlp, init_norm, linear, mlp,
                     rmsnorm, routed_matmul)
from .mamba2 import init_mamba2, init_mamba2_state, mamba2_mixer
from .mla import init_mla, init_mla_cache, mla_attention
from .moe import init_moe, moe_ffn
from .rwkv6 import init_rwkv6, init_rwkv6_state, rwkv6_block

__all__ = ["init_params", "forward", "loss_fn", "init_decode_state",
           "prefill", "decode_step", "param_count"]


# ---------------------------------------------------------------------------
# per-block init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if kind in ("attn", "enc"):
        att = (init_mla(ks[0], cfg) if (cfg.use_mla and kind == "attn")
               else init_attention(ks[0], cfg))
        return {"ln1": init_norm(d, cfg.param_dtype), "attn": att,
                "ln2": init_norm(d, cfg.param_dtype),
                "mlp": init_mlp(ks[1], d, cfg.d_ff, mlp_type=cfg.mlp_type,
                                dtype=cfg.param_dtype)}
    if kind == "moe":
        att = (init_mla(ks[0], cfg) if cfg.use_mla
               else init_attention(ks[0], cfg))
        return {"ln1": init_norm(d, cfg.param_dtype), "attn": att,
                "ln2": init_norm(d, cfg.param_dtype),
                "moe": init_moe(ks[1], cfg)}
    if kind == "mamba2":
        return {"ln": init_norm(d, cfg.param_dtype),
                "mixer": init_mamba2(ks[0], cfg)}
    if kind == "rwkv6":
        return init_rwkv6(ks[0], cfg)
    if kind == "zamba_super":
        inner = jax.vmap(lambda k: _init_block(k, cfg, "mamba2"))(
            jax.random.split(ks[0], cfg.shared_attn_every))
        return {"mamba": inner,
                "in_proj": {"w": (jax.random.normal(ks[1], (2 * d, d)) /
                                  math.sqrt(2 * d)).astype(cfg.param_dtype)}}
    if kind == "dec_cross":
        return {"ln1": init_norm(d, cfg.param_dtype),
                "attn": init_attention(ks[0], cfg),
                "ln_x": init_norm(d, cfg.param_dtype),
                "xattn": init_attention(ks[1], cfg),
                "ln2": init_norm(d, cfg.param_dtype),
                "mlp": init_mlp(ks[2], d, cfg.d_ff, mlp_type=cfg.mlp_type,
                                dtype=cfg.param_dtype)}
    raise ValueError(kind)


def init_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    params: dict = {"embed": init_embedding(ks[0], cfg.vocab, cfg.d_model,
                                            cfg.param_dtype),
                    "final_norm": init_norm(cfg.d_model, cfg.param_dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": (jax.random.normal(ks[1], (cfg.d_model, cfg.vocab)) * 0.02
                  ).astype(cfg.param_dtype)}
    segs = []
    for i, (kind, repeat) in enumerate(cfg.segments()):
        seg_keys = jax.random.split(jax.random.fold_in(ks[2], i), repeat)
        segs.append(jax.vmap(lambda k, kd=kind: _init_block(k, cfg, kd))(
            seg_keys))
    params["segments"] = segs
    if cfg.family == "hybrid":
        params["shared_attn"] = {
            "ln1": init_norm(cfg.d_model, cfg.param_dtype),
            "attn": init_attention(ks[3], cfg),
            "ln2": init_norm(cfg.d_model, cfg.param_dtype),
            "mlp": init_mlp(ks[4], cfg.d_model, cfg.d_ff,
                            mlp_type=cfg.mlp_type, dtype=cfg.param_dtype)}
    if cfg.family == "audio":
        enc_keys = jax.random.split(ks[5], cfg.n_enc_layers)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: _init_block(k, cfg, "enc"))(enc_keys),
            "norm": init_norm(cfg.d_model, cfg.param_dtype)}
    if cfg.family == "vlm":
        params["vision_proj"] = {
            "w": (jax.random.normal(ks[6], (cfg.d_model, cfg.d_model)) /
                  math.sqrt(cfg.d_model)).astype(cfg.param_dtype)}
    return params


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# per-block apply — returns (x, new_cache, aux)
# ---------------------------------------------------------------------------

def _shared_attn_block(shared_p, in_proj, x, x0, ctx, cache):
    cat = jnp.concatenate([x, x0], axis=-1)
    u = routed_matmul(cat, ctx.cast(in_proj["w"]), ctx)
    a, new_cache = attention(shared_p["attn"], rmsnorm(shared_p["ln1"], u),
                             ctx, cache=cache)
    u = u + a
    u = u + mlp(shared_p["mlp"], rmsnorm(shared_p["ln2"], u), ctx)
    return x + u, new_cache


def _apply_block(kind: str, p: dict, x, ctx: Ctx, cache, *, shared=None,
                 x0=None, enc_out=None):
    cfg = ctx.cfg
    zero = jnp.zeros((), jnp.float32)
    if kind in ("attn", "enc"):
        if cfg.use_mla and kind == "attn":
            a, nc = mla_attention(p["attn"], rmsnorm(p["ln1"], x), ctx,
                                  cache=cache)
        else:
            a, nc = attention(p["attn"], rmsnorm(p["ln1"], x), ctx,
                              causal=(kind == "attn"), cache=cache,
                              use_rope=(cfg.family != "audio"))
        x = x + a
        x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x), ctx)
        return x, nc, zero
    if kind == "moe":
        if cfg.use_mla:
            a, nc = mla_attention(p["attn"], rmsnorm(p["ln1"], x), ctx,
                                  cache=cache)
        else:
            a, nc = attention(p["attn"], rmsnorm(p["ln1"], x), ctx,
                              cache=cache)
        x = x + a
        m, aux = moe_ffn(p["moe"], rmsnorm(p["ln2"], x), ctx)
        return x + m, nc, aux
    if kind == "mamba2":
        m, ns = mamba2_mixer(p["mixer"], rmsnorm(p["ln"], x), ctx,
                             state=cache)
        return x + m, ns, zero
    if kind == "rwkv6":
        y, ns = rwkv6_block(p, x, ctx, state=cache)
        return y, ns, zero
    if kind == "zamba_super":
        mamba_cache = cache["mamba"] if cache is not None else None

        def inner(carry, xs):
            h = carry
            pp = xs[0] if cache is not None else xs
            cc = xs[1] if cache is not None else None
            h, nc2, _ = _apply_block("mamba2", pp, h, ctx, cc)
            return h, nc2

        xs = (p["mamba"], mamba_cache) if cache is not None else p["mamba"]
        x, new_mamba = jax.lax.scan(inner, x, xs)
        attn_cache = cache["attn"] if cache is not None else None
        x, new_attn = _shared_attn_block(shared, p["in_proj"], x, x0, ctx,
                                         attn_cache)
        nc = ({"mamba": new_mamba, "attn": new_attn}
              if cache is not None else None)
        return x, nc, zero
    if kind == "dec_cross":
        a, nc = attention(p["attn"], rmsnorm(p["ln1"], x), ctx, cache=cache,
                          use_rope=False)
        x = x + a
        c, _ = attention(p["xattn"], rmsnorm(p["ln_x"], x), ctx,
                         kv_x=enc_out, causal=False, use_rope=False)
        x = x + c
        x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x), ctx)
        return x, nc, zero
    raise ValueError(kind)


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _nest_factors(repeat: int) -> tuple[int, int]:
    """Factor repeat = r1·r2 minimising r1+r2 (nested-scan remat grouping)."""
    best = (1, repeat)
    for a in range(2, int(math.isqrt(repeat)) + 1):
        if repeat % a == 0:
            best = (repeat // a, a)
    return best


def _scan_stack(body, carry, xs, repeat: int, cfg: ModelConfig):
    """Scan ``body`` over a layer stack with the configured remat scheme.

    remat="nested": two-level scan — outer body is checkpointed, so only
    ⌈repeat/r2⌉ inter-layer carries survive to the backward pass instead of
    ``repeat`` (the dominant activation-memory term at depth; §Perf).
    """
    if cfg.remat == "nested" and repeat >= 8:
        r1, r2 = _nest_factors(repeat)
        if r1 > 1 and r2 > 1:
            xs2 = jax.tree.map(
                lambda t: t.reshape(r1, r2, *t.shape[1:]), xs)
            inner_body = jax.checkpoint(body)

            @jax.checkpoint
            def outer(c, xs_grp):
                return jax.lax.scan(inner_body, c, xs_grp)

            carry, ys = jax.lax.scan(outer, carry, xs2)
            ys = jax.tree.map(
                lambda t: t.reshape(repeat, *t.shape[2:]), ys) \
                if ys is not None else None
            return carry, ys
    return jax.lax.scan(_maybe_remat(body, cfg), carry, xs)


def _run_segments(params, x, ctx: Ctx, caches=None, *, x0=None,
                  enc_out=None):
    """Scan every segment; returns (x, new_caches|None, aux_sum)."""
    cfg = ctx.cfg
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = [] if caches is not None else None
    shared = params.get("shared_attn")
    for si, (kind, repeat) in enumerate(cfg.segments()):
        seg_p = params["segments"][si]
        seg_c = caches[si] if caches is not None else None

        def body(carry, xs, kind=kind):
            h, aux = carry
            if caches is not None:
                pp, cc = xs
            else:
                pp, cc = xs, None
            h, nc, a = _apply_block(kind, pp, h, ctx, cc, shared=shared,
                                    x0=x0, enc_out=enc_out)
            # inter-block activation layout (SP shards seq here) — this is
            # also the layout of the saved scan carries
            h = ctx.cons(h, "batch", "seq", "embed")
            return (h, aux + a), nc

        xs = (seg_p, seg_c) if caches is not None else seg_p
        (x, aux_total), seg_nc = _scan_stack(body, (x, aux_total), xs,
                                             repeat, cfg)
        if caches is not None:
            new_caches.append(seg_nc)
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# sinusoidal positions (whisper — no RoPE)
# ---------------------------------------------------------------------------

def _sinusoid(seq: int, d: int, offset=0):
    pos = offset + jnp.arange(seq)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d, 2).astype(jnp.float32) *
                  (-math.log(10000.0) / d))
    pe = jnp.zeros((seq, d))
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def _run_encoder(params, frames, ctx: Ctx):
    """Whisper encoder over stubbed frame embeddings (B, enc_seq, D)."""
    x = frames.astype(ctx.cfg.compute_dtype)
    x = x + _sinusoid(x.shape[1], x.shape[2]).astype(x.dtype)[None]

    def body(h, pp):
        h, _, _ = _apply_block("enc", pp, h, ctx, None)
        return h, None

    x, _ = jax.lax.scan(_maybe_remat(body, ctx.cfg),
                        x, params["encoder"]["blocks"])  # unit: 'enc'
    return rmsnorm(params["encoder"]["norm"], x)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch, ctx: Ctx):
    cfg = ctx.cfg
    x = embed(params["embed"], batch["tokens"], ctx)
    if cfg.family == "vlm":
        vis = routed_matmul(batch["vision"].astype(x.dtype),
                            ctx.cast(params["vision_proj"]["w"]), ctx)
        x = jnp.concatenate([vis, x], axis=1)
        x = ctx.cons(x, "batch", "seq", "embed")
    if cfg.family == "audio":
        x = x + _sinusoid(x.shape[1], x.shape[2]).astype(x.dtype)[None]
    return x


def _logits(params, x, ctx: Ctx):
    x = rmsnorm(params["final_norm"], x)
    if "lm_head" in params:
        w = ctx.cast(params["lm_head"]["w"])
    else:
        w = ctx.cast(params["embed"]["table"]).T
    logits = routed_matmul(x, w, ctx)
    return ctx.cons(logits, "batch", None, "vocab")


def forward(params, batch, cfg: ModelConfig, *, mesh=None, rules=None,
            runtime=None):
    """batch: {tokens (B,S); [frames|vision]} → (logits, aux).
    ``runtime`` — AdsalaRuntime serving the routed matmuls' knob decisions
    when the config routes (None → the process-global runtime)."""
    from .sharding import DEFAULT_RULES
    ctx = Ctx(cfg, mesh, rules or DEFAULT_RULES, runtime)
    x = _embed_inputs(params, batch, ctx)
    enc_out = (_run_encoder(params, batch["frames"], ctx)
               if cfg.family == "audio" else None)
    x, _, aux = _run_segments(params, x, ctx, x0=x, enc_out=enc_out)
    return _logits(params, x, ctx), aux


def loss_fn(params, batch, cfg: ModelConfig, *, mesh=None, rules=None,
            runtime=None, moe_aux_coef: float = 0.01):
    from .sharding import DEFAULT_RULES
    from .layers import chunked_cross_entropy
    ctx = Ctx(cfg, mesh, rules or DEFAULT_RULES, runtime)
    x = _embed_inputs(params, batch, ctx)
    enc_out = (_run_encoder(params, batch["frames"], ctx)
               if cfg.family == "audio" else None)
    x, _, aux = _run_segments(params, x, ctx, x0=x, enc_out=enc_out)
    labels = batch["labels"]
    if cfg.family == "vlm":   # vision prefix carries no LM loss
        pad = jnp.full(batch["vision"].shape[:2], -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    x = rmsnorm(params["final_norm"], x)
    w = (ctx.cast(params["lm_head"]["w"]) if "lm_head" in params
         else ctx.cast(params["embed"]["table"]).T)
    if cfg.ce_chunk:
        ce = chunked_cross_entropy(x, w, labels, chunk=cfg.ce_chunk)
    else:
        logits = ctx.cons(x @ w, "batch", "seq", "vocab")
        ce = cross_entropy(logits, labels)
    return ce + moe_aux_coef * aux, {"ce": ce, "moe_aux": aux}


# ---------------------------------------------------------------------------
# serving: decode state, prefill, decode_step
# ---------------------------------------------------------------------------

def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      dtype):
    hd = cfg.hd()
    kv_cache = lambda: {"k": jnp.zeros((batch, max_len, cfg.kv_heads, hd),
                                       dtype),
                        "v": jnp.zeros((batch, max_len, cfg.kv_heads, hd),
                                       dtype),
                        "len": jnp.zeros((), jnp.int32)}
    if kind in ("attn", "moe", "dec_cross", "enc"):
        return (init_mla_cache(cfg, batch, max_len, dtype)
                if (cfg.use_mla and kind in ("attn", "moe")) else kv_cache())
    if kind == "mamba2":
        return init_mamba2_state(cfg, batch, dtype)
    if kind == "rwkv6":
        return init_rwkv6_state(cfg, batch, dtype)
    if kind == "zamba_super":
        inner = init_mamba2_state(cfg, batch, dtype)
        stacked = jax.tree.map(
            lambda t: jnp.broadcast_to(t, (cfg.shared_attn_every,) + t.shape),
            inner)
        return {"mamba": stacked, "attn": kv_cache()}
    raise ValueError(kind)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> list:
    caches = []
    for kind, repeat in cfg.segments():
        one = _init_block_cache(cfg, kind, batch, max_len, dtype)
        caches.append(jax.tree.map(
            lambda t: jnp.broadcast_to(t, (repeat,) + t.shape).copy(), one))
    return caches


def prefill(params, batch, caches, cfg: ModelConfig, *, mesh=None,
            rules=None, runtime=None):
    """Run the prompt through the model filling caches.
    Returns (last-token logits, new caches)."""
    from .sharding import DEFAULT_RULES
    ctx = Ctx(cfg, mesh, rules or DEFAULT_RULES, runtime)
    x = _embed_inputs(params, batch, ctx)
    enc_out = (_run_encoder(params, batch["frames"], ctx)
               if cfg.family == "audio" else None)
    x, new_caches, _ = _run_segments(params, x, ctx, caches=caches, x0=x,
                                     enc_out=enc_out)
    return _logits(params, x[:, -1:], ctx), new_caches


def decode_step(params, token, caches, cfg: ModelConfig, *, mesh=None,
                rules=None, runtime=None, enc_out=None, x0=None, pos=0):
    """One-token step. token: (B, 1) int32 → (logits (B,1,V), new caches).
    ``pos`` — absolute position (whisper sinusoidal embedding offset)."""
    from .sharding import DEFAULT_RULES
    ctx = Ctx(cfg, mesh, rules or DEFAULT_RULES, runtime)
    x = embed(params["embed"], token, ctx)
    if cfg.family == "audio" and enc_out is None:
        raise ValueError("whisper decode needs enc_out from prefill")
    if cfg.family == "audio":
        x = x + _sinusoid(1, x.shape[2], offset=pos).astype(x.dtype)[None]
    x0 = x if x0 is None else x0
    x, new_caches, _ = _run_segments(params, x, ctx, caches=caches, x0=x0,
                                     enc_out=enc_out)
    return _logits(params, x, ctx), new_caches
