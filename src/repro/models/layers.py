"""Shared neural-net layers: norms, RoPE, embeddings, linears (optionally
routed through the ADSALA-tuned Pallas GEMM), SwiGLU/GELU MLPs, and
memory-bounded blockwise (flash-style) attention with GQA/MQA support.

All modules are pure functions over param dicts.  ``Ctx`` threads the model
config, mesh and logical sharding rules through the stack.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from .sharding import ShardingRules, DEFAULT_RULES, constrain

__all__ = ["Ctx", "init_linear", "linear", "routed_matmul", "init_norm",
           "rmsnorm", "init_embedding", "embed", "rope", "init_attention",
           "attention", "init_mlp", "mlp", "cross_entropy",
           "flash_attention"]


@dataclasses.dataclass
class Ctx:
    cfg: ModelConfig
    mesh: object = None               # jax.sharding.Mesh | None
    rules: ShardingRules = DEFAULT_RULES
    runtime: object = None            # AdsalaRuntime | None (None → global)

    def cast(self, x):
        return x.astype(self.cfg.compute_dtype)

    def cons(self, x, *names):
        if self.mesh is None:
            return x
        return constrain(x, self.rules, self.mesh, *names)

    def routes_gemm(self, x) -> bool:
        """Whether a dense matmul on ``x`` goes through the tuned runtime:
        opt-in via config, single-host only (the sharded path keeps jnp
        matmuls so GSPMD can partition them)."""
        return (self.cfg.use_pallas_gemm and self.mesh is None
                and x.ndim >= 2)


def routed_matmul(x, w, ctx: Ctx):
    """``x @ w`` dispatched through :func:`repro.kernels.ops.run_op` — knob
    selection, decision cache, and backend keying all come from the ADSALA
    runtime carried on ``ctx`` (``None`` → the process-global runtime).

    Activations keep their leading batch axis: ``(B, S, d) @ (d, n)``
    executes as one stacked call whose 2-D weight broadcasts across the
    stack (no host reshape in the hot decode loop).  The interpret/compiled
    kernel mode comes from ``cfg.gemm_interpret`` (``None`` → the backend
    auto-detects the host).  Falls back to plain ``x @ w`` when the config
    does not route.
    """
    if not ctx.routes_gemm(x) or w.ndim != 2:
        return x @ w
    from repro.kernels import ops as kops
    kw = {}
    if ctx.cfg.gemm_interpret is not None:
        kw["interpret"] = ctx.cfg.gemm_interpret
    lead = x.shape[:-2]
    x3 = x.reshape(-1, *x.shape[-2:]) if len(lead) > 1 else x
    y = kops.run_op("gemm", (x3, w), backend=ctx.cfg.gemm_backend,
                    runtime=ctx.runtime, stacked=x3.ndim == 3, **kw)
    return y.reshape(*lead, *y.shape[-2:]) if len(lead) > 1 else y


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# linear / norm / embedding
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype="float32", scale: float | None = None) -> dict:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": _init(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: dict, x, ctx: Ctx, *, out_logical: str | None = None):
    w = ctx.cast(p["w"])
    y = routed_matmul(x, w, ctx)
    if "b" in p:
        y = y + ctx.cast(p["b"])
    if out_logical is not None:
        # 'embed' outputs are inter-block activations → carry the SP seq
        # sharding; head/mlp-parallel outputs leave seq unsharded.
        seq_name = "seq" if out_logical == "embed" else None
        y = ctx.cons(y, "batch", seq_name, out_logical)
    return y


def init_norm(d: int, dtype="float32") -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(
        jnp.float32)).astype(dt)


def init_embedding(key, vocab: int, d: int, dtype="float32") -> dict:
    return {"table": _init(key, (vocab, d), 0.02, dtype)}


def embed(p: dict, ids, ctx: Ctx):
    x = ctx.cast(p["table"])[ids]
    return ctx.cons(x, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, *, theta: float = 1e4):
    """x: (..., S, H, D) rotated by ``positions`` (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (np.arange(0, half) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise (flash-style) attention — memory-bounded for 32k+ contexts
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool, q_offset: int | jax.Array = 0,
                    q_chunk: int = 1024, k_chunk: int = 1024,
                    kv_valid_len=None, causal_skip: bool = False,
                    unroll: int = 1):
    """Online-softmax attention over kv chunks.

    q: (B, S, H, D); k, v: (B, T, KH, D) with H = G·KH (GQA groups).
    ``q_offset`` — absolute position of q[0] (decode: cache length).
    ``kv_valid_len`` — optional (B,) number of valid cache entries.
    ``causal_skip`` — unrolled-q variant that skips fully-masked kv blocks
    (≈½ the FLOPs at long context; §Perf hillclimb knob).

    Never materialises more than (B, Cq, H, Ck) scores.
    """
    B, S, H, D = q.shape
    T, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]                     # may differ from D (MLA)
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    q_chunk = min(q_chunk, S)
    k_chunk = min(k_chunk, T)
    nq = -(-S // q_chunk)
    nk = -(-T // k_chunk)
    Sp, Tp = nq * q_chunk, nk * k_chunk
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))

    # inputs stay low-precision; f32 only inside the chunk step (accumulators
    # and softmax) — the flash-kernel memory/precision contract.
    qc = q.reshape(B, nq, q_chunk, KH, G, D)
    kc = k.reshape(B, nk, k_chunk, KH, D)
    vc = v.reshape(B, nk, k_chunk, KH, Dv)
    NEG = jnp.float32(-1e30)

    def kv_step(carry, j, qi_block, i):
        m, l, acc = carry
        kj = jax.lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
        # scores: (B, Cq, G, KH, Ck), f32 accumulation from bf16 operands
        s = jnp.einsum("bqhgd,bkhd->bqghk", qi_block, kj,
                       preferred_element_type=jnp.float32) * scale
        q_pos = q_offset + i * q_chunk + jnp.arange(q_chunk)
        k_pos = j * k_chunk + jnp.arange(k_chunk)
        mask = jnp.ones((q_chunk, k_chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        mask &= (k_pos < T)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, NEG)
        if kv_valid_len is not None:
            ok = k_pos[None, :] < kv_valid_len[:, None]        # (B, Ck)
            s = jnp.where(ok[:, None, None, None, :], s, NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(s <= NEG * 0.5, 0.0, p)   # fully-masked-block guard
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqghk,bkhd->bqghd", p.astype(v.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    # checkpoint the kv step: its O(Cq·Ck) score/softmax intermediates are
    # recomputed in the backward pass instead of being saved per kv block
    # (flash-attention memory contract).
    kv_step_ckpt = jax.checkpoint(kv_step)

    def q_block(i):
        qi = jax.lax.dynamic_index_in_dim(qc, i, 1, keepdims=False)
        # qi: (B, Cq, KH, G, D) = b q h g d for the einsum
        init = (jnp.full((B, q_chunk, G, KH), NEG),
                jnp.zeros((B, q_chunk, G, KH)),
                jnp.zeros((B, q_chunk, G, KH, Dv)))
        if causal_skip and causal and isinstance(q_offset, int):
            # static upper bound on reachable kv blocks for this q block
            hi = min(nk, ((q_offset + (i + 1) * q_chunk - 1) // k_chunk) + 1)
            (m, l, acc), _ = jax.lax.scan(
                lambda c, j: kv_step_ckpt(c, j, qi, i), init, jnp.arange(hi),
                unroll=min(unroll, hi))
        else:
            (m, l, acc), _ = jax.lax.scan(
                lambda c, j: kv_step_ckpt(c, j, qi, i), init, jnp.arange(nk),
                unroll=min(unroll, nk))
        out_i = acc / jnp.maximum(l[..., None], 1e-30)
        # cast before stacking across q blocks (halves the stacked buffer)
        return out_i.transpose(0, 1, 3, 2, 4).astype(q.dtype)

    if causal_skip and causal and isinstance(q_offset, int):
        outs = [q_block(i) for i in range(nq)]               # unrolled
        out = jnp.stack(outs, axis=1)
    else:
        _, out = jax.lax.scan(lambda c, i: (c, q_block(i)), None,
                              jnp.arange(nq))
        out = out.transpose(1, 0, 2, 3, 4, 5)
    # (B, nq, Cq, KH, G, Dv) → heads h = kh·G + g, matching the q projection
    out = out.reshape(B, Sp, KH * G, Dv)[:, :S]
    return out.astype(q.dtype)


def _dense_decode_attention(q, k, v, start):
    """Single-shot attention for decode (S==1): one einsum over the whole
    cache — partitions cleanly under GSPMD whether the cache is sharded on
    kv_heads or on sequence (SP fallback), unlike a scanned chunk loop.
    q: (B,S,H,D); k,v: (B,T,KH,Dk/Dv); valid positions are < start+S."""
    B, S, H, D = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    # operands stay low-precision (no whole-cache f32 copies); f32 accum
    q_ = q.reshape(B, S, KH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqghk", q_, k,
                   preferred_element_type=jnp.float32) * scale
    k_pos = jnp.arange(T)[None, None, None, None, :]
    q_pos = (start + jnp.arange(S))[None, :, None, None, None]
    s = jnp.where(k_pos <= q_pos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqghk,bkhd->bqghd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.transpose(0, 1, 3, 2, 4).reshape(B, S, KH * G, -1)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, *, d_model: int | None = None,
                   cross: bool = False) -> dict:
    d = d_model or cfg.d_model
    hd = cfg.hd()
    ks = jax.random.split(key, 5)
    p = {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias,
                          dtype=cfg.param_dtype),
        "wk": init_linear(ks[1], d, cfg.kv_heads * hd, bias=cfg.qkv_bias,
                          dtype=cfg.param_dtype),
        "wv": init_linear(ks[2], d, cfg.kv_heads * hd, bias=cfg.qkv_bias,
                          dtype=cfg.param_dtype),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d,
                          dtype=cfg.param_dtype,
                          scale=1.0 / math.sqrt(cfg.n_heads * hd)),
    }
    return p


def attention(p: dict, x, ctx: Ctx, *, kv_x=None, causal: bool = True,
              positions=None, cache: dict | None = None,
              use_rope: bool = True):
    """GQA attention. ``cache`` (decode): {k, v, (B,T,KH,D); len (B,)} —
    functional update returned alongside the output."""
    cfg = ctx.cfg
    B, S, _ = x.shape
    hd = cfg.hd()
    kv_in = x if kv_x is None else kv_x
    q = linear(p["wq"], x, ctx).reshape(B, S, cfg.n_heads, hd)
    k = linear(p["wk"], kv_in, ctx).reshape(B, kv_in.shape[1], cfg.kv_heads, hd)
    v = linear(p["wv"], kv_in, ctx).reshape(B, kv_in.shape[1], cfg.kv_heads, hd)
    # head-parallel region: seq deliberately unsharded here (under SP rules
    # this boundary is the all-gather / reduce-scatter pair).  batch_attn
    # may span ('data','model') when heads don't divide the TP axis.
    q = ctx.cons(q, "batch_attn", None, "heads", None)
    k = ctx.cons(k, "batch_attn", "kv_seq", "kv_heads", None)
    v = ctx.cons(v, "batch_attn", "kv_seq", "kv_heads", None)

    new_cache = None
    if cache is not None:
        start = cache["len"]                          # scalar int32
        if positions is None:
            positions = start + jnp.arange(S)[None, :]
        if use_rope:
            q = rope(q, positions, theta=cfg.rope_theta)
            k = rope(k, positions, theta=cfg.rope_theta)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                                 k.astype(cache["k"].dtype),
                                                 start, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                                 v.astype(cache["v"].dtype),
                                                 start, axis=1)
        new_cache = {"k": ck, "v": cv, "len": start + S}
        if S == 1:
            out = _dense_decode_attention(q, ck.astype(q.dtype),
                                          cv.astype(q.dtype), start)
        else:
            valid = jnp.full((B,), start + S)
            out = flash_attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                                  causal=causal, q_offset=start,
                                  q_chunk=min(cfg.attn_q_chunk, S),
                                  k_chunk=cfg.attn_k_chunk,
                                  kv_valid_len=valid,
                                  unroll=cfg.unroll_attn)
    else:
        if positions is None:
            positions = jnp.arange(S)[None, :].repeat(B, 0)
        if use_rope:
            q = rope(q, positions, theta=cfg.rope_theta)
            k = rope(k, positions, theta=cfg.rope_theta)
        out = flash_attention(q, k, v, causal=causal,
                              q_chunk=cfg.attn_q_chunk,
                              k_chunk=cfg.attn_k_chunk,
                              causal_skip=cfg.causal_skip,
                              unroll=cfg.unroll_attn)
    out = ctx.cons(out, "batch_attn", None, "heads", None)
    out = linear(p["wo"], out.reshape(B, S, cfg.n_heads * hd), ctx,
                 out_logical="embed")
    return (out, new_cache) if cache is not None else (out, None)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, *, mlp_type: str = "swiglu",
             dtype="float32") -> dict:
    ks = jax.random.split(key, 3)
    if mlp_type == "swiglu":
        return {"wg": init_linear(ks[0], d, d_ff, dtype=dtype),
                "wu": init_linear(ks[1], d, d_ff, dtype=dtype),
                "wd": init_linear(ks[2], d_ff, d, dtype=dtype)}
    return {"w1": init_linear(ks[0], d, d_ff, dtype=dtype),
            "w2": init_linear(ks[1], d_ff, d, dtype=dtype)}


def mlp(p: dict, x, ctx: Ctx):
    if "wg" in p:
        h = jax.nn.silu(linear(p["wg"], x, ctx, out_logical="mlp")) * \
            linear(p["wu"], x, ctx, out_logical="mlp")
        return linear(p["wd"], h, ctx, out_logical="embed")
    h = jax.nn.gelu(linear(p["w1"], x, ctx, out_logical="mlp"))
    return linear(p["w2"], h, ctx, out_logical="embed")


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, *, z_loss: float = 0.0):
    """Mean next-token CE in f32; labels < 0 are masked out."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * lse ** 2
    mask = (labels >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def chunked_cross_entropy(x, w, labels, *, chunk: int = 2048,
                          z_loss: float = 0.0, unroll: bool = False):
    """CE fused with the LM head, scanned over seq chunks so the (B, S, V)
    f32 logits tensor is never materialised — each chunk's logits are
    recomputed in the backward pass (jax.checkpoint).  Dominant memory term
    of the train step at 128k vocab; §Perf."""
    B, S, D = x.shape
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xs = (x.reshape(B, nc, chunk, D).swapaxes(0, 1),
          labels.reshape(B, nc, chunk).swapaxes(0, 1))

    @jax.checkpoint
    def body(carry, inp):
        tot, cnt = carry
        xc, lc = inp
        logits = (xc @ w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(lc, 0)[..., None],
                                 axis=-1)[..., 0]
        nll = lse - ll
        if z_loss:
            nll = nll + z_loss * lse ** 2
        mask = (lc >= 0).astype(jnp.float32)
        return (tot + (nll * mask).sum(), cnt + mask.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), xs,
                                 unroll=unroll)
    return tot / jnp.maximum(cnt, 1.0)
