"""Mamba2 (SSD) mixer — chunked state-space dual form.

Sequence mixing is the scalar-decay SSD recurrence

    h_t = exp(dt_t·A) · h_{t-1} + dt_t · B_t ⊗ x_t        h: (H, P, N)
    y_t = C_t · h_t + D ⊙ x_t

computed in chunks of ``cfg.ssm_chunk``: within a chunk the recurrence is a
masked (L × L) decay-weighted attention-like matmul (MXU work); across
chunks a ``lax.scan`` carries the (B, H, P, N) state.  All per-chunk
tensors live inside the scan body, so peak memory is O(B·L·L·H) per chunk,
not O(S²).  Decode is the recurrence applied to a single token — O(1) state,
which is why the hybrid/SSM archs own the ``long_500k`` cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import Ctx, init_linear, init_norm, linear, rmsnorm

__all__ = ["init_mamba2", "mamba2_mixer", "init_mamba2_state"]


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    conv_dim = d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def init_mamba2(key, cfg: ModelConfig) -> dict:
    d_inner, n_heads, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + n_heads
    return {
        "in_proj": init_linear(ks[0], cfg.d_model, proj_out,
                               dtype=cfg.param_dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_dim))
                   * 0.1).astype(cfg.param_dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.param_dtype),
        "A_log": jnp.zeros((n_heads,), cfg.param_dtype),
        "D": jnp.ones((n_heads,), cfg.param_dtype),
        "dt_bias": jnp.zeros((n_heads,), cfg.param_dtype),
        "norm": init_norm(d_inner, cfg.param_dtype),
        "out_proj": init_linear(ks[2], d_inner, cfg.d_model,
                                dtype=cfg.param_dtype),
    }


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype):
    d_inner, n_heads, conv_dim = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, n_heads, cfg.ssm_headdim, cfg.ssm_state),
                         jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
    }


def _causal_conv(xBC, w, b):
    """Depthwise causal conv (width W) via shifted adds."""
    W = w.shape[0]
    out = xBC * w[W - 1]
    for i in range(1, W):
        shifted = jnp.pad(xBC, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[W - 1 - i]
    return out + b


def _split(cfg, zxbcdt):
    d_inner, n_heads, _ = _dims(cfg)
    gn = cfg.ssm_groups * cfg.ssm_state
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner: 2 * d_inner + 2 * gn]
    dt = zxbcdt[..., 2 * d_inner + 2 * gn:]
    return z, xBC, dt


def _ssd_chunked(x, dt, A, B_in, C_in, cfg, h0, ctx=None, *, unroll=False):
    """x:(B,S,H,P) dt:(B,S,H) A:(H,) B_in/C_in:(B,S,G,N) → y, h_final."""
    Bsz, S, H, P = x.shape
    N, G, L = cfg.ssm_state, cfg.ssm_groups, min(cfg.ssm_chunk, S)
    nc = -(-S // L)
    pad = nc * L - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_in = jnp.pad(B_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_in = jnp.pad(C_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
    rep = H // G
    to_heads = lambda t: jnp.repeat(t, rep, axis=2)           # (B,S,H,N)
    Bh, Ch = to_heads(B_in), to_heads(C_in)
    if ctx is not None:   # head-parallel layout for the SSD region
        x = ctx.cons(x, "batch", None, "heads", None)
        Bh = ctx.cons(Bh, "batch", None, "heads", None)
        Ch = ctx.cons(Ch, "batch", None, "heads", None)

    # chunked xs for the scan: leading axis nc
    csplit = lambda t: t.reshape(Bsz, nc, L, *t.shape[2:]).swapaxes(0, 1)
    xs = (csplit(x), csplit(dt), csplit(Bh), csplit(Ch))

    def chunk_step(h, inp):
        xc, dtc, Bc, Cc = inp                                  # (B,L,H,*)
        lA = (dtc * A).astype(jnp.float32)                     # ≤ 0
        cum = jnp.cumsum(lA, axis=1)                           # (B,L,H)
        cum_cl = jnp.maximum(cum, -30.0)
        # intra-chunk: scores[t,s] = (C_t·B_s)·exp(cum_t−cum_s)·dt_s, s ≤ t
        cb = jnp.einsum("blhn,bshn->blsh", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))
        decay = jnp.exp(cum[:, :, None, :] - cum_cl[:, None, :, :])
        mask = jnp.tril(jnp.ones((L, L), bool))
        scores = jnp.where(mask[None, :, :, None], cb * decay, 0.0)
        scores = scores * dtc.astype(jnp.float32)[:, None, :, :]
        y_intra = jnp.einsum("blsh,bshp->blhp", scores,
                             xc.astype(jnp.float32))
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("blhn,bhpn->blhp",
                             Cc.astype(jnp.float32) *
                             jnp.exp(cum)[..., None], h)
        # state update
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum_cl)        # (B,L,H)
        dBx = jnp.einsum("blh,blhn,blhp->bhpn",
                         (dtc.astype(jnp.float32) * decay_to_end),
                         Bc.astype(jnp.float32), xc.astype(jnp.float32))
        h_new = h * jnp.exp(cum[:, -1, :])[:, :, None, None] + dBx
        if ctx is not None:   # keep the carried state head-sharded: the
            # backward scan stacks one carry per chunk (B,H,P,N)
            h_new = ctx.cons(h_new, "batch", "heads", None, None)
        return h_new, (y_intra + y_inter)

    # checkpoint: intra-chunk scores are recomputed in backward instead of
    # being stacked across chunks (and across scanned layers) — same memory
    # contract as the flash kv step
    h_final, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0, xs,
                               unroll=min(unroll, nc))
    y = ys.swapaxes(0, 1).reshape(Bsz, nc * L, H, P)[:, :S]
    return y, h_final


def mamba2_mixer(p: dict, x, ctx: Ctx, *, state: dict | None = None):
    """x: (B,S,D) → (y (B,S,D), new_state|None)."""
    cfg = ctx.cfg
    Bsz, S, _ = x.shape
    d_inner, n_heads, conv_dim = _dims(cfg)
    N, G, P = cfg.ssm_state, cfg.ssm_groups, cfg.ssm_headdim

    zxbcdt = linear(p["in_proj"], x, ctx, out_logical="ssm_inner")
    z, xBC, dt = _split(cfg, zxbcdt)

    new_state = None
    if state is None:
        xBC = _causal_conv(xBC, ctx.cast(p["conv_w"]), ctx.cast(p["conv_b"]))
    else:
        hist = jnp.concatenate([state["conv"].astype(xBC.dtype), xBC], axis=1)
        xBC_full = _causal_conv(hist, ctx.cast(p["conv_w"]),
                                ctx.cast(p["conv_b"]))
        xBC = xBC_full[:, -S:]
        new_conv = hist[:, -(cfg.conv_width - 1):]
    xBC = jax.nn.silu(xBC)

    x_ssm = xBC[..., :d_inner].reshape(Bsz, S, n_heads, P)
    B_in = xBC[..., d_inner: d_inner + G * N].reshape(Bsz, S, G, N)
    C_in = xBC[..., d_inner + G * N:].reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))   # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (H,) < 0

    h0 = (state["ssm"] if state is not None else
          jnp.zeros((Bsz, n_heads, P, N), jnp.float32))
    if state is not None and S == 1:
        # pure decode recurrence (no chunk machinery)
        dA = jnp.exp(dt[:, 0] * A)                           # (B,H)
        Bh = jnp.repeat(B_in[:, 0], n_heads // G, axis=1)    # (B,H,N)
        Ch = jnp.repeat(C_in[:, 0], n_heads // G, axis=1)
        dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt[:, 0],
                         Bh.astype(jnp.float32),
                         x_ssm[:, 0].astype(jnp.float32))
        h = h0 * dA[:, :, None, None] + dBx
        y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), h)
        y = y[:, None]                                       # (B,1,H,P)
        new_state = {"ssm": h, "conv": new_conv}
    else:
        y, h = _ssd_chunked(x_ssm, dt, A, B_in, C_in, cfg, h0, ctx,
                            unroll=cfg.unroll_ssm)
        if state is not None:
            new_state = {"ssm": h, "conv": new_conv}

    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * \
        x_ssm.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    y = linear(p["out_proj"], y, ctx, out_logical="embed")
    return y, new_state
