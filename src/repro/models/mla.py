"""Multi-head Latent Attention (DeepSeek-V2) — low-rank-compressed KV cache.

Two execution forms, as in production DeepSeek serving:

  * prefill/train — the latent c_kv is expanded through W_kb/W_vb to full
    per-head K/V and runs through blockwise flash attention (MXU-dense).
  * decode — the *absorbed* form: q_nope is folded through W_kb so scores
    are taken directly against the (T, kv_lora) latent cache, and the
    attention context is expanded through W_vb only once per step.  The KV
    cache holds kv_lora + qk_rope floats/token — 576 vs. 2·H·192 = 6144 for
    an equivalent GQA cache (the paper-V2 compression claim).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import Ctx, init_linear, init_norm, linear, rmsnorm, rope, \
    flash_attention

__all__ = ["init_mla", "mla_attention", "init_mla_cache"]


def init_mla(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h, nope, rp, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, \
        cfg.v_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, h * (nope + rp), dtype=cfg.param_dtype),
        "wkv_a": init_linear(ks[1], d, cfg.kv_lora + rp,
                             dtype=cfg.param_dtype),
        "kv_norm": init_norm(cfg.kv_lora, cfg.param_dtype),
        "wkv_b": init_linear(ks[2], cfg.kv_lora, h * (nope + vd),
                             dtype=cfg.param_dtype),
        "wo": init_linear(ks[3], h * vd, d, dtype=cfg.param_dtype),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def _project_q(p, x, cfg, ctx):
    B, S, _ = x.shape
    h, nope, rp = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = linear(p["wq"], x, ctx).reshape(B, S, h, nope + rp)
    # head-parallel region — seq unsharded here (SP boundary)
    q = ctx.cons(q, "batch", None, "heads", None)
    return q[..., :nope], q[..., nope:]


def mla_attention(p: dict, x, ctx: Ctx, *, cache: dict | None = None):
    """Returns (out, new_cache|None)."""
    cfg = ctx.cfg
    B, S, _ = x.shape
    h, nope, rp, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, \
        cfg.v_head_dim
    scale = 1.0 / math.sqrt(nope + rp)

    kv_a = linear(p["wkv_a"], x, ctx)
    c_kv = rmsnorm(p["kv_norm"], kv_a[..., :cfg.kv_lora])
    k_rope_new = kv_a[..., cfg.kv_lora:]                     # (B,S,rp) 1 head
    q_nope, q_rope = _project_q(p, x, cfg, ctx)

    if cache is None:
        positions = jnp.arange(S)[None, :]
        q_rope = rope(q_rope, positions, theta=cfg.rope_theta)
        k_rope = rope(k_rope_new[:, :, None, :], positions,
                      theta=cfg.rope_theta)[:, :, 0]
        # expand latent → per-head K/V, dense attention (prefill/train form)
        kv = linear(p["wkv_b"], c_kv, ctx).reshape(B, S, h, nope + vd)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, h, rp))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = flash_attention(q, k, v, causal=True,
                              q_chunk=cfg.attn_q_chunk,
                              k_chunk=cfg.attn_k_chunk,
                              causal_skip=cfg.causal_skip,
                              unroll=cfg.unroll_attn)
        out = linear(p["wo"], out.reshape(B, S, h * vd), ctx,
                     out_logical="embed")
        return out, None

    # ---- cached path: update the latent cache, then attend ------------------
    start = cache["len"]
    positions = start + jnp.arange(S)[None, :]
    q_rope = rope(q_rope, positions, theta=cfg.rope_theta)
    k_rope_new = rope(k_rope_new[:, :, None, :], positions,
                      theta=cfg.rope_theta)[:, :, 0]
    c = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), start, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), start,
        axis=1)
    new_cache = {"c_kv": c, "k_rope": kr, "len": start + S}

    if S > 1:
        # prefill: expand latent → per-head K/V, blockwise flash (the
        # absorbed form would materialise S×T scores — 8.6 GB/dev at 32k)
        T = c.shape[1]
        kv = linear(p["wkv_b"], ctx.cast(c), ctx).reshape(B, T, h, nope + vd)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(ctx.cast(kr)[:, :, None, :],
                                      (B, T, h, rp))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = flash_attention(q, k, v, causal=True, q_offset=start,
                              q_chunk=cfg.attn_q_chunk,
                              k_chunk=cfg.attn_k_chunk,
                              kv_valid_len=jnp.full((B,), start + S),
                              unroll=cfg.unroll_attn)
        out = linear(p["wo"], out.reshape(B, S, h * vd), ctx,
                     out_logical="embed")
        return out, new_cache

    # ---- decode: absorbed form over the latent cache -----------------------
    w_b = ctx.cast(p["wkv_b"]["w"]).reshape(cfg.kv_lora, h, nope + vd)
    w_kb, w_vb = w_b[..., :nope], w_b[..., nope:]
    # absorb: q_c[b,s,h,l] = Σ_n q_nope·W_kb[l,h,n]
    q_c = jnp.einsum("bshn,lhn->bshl", q_nope, w_kb)
    scores = (jnp.einsum("bshl,btl->bsht", q_c, ctx.cast(c)) +
              jnp.einsum("bshr,btr->bsht", q_rope, ctx.cast(kr))) * scale
    T = c.shape[1]
    k_pos = jnp.arange(T)[None, None, None, :]
    valid = k_pos < (start + S)
    q_pos = (positions[:, :, None, None])
    causal_ok = k_pos <= q_pos
    scores = jnp.where(valid & causal_ok, scores.astype(jnp.float32), -1e30)
    attn = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_c = jnp.einsum("bsht,btl->bshl", attn, ctx.cast(c))
    out = jnp.einsum("bshl,lhv->bshv", ctx_c, w_vb)
    out = linear(p["wo"], out.reshape(B, S, h * vd), ctx, out_logical="embed")
    return out, new_cache
