"""Logical-axis sharding rules (MaxText-style) for the (pod, data, model)
production mesh.

Tensors are annotated with *logical* axis names; a :class:`ShardingRules`
table maps each logical name to zero or more mesh axes.  Changing the table
re-shards the whole model — this is the knob the beyond-paper sharding
autotuner (DESIGN.md §7.1) searches over, and how single-pod vs multi-pod
meshes reuse one model definition (``batch`` → ('data',) or
('pod', 'data')).

A logical dim is only sharded if its size divides the product of the mapped
mesh axes — otherwise it silently falls back to replication (e.g. MQA's
kv_heads=1 across a 16-way model axis).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "DEFAULT_RULES", "MULTIPOD_RULES", "logical_spec",
           "constrain", "mesh_axis_size"]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name → tuple of mesh axis names (() = replicate)."""
    table: Mapping[str, tuple[str, ...]]

    def axes_for(self, name: str | None) -> tuple[str, ...]:
        if name is None:
            return ()
        return tuple(self.table.get(name, ()))

    def replace(self, **updates: tuple[str, ...]) -> "ShardingRules":
        t = dict(self.table)
        for k, v in updates.items():
            t[k] = tuple(v)
        return ShardingRules(t)


_BASE_TABLE = {
    # activations
    "batch": ("data",),
    "batch_attn": ("data",),     # attention-region batch (may add 'model'
                                 # when heads don't divide the TP axis)
    "seq": (),                   # sharded for long-context cells (SP)
    "kv_seq": (),
    "embed": (),                 # d_model on activations: replicated
    "heads": ("model",),
    "kv_heads": ("model",),
    # params — TP axis per Megatron; FSDP axis shards the complement
    "vocab": ("model",),
    "embed_fsdp": ("data",),     # FSDP dim of weight matrices
    "mlp": ("model",),           # d_ff / column-parallel out dim
    "qkv": ("model",),
    "o_in": ("model",),          # row-parallel in dim
    "experts": ("model",),       # EP
    "expert_cap": (),            # capacity/slot parallelism fallback
    "expert_mlp": (),            # within-expert width (EP precludes TP here)
    "ssm_inner": ("model",),
    "lora": (),
    "conv": (),
    "norm": (),
    "state": (),
}

DEFAULT_RULES = ShardingRules(dict(_BASE_TABLE))
MULTIPOD_RULES = DEFAULT_RULES.replace(batch=("pod", "data"),
                                       embed_fsdp=("data",))


def mesh_axis_size(mesh: Mesh, axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def logical_spec(rules: ShardingRules, mesh: Mesh,
                 names: Sequence[str | None],
                 dims: Sequence[int] | None = None) -> P:
    """PartitionSpec from logical names; non-divisible dims replicate."""
    parts = []
    for i, name in enumerate(names):
        axes = rules.axes_for(name)
        if not axes:
            parts.append(None)
            continue
        if dims is not None:
            # progressively drop trailing axes until the dim divides —
            # e.g. batch=('data','model') degrades to ('data',) for small B
            while axes:
                size = mesh_axis_size(mesh, axes)
                if size > 1 and dims[i] % size == 0:
                    break
                axes = axes[:-1]
            if not axes:
                parts.append(None)
                continue
        parts.append(axes if len(axes) > 1 else axes[0])
    # trailing Nones can be dropped but keep explicit for readability
    return P(*parts)


def constrain(x, rules: ShardingRules, mesh: Mesh, *names: str | None):
    """with_sharding_constraint by logical names (no-op off-mesh)."""
    if mesh is None or mesh.empty:
        return x
    spec = logical_spec(rules, mesh, names, dims=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
