"""Architecture zoo: 10 assigned architectures assembled from shared layers
(configs select via --arch).  Public API: init_params / forward / loss_fn /
init_decode_state / prefill / decode_step."""

from .transformer import (decode_step, forward, init_decode_state,
                          init_params, loss_fn, param_count, prefill)
from .sharding import (DEFAULT_RULES, MULTIPOD_RULES, ShardingRules,
                       constrain, logical_spec)
from .layers import Ctx, cross_entropy, flash_attention

__all__ = ["decode_step", "forward", "init_decode_state", "init_params",
           "loss_fn", "param_count", "prefill", "DEFAULT_RULES",
           "MULTIPOD_RULES", "ShardingRules", "constrain", "logical_spec",
           "Ctx", "cross_entropy", "flash_attention"]
