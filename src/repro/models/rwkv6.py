"""RWKV6 "Finch" — attention-free token mixing with data-dependent decay.

Time-mix recurrence (per head, K = V = head_dim):

    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t          S: (K, V)
    y_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)

with per-channel, per-token decay w_t = exp(−exp(w0 + lora(x_t))) ∈ (0,1)
(the Finch novelty) and data-dependent token-shift lerps.  Computed in
chunks: within a chunk the recurrence becomes a decay-weighted (L × L)
score matmul via the exp-difference factorisation

    exp(cum_{t−1} − cum_s) = (r_t ⊙ e^{cum_{t−1}}) · (k_s ⊙ e^{−cum_s})

with cum clamped at −30 for f32 safety (contributions below e^{−30} are
dead); across chunks a ``lax.scan`` carries (B, H, K, V) state.  Decode is
the one-token recurrence — O(1) state → owns ``long_500k``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import Ctx, init_linear, linear

__all__ = ["init_rwkv6", "rwkv6_block", "init_rwkv6_state"]

_MIX = ("r", "k", "v", "g", "w")


def _heads(cfg: ModelConfig):
    K = cfg.rwkv_head_dim
    return cfg.d_model // K, K


def init_rwkv6(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H, K = _heads(cfg)
    L = cfg.rwkv_lora
    ks = jax.random.split(key, 16)
    from .layers import init_norm
    p = {
        # pre-norms (RWKV blocks own their residual structure)
        "ln1": init_norm(d, cfg.param_dtype),
        "ln2": init_norm(d, cfg.param_dtype),
        # time-mix
        "mu_x": jnp.full((d,), 0.5, cfg.param_dtype),
        "mu": jnp.full((5, d), 0.5, cfg.param_dtype),
        "lora_A": (jax.random.normal(ks[0], (d, 5 * L)) * 0.01
                   ).astype(cfg.param_dtype),
        "lora_B": (jax.random.normal(ks[1], (5, L, d)) * 0.01
                   ).astype(cfg.param_dtype),
        "w0": jnp.full((d,), -1.0, cfg.param_dtype),
        "w_lora_A": (jax.random.normal(ks[2], (d, L)) * 0.01
                     ).astype(cfg.param_dtype),
        "w_lora_B": (jax.random.normal(ks[3], (L, d)) * 0.01
                     ).astype(cfg.param_dtype),
        "wr": init_linear(ks[4], d, d, dtype=cfg.param_dtype),
        "wk": init_linear(ks[5], d, d, dtype=cfg.param_dtype),
        "wv": init_linear(ks[6], d, d, dtype=cfg.param_dtype),
        "wg": init_linear(ks[7], d, d, dtype=cfg.param_dtype),
        "u": (jax.random.normal(ks[8], (H, K)) * 0.1).astype(cfg.param_dtype),
        "ln_scale": jnp.ones((H, K), cfg.param_dtype),
        "ln_bias": jnp.zeros((H, K), cfg.param_dtype),
        "wo": init_linear(ks[9], d, d, dtype=cfg.param_dtype),
        # channel-mix
        "cm_mu_k": jnp.full((d,), 0.5, cfg.param_dtype),
        "cm_mu_r": jnp.full((d,), 0.5, cfg.param_dtype),
        "cm_wk": init_linear(ks[10], d, cfg.d_ff, dtype=cfg.param_dtype),
        "cm_wv": init_linear(ks[11], cfg.d_ff, d, dtype=cfg.param_dtype),
        "cm_wr": init_linear(ks[12], d, d, dtype=cfg.param_dtype),
    }
    return p


def init_rwkv6_state(cfg: ModelConfig, batch: int, dtype):
    H, K = _heads(cfg)
    return {
        "tm_prev": jnp.zeros((batch, cfg.d_model), dtype),
        "cm_prev": jnp.zeros((batch, cfg.d_model), dtype),
        "S": jnp.zeros((batch, H, K, K), jnp.float32),
    }


def _shift(x, prev):
    """x_{t-1} along seq; position 0 takes ``prev`` (decode carry)."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev.astype(x.dtype))
    return shifted


def _wkv_chunked(r, k, v, w_log, u, chunk, S0, ctx=None, *, unroll=False):
    """r,k,v: (B,T,H,K); w_log: (B,T,H,K) = log w ≤ 0; u: (H,K).
    Returns (y (B,T,H,K), S_final (B,H,K,K))."""
    B, T, H, K = r.shape
    L = min(chunk, T)
    nc = -(-T // L)
    pad = nc * L - T
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        w_log = jnp.pad(w_log, ((0, 0), (0, pad), (0, 0), (0, 0)))
    csplit = lambda t: t.reshape(B, nc, L, H, K).swapaxes(0, 1)
    xs = (csplit(r.astype(jnp.float32)), csplit(k.astype(jnp.float32)),
          csplit(v.astype(jnp.float32)), csplit(w_log.astype(jnp.float32)))

    mask_strict = jnp.tril(jnp.ones((L, L), bool), -1)

    def chunk_step(S, inp):
        rc, kc, vc, lw = inp                                   # (B,L,H,K)
        cum = jnp.cumsum(lw, axis=1)                           # ≤ 0
        cum_cl = jnp.maximum(cum, -30.0)
        cum_prev = jnp.pad(cum, ((0, 0), (1, 0), (0, 0), (0, 0)))[:, :-1]
        r_sc = rc * jnp.exp(cum_prev)                          # safe: ≤ rc
        k_sc = kc * jnp.exp(-cum_cl)                           # ≤ e^30
        scores = jnp.einsum("blhk,bshk->bhls", r_sc, k_sc)
        scores = jnp.where(mask_strict[None, None], scores, 0.0)
        y = jnp.einsum("bhls,bshk->blhk", scores, vc)
        # current-token bonus
        bonus = jnp.einsum("blhk,blhk->blh", rc, u[None, None] * kc)
        y = y + bonus[..., None] * vc
        # carried state
        y = y + jnp.einsum("blhk,bhkv->blhv", r_sc, S)
        # state update
        k_end = kc * jnp.exp(cum[:, -1:, :, :] - cum_cl)
        S_new = S * jnp.exp(cum[:, -1])[..., None] + \
            jnp.einsum("bshk,bshv->bhkv", k_end, vc)
        if ctx is not None:
            S_new = ctx.cons(S_new, "batch", "heads", None, None)
        return S_new, y

    S_final, ys = jax.lax.scan(jax.checkpoint(chunk_step), S0, xs,
                               unroll=min(unroll, nc))
    y = ys.swapaxes(0, 1).reshape(B, nc * L, H, K)[:, :T]
    return y, S_final


def rwkv6_block(p: dict, x, ctx: Ctx, *, state: dict | None = None):
    """Full RWKV6 layer (time-mix + channel-mix), pre-LN residual style.
    x: (B,S,D) → (y, new_state|None)."""
    cfg = ctx.cfg
    B, S, D = x.shape
    H, K = _heads(cfg)
    from .layers import rmsnorm

    x_res = x
    x = rmsnorm(p["ln1"], x)

    # ---------------- time mix ----------------
    prev = state["tm_prev"] if state is not None else None
    x_prev = _shift(x, prev)
    dx = x_prev - x
    mu_x = ctx.cast(p["mu_x"])
    xx = x + dx * mu_x
    lora = jnp.tanh(xx @ ctx.cast(p["lora_A"])).reshape(B, S, 5, -1)
    dd = jnp.einsum("bsfl,fld->bsfd", lora, ctx.cast(p["lora_B"]))
    mixed = x[:, :, None] + dx[:, :, None] * (ctx.cast(p["mu"])[None, None]
                                              + dd)           # (B,S,5,D)
    xr, xk, xv, xg, xw = [mixed[:, :, i] for i in range(5)]

    r = ctx.cons(linear(p["wr"], xr, ctx).reshape(B, S, H, K),
                 "batch", None, "heads", None)
    k = ctx.cons(linear(p["wk"], xk, ctx).reshape(B, S, H, K),
                 "batch", None, "heads", None)
    v = ctx.cons(linear(p["wv"], xv, ctx).reshape(B, S, H, K),
                 "batch", None, "heads", None)
    g = linear(p["wg"], xg, ctx)
    w_log = -jnp.exp(p["w0"].astype(jnp.float32) +
                     (jnp.tanh(xw @ ctx.cast(p["w_lora_A"])) @
                      ctx.cast(p["w_lora_B"])).astype(jnp.float32))
    w_log = w_log.reshape(B, S, H, K)

    S0 = (state["S"] if state is not None
          else jnp.zeros((B, H, K, K), jnp.float32))
    if state is not None and S == 1:
        # one-token recurrence
        rt, kt, vt = (t[:, 0].astype(jnp.float32) for t in (r, k, v))
        y = jnp.einsum("bhk,bhkv->bhv", rt, S0) + \
            jnp.einsum("bhk,bhk,bhv->bhv", rt,
                       p["u"].astype(jnp.float32)[None] * kt, vt)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        S_new = S0 * jnp.exp(w_log[:, 0])[..., None] + kv
        y = y[:, None]                                        # (B,1,H,K)
    else:
        y, S_new = _wkv_chunked(r, k, v, w_log, p["u"].astype(jnp.float32),
                                cfg.rwkv_chunk, S0, ctx,
                                unroll=cfg.unroll_ssm)

    # per-head group-norm, gate, output proj
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1)[..., None]
    y = (y - mean) * jax.lax.rsqrt(var + 1e-5)
    y = y * p["ln_scale"].astype(jnp.float32)[None, None] + \
        p["ln_bias"].astype(jnp.float32)[None, None]
    y = y.reshape(B, S, D).astype(x.dtype) * jax.nn.silu(g)
    tm_out = linear(p["wo"], y, ctx, out_logical="embed")

    h_res = x_res + tm_out
    h = rmsnorm(p["ln2"], h_res)

    # ---------------- channel mix ----------------
    prev_cm = state["cm_prev"] if state is not None else None
    h_prev = _shift(h, prev_cm)
    dh = h_prev - h
    hk = h + dh * ctx.cast(p["cm_mu_k"])
    hr = h + dh * ctx.cast(p["cm_mu_r"])
    kk = jnp.square(jax.nn.relu(linear(p["cm_wk"], hk, ctx,
                                       out_logical="mlp")))
    cm_out = jax.nn.sigmoid(linear(p["cm_wr"], hr, ctx)) * \
        linear(p["cm_wv"], kk, ctx, out_logical="embed")
    out = h_res + cm_out

    new_state = None
    if state is not None:
        new_state = {"tm_prev": x[:, -1], "cm_prev": h[:, -1], "S": S_new}
    return out, new_state
