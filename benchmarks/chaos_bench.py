#!/usr/bin/env python
"""Chaos harness: seeded fault scenarios against the resilient serving stack.

Drives the failure paths of the serving layer (``repro.serving``) with a
deterministic :class:`FaultPlan` — no real crashes, no wall-clock races —
and checks the resilience *contract* end to end:

  1. **kernel crash storm** — every accelerator rung (pallas, cpu_blocked)
     crashes at launch, for every op: the degradation ladder must land each
     bucket on ``ref``, every future must resolve, and the served results
     must be **bit-identical** to a clean stacked ref run of the same width;
  2. **poisoned knob** — the model's selected knob crashes every attempt
     while the backend's default config runs clean: the crash must be
     pinned on the knob (TTL'd quarantine), the bucket served on the SAME
     backend by the default-knob probe, the quarantined knob never cached
     while the breaker is open, and the model's own pick served again after
     the TTL (half-open recovery);
  3. **worker death** — an injected raise after a bucket is claimed kills
     the worker thread: the supervisor must respawn it and requeue the
     claimed bucket with zero request loss;
  4. **artifact-load failure** — one corrupt artifact and one injected load
     fault must not abort registry hydration: the healthy artifact loads,
     both casualties are recorded, and a later retry recovers;
  5. **retuner refit failure** — a drift-triggered refit raises: the loop
     must count the failure, keep serving the old model, and complete the
     retune on the next step once the fault clears;
  6. **error-budget skip** — a rung that fails its whole rolling window
     must be skipped OUTRIGHT by later buckets (zero attempts, zero
     backoff sleeps) while serving continues on the fallback — and the
     budget-gated ladder must be measurably faster than the same dead-rung
     workload with budgets disabled (``budget_ladder_speedup``);
  7. **half-open probe** — once the probe interval elapses a single
     attempt is let through; on a healed rung it closes the breaker and
     traffic returns to the primary backend;
  8. **admission control** — deadline-infeasible requests and
     above-threshold batch/exploration traffic shed synchronously at
     submit while user traffic is still admitted, and brownout serves
     backlogged buckets with ZERO model evaluations;
  9. **torn snapshot recovery** — a decision-cache snapshot damaged on
     disk recovers by dropping exactly the torn record (deep crash
     recovery lives in ``benchmarks/recovery_bench.py``).

Every metric is structural (pass/fail counts and flags) and the plan is
seeded, so a scenario replays bit-for-bit on any host — except the one
wall-clock ratio ``budget_ladder_speedup``, which divides two runs of the
same seeded workload on the same host and is gated with a wide floor.  The
committed trajectory lives in ``BENCH_chaos.json`` and is gated by
``scripts/bench_diff.py --chaos-fresh``.

    PYTHONPATH=src python benchmarks/chaos_bench.py --smoke
    PYTHONPATH=src python benchmarks/chaos_bench.py --json /tmp/c.json
    PYTHONPATH=src python benchmarks/chaos_bench.py --record pr8
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.backends import get_backend  # noqa: E402
from repro.core import (AdsalaRuntime, ModelRegistry,  # noqa: E402
                        install_subroutine)
from repro.kernels import ops  # noqa: E402
from repro.kernels.ops import run_op  # noqa: E402
from repro.serving import (AdmissionRejectedError, BlasService,  # noqa: E402
                           FaultPlan, FaultSpec, Retuner, RetuneConfig,
                           ServeConfig)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_chaos.json"

OPS = ("gemm", "symm", "syrk", "syr2k", "trmm", "trsm")
DIMS = {"gemm": (16, 16, 16), "symm": (16, 16), "syrk": (16, 16),
        "syr2k": (16, 16), "trmm": (16, 16), "trsm": (16, 16)}


def make(op, dims, seed=0):
    return get_backend("ref").make_operands(op, dims, np.float32, seed=seed)


class _FixedSub:
    """Stub subroutine whose "model" always picks one fixed knob; its
    evaluations are observable (brownout's zero-evals assertions)."""

    def __init__(self, knob, backend, op="gemm", dtype_bytes=4):
        self.backend, self.op, self.dtype_bytes = backend, op, dtype_bytes
        self.knob = knob
        self.artifact_version = 0
        self.evals = 0

    def select(self, dims):
        self.evals += 1
        return self.knob


def _track(futures_seen, futs):
    futures_seen.extend(futs)
    return futs


# ---------------------------------------------------------------------------
# scenarios
# ---------------------------------------------------------------------------

def scenario_crash_storm(n_per_op: int, seed: int, futures_seen: list) -> dict:
    """Every accelerator launch crashes → all buckets land on ref,
    bit-identical to a clean stacked ref run of the same width."""
    plan = FaultPlan([FaultSpec(site="kernel_execute", times=None,
                                match=lambda c: c["backend"] != "ref")],
                     seed=seed)
    rt = AdsalaRuntime(faults=plan)
    cfg = ServeConfig(backend="pallas", max_batch=n_per_op, linger_ms=1.0,
                      workers=2, min_steal=n_per_op, exec_retries=0,
                      retry_backoff_s=0.0)
    reqs = {op: [make(op, DIMS[op], seed=i) for i in range(n_per_op)]
            for op in OPS}
    with BlasService(runtime=rt, config=cfg, faults=plan) as svc:
        futs = {op: _track(futures_seen,
                           [svc.submit(op, r) for r in reqs[op]])
                for op in OPS}
        outs = {op: [np.asarray(f.result(timeout=120)) for f in futs[op]]
                for op in OPS}
    bit_identical = True
    for op in OPS:
        stacked = tuple(np.stack([r[i] for r in reqs[op]])
                        for i in range(len(reqs[op][0])))
        clean = np.asarray(run_op(op, stacked, backend="ref", stacked=True))
        for i, out in enumerate(outs[op]):
            if not np.array_equal(out, clean[i]):
                bit_identical = False
    return {
        "crash_storm_failed": int(svc.stats.failed),
        "crash_storm_completed": int(svc.stats.completed),
        "crash_storm_bit_identical": bool(bit_identical),
        "crash_storm_fallback_executions":
            int(svc.stats.fallback_executions),
        "crash_storm_injected": int(plan.fired("kernel_execute")),
    }


def scenario_poisoned_knob(seed: int, futures_seen: list) -> dict:
    """The selected knob crashes, the default runs clean → quarantine the
    knob, serve on the same backend, recover the model's pick after TTL."""
    be = get_backend("cpu_blocked")
    default = be.default_knob("gemm")
    bad = next(c for c in be.knob_space("gemm").candidates if c != default)
    plan = FaultPlan([FaultSpec(site="kernel_execute", times=None,
                                match=lambda c: c.get("knob") == bad)],
                     seed=seed)
    rt = AdsalaRuntime(faults=plan)
    rt.register(_FixedSub(bad, "cpu_blocked"))
    cfg = ServeConfig(backend="cpu_blocked", max_batch=4, linger_ms=1.0,
                      workers=1, min_steal=4, exec_retries=0,
                      retry_backoff_s=0.0, quarantine_ttl_s=0.3)
    reqs = [make("gemm", (16, 16, 16), seed=i) for i in range(4)]
    with BlasService(runtime=rt, config=cfg, faults=plan) as svc:
        futs = _track(futures_seen, [svc.submit("gemm", r) for r in reqs])
        outs = [np.asarray(f.result(timeout=120), np.float64) for f in futs]
    served_correct = all(
        np.max(np.abs(out - np.asarray(r[0] @ r[1], np.float64)))
        / (np.max(np.abs(np.asarray(r[0] @ r[1], np.float64))) + 1e-9)
        < 5e-4 for r, out in zip(reqs, outs))
    quarantined = rt.is_quarantined("gemm", 4, "cpu_blocked", bad)
    # while the breaker is open: forced to the fallback, never cached
    forced = rt.select("gemm", (16, 16, 16), 4, backend="cpu_blocked")
    not_cached = rt.peek("gemm", (16, 16, 16), 4,
                         backend="cpu_blocked") is None
    time.sleep(0.4)                  # past the TTL: breaker half-opens
    recovered = rt.select("gemm", (16, 16, 16), 4,
                          backend="cpu_blocked") == bad
    return {
        "poisoned_knob_quarantined": bool(
            quarantined and svc.stats.quarantined_knobs == 1),
        "poisoned_knob_served_correct": bool(
            served_correct and svc.stats.failed == 0),
        "poisoned_knob_same_backend": bool(
            svc.stats.fallback_executions == 0),
        "quarantine_forces_fallback": bool(forced == default),
        "quarantine_not_cached_while_open": bool(not_cached),
        "recovery_after_ttl": bool(recovered),
    }


def scenario_worker_death(n: int, seed: int, futures_seen: list) -> dict:
    """A claimed bucket's worker dies → supervisor respawns the thread and
    requeues the bucket; zero request loss."""
    plan = FaultPlan([FaultSpec(site="worker", times=1)], seed=seed)
    cfg = ServeConfig(backend="ref", max_batch=n, linger_ms=1.0, workers=2,
                      min_steal=n)
    reqs = [make("gemm", (16, 16, 16), seed=i) for i in range(n)]
    with BlasService(runtime=AdsalaRuntime(), config=cfg,
                     faults=plan) as svc:
        futs = _track(futures_seen, [svc.submit("gemm", r) for r in reqs])
        outs = [np.asarray(f.result(timeout=120), np.float64) for f in futs]
    correct = all(
        np.max(np.abs(out - np.asarray(r[0] @ r[1], np.float64)))
        / (np.max(np.abs(np.asarray(r[0] @ r[1], np.float64))) + 1e-9)
        < 5e-4 for r, out in zip(reqs, outs))
    return {
        "worker_death_no_loss": bool(
            correct and svc.stats.completed == n and svc.stats.failed == 0
            and plan.fired("worker") == 1),
        "worker_respawns": int(svc.stats.worker_respawns),
    }


def scenario_artifact_load(n_samples: int, seed: int) -> dict:
    """One corrupt artifact + one injected load fault: hydration survives,
    records both casualties, and a retry recovers the injected one."""
    space = ops.knob_space_for("gemm", sizes=(32, 64))
    sub = install_subroutine(
        "gemm", space, lambda dims, knob: 1e-3, n_samples=n_samples,
        dim_lo=32, dim_hi=64, max_footprint_bytes=1_000_000, tune_trials=1,
        candidates=("LinearRegression",), use_lof=False, seed=seed,
        backend="pallas")
    plan = FaultPlan([FaultSpec(site="artifact_load", times=1)], seed=seed)
    with tempfile.TemporaryDirectory() as td:
        reg = ModelRegistry(td, faults=plan)
        reg.save(sub)
        (Path(td) / "pallas__zzz_b4.adsala").write_bytes(b"not msgpack")
        rt = AdsalaRuntime()
        first = reg.load_into(rt)
        first_errors = len(reg.last_load_errors)
        retry = reg.load_into(rt)
        retry_errors = len(reg.last_load_errors)
        return {
            "artifact_load_isolated": bool(
                first == 0 and first_errors == 2
                and retry == 1 and retry_errors == 1
                and rt.has("gemm", 4, "pallas")),
        }


def scenario_retuner_refit(n_samples: int, seed: int) -> dict:
    """Drift-triggered refit raises once: counted, old model keeps serving,
    the NEXT step completes the retune."""
    pool = [(32, 32, 32), (48, 32, 64), (64, 48, 32), (32, 64, 48)]
    space = ops.knob_space_for("gemm", sizes=(32, 64))
    sub = install_subroutine(
        "gemm", space, lambda dims, knob: 1e-3, n_samples=n_samples,
        dim_lo=32, dim_hi=64, max_footprint_bytes=1_000_000, tune_trials=1,
        candidates=("LinearRegression",), use_lof=False, seed=seed,
        backend="pallas")
    plan = FaultPlan([FaultSpec(site="retuner_refit", times=1)], seed=seed)
    rt = AdsalaRuntime()
    rt.register(sub)
    ret = Retuner(rt, config=RetuneConfig(min_samples=len(pool),
                                          tune_trials=1, seed=seed),
                  faults=plan)
    before = {d: rt.select("gemm", d, 4, backend="pallas") for d in pool}
    for d in pool:                   # measured 4x the (flat) prediction
        rt.record_batch("gemm", d, 4, "pallas", 1, exec_seconds=4e-3,
                        exec_items=1)
    first = ret.step()               # refit raises: counted, survived
    survived = (first == [] and ret.stats.refit_failures == 1
                and ret.stats.retunes == 0)
    during = {d: rt.select("gemm", d, 4, backend="pallas") for d in pool}
    still_serving = during == before        # old model's decisions intact
    second = ret.step()              # fault cleared: the retune completes
    recovered = (second == [("pallas", "gemm", 4)]
                 and ret.stats.retunes == 1)
    return {
        "refit_failure_survived": bool(survived),
        "refit_served_old_model": bool(still_serving),
        "refit_recovered_next_step": bool(recovered),
    }


def scenario_error_budget(seed: int, futures_seen: list) -> dict:
    """A permanently dead rung: after one warmup bucket pays the full retry
    schedule, every later bucket must skip the rung outright (zero kernel
    attempts, zero backoff sleeps) — and the budget-gated ladder must beat
    the ungated ladder on wall clock for the same workload."""
    n_later = 6

    def run(enabled: bool):
        plan = FaultPlan([FaultSpec(site="kernel_execute", times=None,
                                    match=lambda c:
                                    c["backend"] == "cpu_blocked")],
                         seed=seed)
        rt = AdsalaRuntime(faults=plan)
        cfg = ServeConfig(backend="cpu_blocked", max_batch=1, linger_ms=0.5,
                          workers=1, min_steal=1, exec_retries=2,
                          retry_backoff_s=0.03, error_budget=enabled,
                          budget_window=8, budget_threshold=0.4,
                          budget_min_count=2, budget_probe_interval_s=60.0)
        reqs = [make("gemm", (16, 16, 16), seed=i)
                for i in range(1 + n_later)]
        t0 = time.perf_counter()
        with BlasService(runtime=rt, config=cfg, faults=plan) as svc:
            f0 = _track(futures_seen, [svc.submit("gemm", reqs[0])])[0]
            f0.result(timeout=120)
            fired_warmup = plan.fired("kernel_execute")
            futs = _track(futures_seen,
                          [svc.submit("gemm", r) for r in reqs[1:]])
            for f in futs:
                f.result(timeout=120)
            fired_later = plan.fired("kernel_execute") - fired_warmup
            stats = svc.stats
        return (time.perf_counter() - t0, fired_warmup, fired_later, stats)

    t_on, warm_on, later_on, stats_on = run(True)
    t_off, _warm_off, later_off, stats_off = run(False)
    return {
        # warmup paid the full schedule (3 attempts), then zero attempts:
        # the breaker opened and every later bucket skipped the dead rung
        "budget_rung_skipped": bool(warm_on == 3 and later_on == 0),
        "budget_skips_counted": bool(stats_on.budget_skips >= n_later),
        "budget_all_served": bool(stats_on.failed == 0
                                  and stats_off.failed == 0
                                  and later_off == 3 * n_later),
        "budget_ladder_speedup": round(t_off / t_on, 2),
    }


def scenario_budget_probe(seed: int, futures_seen: list) -> dict:
    """Half-open recovery: the fault dies with the warmup bucket, the next
    bucket is skipped (breaker open), and after the probe interval one
    probe attempt closes the breaker — traffic returns to the primary."""
    plan = FaultPlan([FaultSpec(site="kernel_execute", times=3,
                                match=lambda c:
                                c["backend"] == "cpu_blocked")],
                     seed=seed)
    rt = AdsalaRuntime(faults=plan)
    cfg = ServeConfig(backend="cpu_blocked", max_batch=1, linger_ms=0.5,
                      workers=1, min_steal=1, exec_retries=2,
                      retry_backoff_s=0.0, budget_window=8,
                      budget_threshold=0.4, budget_min_count=2,
                      budget_probe_interval_s=0.25)
    with BlasService(runtime=rt, config=cfg, faults=plan) as svc:
        for _ in range(2):               # warmup (opens) + one skipped
            f = _track(futures_seen,
                       [svc.submit("gemm", make("gemm", (16, 16, 16)))])[0]
            f.result(timeout=120)
        skipped = svc.stats.budget_skips
        fallbacks_before = svc.stats.fallback_executions
        time.sleep(0.3)                  # past the probe interval
        f = _track(futures_seen,
                   [svc.submit("gemm", make("gemm", (16, 16, 16)))])[0]
        f.result(timeout=120)            # probe attempt: fault exhausted
        state = svc.budget_state().get(("cpu_blocked", "gemm"), {})
        return {
            "budget_probe_recovers": bool(
                skipped >= 1 and svc.stats.budget_probes == 1
                and state.get("state") == "closed"
                and svc.stats.fallback_executions == fallbacks_before),
        }


def scenario_admission(seed: int, futures_seen: list) -> dict:
    """Overload sheds at the front door: backlogged batch/exploration
    traffic is rejected at its threshold while user traffic is admitted;
    a deadline the bucket's observed queue delay cannot meet is rejected
    before it ever parks; brownout serves with zero model evaluations."""
    # priority shedding: one worker held by an injected latency while user
    # traffic fills the buffer to the shed thresholds
    plan = FaultPlan([FaultSpec(site="stacked_execute", exc=None,
                                latency_s=0.25, times=None)], seed=seed)
    cfg = ServeConfig(backend="ref", max_batch=1, linger_ms=0.5, workers=1,
                      min_steal=1, max_pending=8, shed_explore_at=0.25,
                      shed_batch_at=0.5)
    reqs = [make("gemm", (16, 16, 16), seed=i) for i in range(4)]
    with BlasService(runtime=AdsalaRuntime(), config=cfg,
                     faults=plan) as svc:
        futs = _track(futures_seen, [svc.submit("gemm", r) for r in reqs])
        shed = 0
        for prio in ("exploration", "batch"):   # 4 in flight >= 2 and >= 4
            try:
                svc.submit("gemm", reqs[0], priority=prio)
            except AdmissionRejectedError:
                shed += 1
        for f in futs:
            f.result(timeout=120)
        priority_ok = (shed == 2 and svc.stats.shed_priority == 2
                       and svc.stats.completed == len(reqs)
                       and svc.stats.failed == 0)

    # deadline shedding: the bucket's recorded queue delay says 0.5s, the
    # request allows 0.05s — rejected synchronously, zero evals spent
    rt = AdsalaRuntime()
    rt.record_batch("gemm", (16, 16, 16), 4, "ref", 1,
                    queue_seconds=0.5, exec_items=1)
    cfg2 = ServeConfig(backend="ref", max_batch=1, linger_ms=0.5, workers=1,
                       min_steal=1)
    with BlasService(runtime=rt, config=cfg2) as svc2:
        try:
            svc2.submit("gemm", reqs[0], deadline=0.05)
            deadline_ok = False
        except AdmissionRejectedError:
            deadline_ok = svc2.stats.shed_deadline == 1

    # brownout: past the backlog threshold every bucket serves
    # cached-or-default knobs — the registered model is never evaluated
    rt3 = AdsalaRuntime()
    sub = _FixedSub(get_backend("ref").default_knob("gemm"), "ref")
    rt3.register(sub)
    cfg3 = ServeConfig(backend="ref", max_batch=1, linger_ms=0.5, workers=1,
                       min_steal=1, brownout_pending=1)
    with BlasService(runtime=rt3, config=cfg3) as svc3:
        futs = _track(futures_seen, [svc3.submit("gemm", r) for r in reqs])
        for f in futs:
            f.result(timeout=120)
        brownout_ok = (sub.evals == 0 and rt3.stats.model_evals == 0
                       and svc3.stats.brownout_batches >= 1
                       and svc3.stats.failed == 0)
        brownout_batches = svc3.stats.brownout_batches
    # control: the same workload without brownout DOES evaluate the model
    # (otherwise the zero-evals assertion above is vacuous)
    rt4 = AdsalaRuntime()
    sub4 = _FixedSub(get_backend("ref").default_knob("gemm"), "ref")
    rt4.register(sub4)
    with BlasService(runtime=rt4, config=cfg2) as svc4:
        futs = _track(futures_seen, [svc4.submit("gemm", r) for r in reqs])
        for f in futs:
            f.result(timeout=120)
    return {
        "admission_priority_shed": bool(priority_ok),
        "admission_deadline_shed": bool(deadline_ok),
        "brownout_zero_evals": bool(brownout_ok),
        "brownout_batches": int(brownout_batches),
        "brownout_control_evals": int(sub4.evals),
    }


def scenario_torn_snapshot(seed: int) -> dict:
    """One decision-cache snapshot record damaged on disk: warm start drops
    exactly the torn record and imports the survivors (the full crash
    matrix lives in recovery_bench)."""
    from repro.core.durable import MAGIC
    shapes = [(32, 32, 32), (64, 64, 64)]
    with tempfile.TemporaryDirectory() as td:
        reg = ModelRegistry(td)
        rt = AdsalaRuntime()
        rt.register(_FixedSub(get_backend("cpu_blocked").default_knob("gemm"),
                              "cpu_blocked"))
        for d in shapes:
            rt.select("gemm", d, 4, backend="cpu_blocked")
        path = reg.save_decision_cache(rt)
        lines = path.read_text().splitlines()
        assert lines[0] == MAGIC
        lines[2] = "00000000" + lines[2][8:]     # oldest entry: bad CRC
        path.write_text("\n".join(lines) + "\n")
        warm = AdsalaRuntime()
        warm.register(_FixedSub(
            get_backend("cpu_blocked").default_knob("gemm"), "cpu_blocked"))
        imported = ModelRegistry(td).load_decision_cache(warm)
        return {
            "torn_snapshot_recovered": bool(
                imported == 1 and [tuple(e["dims"])
                                   for e in warm.export_cache()]
                == [(64, 64, 64)]),
        }


def run_scenarios(*, n_per_op: int = 4, n_samples: int = 12,
                  seed: int = 0) -> dict:
    futures_seen: list = []
    metrics: dict = {}
    metrics.update(scenario_crash_storm(n_per_op, seed, futures_seen))
    metrics.update(scenario_poisoned_knob(seed, futures_seen))
    metrics.update(scenario_worker_death(max(4, n_per_op), seed,
                                         futures_seen))
    metrics.update(scenario_artifact_load(n_samples, seed))
    metrics.update(scenario_retuner_refit(n_samples, seed))
    metrics.update(scenario_error_budget(seed, futures_seen))
    metrics.update(scenario_budget_probe(seed, futures_seen))
    metrics.update(scenario_admission(seed, futures_seen))
    metrics.update(scenario_torn_snapshot(seed))
    # the headline contract: every future ever submitted has resolved
    metrics["hung_futures"] = sum(not f.done() for f in futures_seen)
    metrics["futures_submitted"] = len(futures_seen)
    return metrics


STRUCTURAL = (("crash_storm_failed", 0),
              ("crash_storm_bit_identical", True),
              ("poisoned_knob_quarantined", True),
              ("poisoned_knob_served_correct", True),
              ("poisoned_knob_same_backend", True),
              ("quarantine_forces_fallback", True),
              ("quarantine_not_cached_while_open", True),
              ("recovery_after_ttl", True),
              ("worker_death_no_loss", True),
              ("artifact_load_isolated", True),
              ("refit_failure_survived", True),
              ("refit_served_old_model", True),
              ("refit_recovered_next_step", True),
              ("budget_rung_skipped", True),
              ("budget_skips_counted", True),
              ("budget_all_served", True),
              ("budget_probe_recovers", True),
              ("admission_priority_shed", True),
              ("admission_deadline_shed", True),
              ("brownout_zero_evals", True),
              ("torn_snapshot_recovered", True),
              ("hung_futures", 0))

#: floor for the enabled/disabled wall-clock ratio of the dead-rung
#: workload — the ungated ladder pays 3 attempts + backoff sleeps per
#: bucket where the gated one skips outright, so real values sit well
#: above 2x; 1.2x only catches the gate silently not engaging
SPEEDUP_FLOOR = 1.2


def check(metrics: dict) -> list[str]:
    """Structural pass/fail list (empty = healthy)."""
    bad = [f"{k}={metrics[k]!r} (want {want!r})"
           for k, want in STRUCTURAL if metrics[k] != want]
    if metrics["crash_storm_fallback_executions"] < 1:
        bad.append("crash_storm_fallback_executions=0 (want >=1)")
    if metrics["worker_respawns"] < 1:
        bad.append("worker_respawns=0 (want >=1)")
    if metrics["budget_ladder_speedup"] < SPEEDUP_FLOOR:
        bad.append(f"budget_ladder_speedup="
                   f"{metrics['budget_ladder_speedup']} "
                   f"(want >={SPEEDUP_FLOOR})")
    if metrics["brownout_batches"] < 1:
        bad.append("brownout_batches=0 (want >=1)")
    if metrics["brownout_control_evals"] < 1:
        bad.append("brownout_control_evals=0 (want >=1 — the brownout "
                   "zero-evals gate would be vacuous)")
    return bad


def record_entry(entry_id: str, payload: dict, path: Path = BENCH_PATH):
    from common import record_trajectory_entry    # script-mode only module
    record_trajectory_entry(path, "chaos", entry_id, payload)
    print(f"[chaos_bench] recorded entry {entry_id!r} -> {path}")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--per-op", type=int, default=8,
                   help="requests per op in the crash storm")
    p.add_argument("--samples", type=int, default=24,
                   help="install-sweep Halton samples for the artifact/"
                        "retuner scenarios")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--smoke", action="store_true",
                   help="small preset for CI (4 per op, 12 samples)")
    p.add_argument("--json", type=Path, default=None,
                   help="write metrics JSON here (bench_diff --chaos-fresh "
                        "input)")
    p.add_argument("--record", default=None, metavar="ENTRY",
                   help="append/refresh this entry in the committed "
                        "BENCH_chaos.json trajectory")
    args = p.parse_args(argv)
    if args.smoke:
        args.per_op, args.samples = 4, 12

    metrics = run_scenarios(n_per_op=args.per_op, n_samples=args.samples,
                            seed=args.seed)
    for k, v in metrics.items():
        print(f"  {k:>36}: {v}")
    bad = check(metrics)

    if args.json is not None:
        args.json.write_text(json.dumps(
            {"summary": metrics, "smoke_baseline": metrics}, indent=1))
        print(f"[chaos_bench] wrote {args.json}")
    if args.record is not None:
        record_entry(args.record, {
            "host": {"platform": platform.platform(),
                     "python": platform.python_version()},
            "config": {"per_op": args.per_op, "samples": args.samples,
                       "seed": args.seed},
            "smoke_baseline": metrics,
        })

    if bad:
        print(f"[chaos_bench] FAILED: {'; '.join(bad)}")
        return 1
    print(f"[chaos_bench] OK — {metrics['futures_submitted']} futures all "
          f"resolved, {metrics['crash_storm_injected']} injected crashes "
          f"absorbed, knob quarantined + recovered after TTL, "
          f"{metrics['worker_respawns']} worker respawn(s), retuner refit "
          f"failure survived")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
