#!/usr/bin/env python
"""Fleet harness: sharded multi-process serving vs the in-process service.

Two scenarios, both on the same Zipf-distributed GEMM-family traffic the
serving benches use:

  throughput  the same open-loop workload is driven through (a) a
              single-process :class:`~repro.serving.BlasService` and (b) an
              N-process :class:`~repro.serving.FleetService` — identical
              front-end, but each flushed bucket executes in its own OS
              process with its own runtime, so the stacked kernels escape
              the GIL.  Reports the fleet/single throughput ratio (the
              ISSUE-10 claim: >= 1.5x with 2 processes on a multi-core
              host; advisory below --low-core-threshold cores, where there
              is no parallelism to win);
  warm-join   the shared-journal coherence claim, structurally: member 1
              decides a shape set against a real installed model (each
              miss-path decision journaled), ``add_member()`` hydrates a
              second executor from the shared journal, the same shapes are
              re-served, and the newcomer must have performed ZERO model
              evaluations (``warm_join_zero_evals``).  Also checks the
              fingerprint resolver picked the exact arch slug and the
              membership roster saw both executors.

Structural flags are exact-gated by ``scripts/bench_diff.py --fleet-fresh``;
the throughput ratio is tolerance-gated (warn-only on low-core hosts, like
the serving speedup gate).

    PYTHONPATH=src python benchmarks/fleet_bench.py --smoke
    PYTHONPATH=src python benchmarks/fleet_bench.py --processes 2 \
        --requests 600 --json /tmp/fleet.json
    PYTHONPATH=src python benchmarks/fleet_bench.py --record pr10
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from serve_bench import _drive, build_traffic, percentiles  # noqa: E402

from repro.core import AdsalaRuntime, ModelRegistry, install_backend  # noqa: E402
from repro.distributed import FleetMembership  # noqa: E402
from repro.serving import (BlasService, FleetConfig, FleetService,  # noqa: E402
                           ServeConfig)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_fleet.json"


# ---------------------------------------------------------------------------
# scenario 1: throughput — fleet vs in-process service
# ---------------------------------------------------------------------------

def _warm_service(svc, traffic) -> None:
    """One request per distinct shape: JIT/import cost (the fleet's children
    pay the kernel-stack import on their first exec) stays out of the
    measured window for both modes."""
    done = set()
    futs = []
    for op, dims, operands in traffic:
        if (op, dims) not in done:
            done.add((op, dims))
            futs.append(svc.submit(op, operands))
    for f in futs:
        f.result(timeout=300)


def _measure(svc, traffic, args) -> dict:
    futs = []

    def submit_one(i, op, operands, done_at):
        f = svc.submit(op, operands)
        f.add_done_callback(
            lambda _f, i=i: done_at.__setitem__(i, time.perf_counter()))
        futs.append(f)

    def wait_all():
        for f in futs:
            f.result(timeout=600)

    wall, lat = _drive(traffic, args, submit_one, wait_all)
    p50, p99 = percentiles(lat)
    return {"wall_s": wall, "throughput_rps": len(traffic) / wall,
            "p50_ms": p50 * 1e3, "p99_ms": p99 * 1e3}


def _median_rows(svc, traffic, args) -> dict:
    rows = [_measure(svc, traffic, args) for _ in range(max(1, args.repeats))]
    rows.sort(key=lambda r: r["throughput_rps"])
    return rows[len(rows) // 2]


def scenario_throughput(args) -> tuple[dict, dict]:
    traffic = build_traffic(args.op, args)
    print(f"[fleet_bench] {len(traffic)} {args.op} requests over "
          f"{args.shapes} Zipf(a={args.zipf_a}) shapes, backend="
          f"{args.backend}, {args.processes} executor processes")
    scfg = ServeConfig(backend=args.backend, max_batch=args.max_batch,
                       linger_ms=args.linger_ms, workers=1,
                       max_pending=args.max_pending)

    with BlasService(runtime=AdsalaRuntime(), config=scfg) as svc:
        _warm_service(svc, traffic)
        single = _median_rows(svc, traffic, args)
    single["mode"] = "single-process"

    svc = FleetService(fleet=FleetConfig(processes=args.processes),
                       config=scfg)
    try:
        _warm_service(svc, traffic)
        fleet = _median_rows(svc, traffic, args)
        fleet["batches"] = svc.stats.batches
        fleet["mean_batch"] = svc.stats.mean_batch
    finally:
        svc.close()
    fleet["mode"] = f"fleet-{args.processes}p"

    for row in (single, fleet):
        print(f"[fleet_bench] {row['mode']:>15}: "
              f"{row['throughput_rps']:8.1f} req/s  "
              f"p50={row['p50_ms']:7.2f} ms  p99={row['p99_ms']:7.2f} ms")
    ratio = fleet["throughput_rps"] / max(single["throughput_rps"], 1e-9)
    print(f"[fleet_bench] fleet/single throughput: {ratio:.2f}x "
          f"(median of {max(1, args.repeats)})")
    return single, fleet


# ---------------------------------------------------------------------------
# scenario 2: warm join — shared-journal coherence, structurally
# ---------------------------------------------------------------------------

WARM_SHAPES = ((32, 32, 32), (48, 32, 32), (64, 48, 32), (64, 64, 64))


def scenario_warm_join(args) -> dict:
    """Member 1 decides WARM_SHAPES against an installed model; a member
    added afterwards hydrates from the shared journal and re-serving the
    same shapes costs it zero model evaluations."""
    from repro.backends import get_backend
    rng = np.random.default_rng(args.seed + 7)

    def submit_all(svc, repeat=1):
        futs = []
        for m, n, k in WARM_SHAPES * repeat:
            a = rng.standard_normal((m, k)).astype(np.float32)
            b = rng.standard_normal((k, n)).astype(np.float32)
            futs.append(svc.submit("gemm", (a, b)))
        for f in futs:
            f.result(timeout=300)

    with tempfile.TemporaryDirectory() as td:
        reg = ModelRegistry(td)
        sub_reg = reg.for_fingerprint(create=True)
        print("[fleet_bench] warm-join: mini-installing tuned "
              "cpu_blocked/gemm model into the arch-fingerprint registry ...")
        install_backend(get_backend("cpu_blocked"), ops=("gemm",),
                        n_samples=12, dim_lo=32, dim_hi=96,
                        max_footprint_bytes=1_000_000, tune_trials=1,
                        candidates=("LinearRegression",), registry=sub_reg,
                        seed=args.seed)
        svc = FleetService(
            fleet=FleetConfig(processes=1, registry_root=td),
            config=ServeConfig(backend="cpu_blocked", max_batch=4,
                               linger_ms=1.0))
        try:
            submit_all(svc)
            first = svc.fleet_stats()[0]
            print(f"[fleet_bench] member 1: {first['model_evals']} model "
                  f"evals over {len(WARM_SHAPES)} shapes, fingerprint "
                  f"resolution={first['resolution'].get('mode')!r}")
            info = svc.add_member()
            print(f"[fleet_bench] member 2 joined: "
                  f"{info.get('warm_started', 0)} decisions hydrated "
                  f"from the shared journal")
            submit_all(svc, repeat=4)
            stats = svc.fleet_stats()
            newcomer = stats[1]
            members = FleetMembership(Path(td) / "members").members(
                live_only=False)
        finally:
            svc.close()
    print(f"[fleet_bench] member 2 after re-serve: "
          f"{newcomer['model_evals']} model evals "
          f"({newcomer['journal_absorbed']} journal records absorbed)")
    return {
        "warm_join_first_decided": bool(first["model_evals"] >= 1),
        "warm_join_fingerprint_exact": bool(
            first["resolution"].get("mode") == "exact"),
        "warm_join_hydrated": bool(
            info.get("warm_started", 0) >= len(WARM_SHAPES)),
        "warm_join_zero_evals": bool(newcomer["model_evals"] == 0),
        "warm_join_members_seen": len(members),
        "warm_join_first_evals": int(first["model_evals"]),
        "warm_join_hydrated_decisions": int(info.get("warm_started", 0)),
    }


STRUCTURAL = (("warm_join_first_decided", True),
              ("warm_join_fingerprint_exact", True),
              ("warm_join_hydrated", True),
              ("warm_join_zero_evals", True),
              ("warm_join_members_seen", 2))


def check(metrics: dict) -> list[str]:
    return [f"{k}={metrics[k]!r} (want {want!r})"
            for k, want in STRUCTURAL if metrics[k] != want]


def record_entry(entry_id: str, payload: dict, path: Path = BENCH_PATH):
    from common import record_trajectory_entry    # script-mode only module
    record_trajectory_entry(path, "fleet", entry_id, payload)
    print(f"[fleet_bench] recorded entry {entry_id!r} -> {path}")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--op", default="gemm", choices=(
        "gemm", "symm", "syrk", "syr2k", "trmm", "trsm"))
    p.add_argument("--backend", default="cpu_blocked",
                   help="throughput-scenario backend (cpu_blocked: real "
                        "numpy kernels, the regime where processes beat "
                        "threads)")
    p.add_argument("--processes", type=int, default=2)
    p.add_argument("--requests", type=int, default=600)
    p.add_argument("--shapes", type=int, default=6)
    p.add_argument("--zipf-a", type=float, default=1.5)
    p.add_argument("--rate", type=float, default=0.0,
                   help="open-loop arrival rate req/s (0 = saturation)")
    p.add_argument("--dim-lo", type=int, default=32)
    p.add_argument("--dim-hi", type=int, default=96)
    p.add_argument("--max-batch", type=int, default=16)
    p.add_argument("--linger-ms", type=float, default=5.0)
    p.add_argument("--max-pending", type=int, default=4096)
    p.add_argument("--repeats", type=int, default=3)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--low-core-threshold", type=int, default=3,
                   help="below this many cores the --min-ratio gate is "
                        "advisory (a 1-2 core host has no process "
                        "parallelism for the fleet to win)")
    p.add_argument("--strict", action="store_true",
                   help="enforce --min-ratio even on low-core hosts")
    p.add_argument("--min-ratio", type=float, default=None,
                   help="exit nonzero unless fleet/single throughput >= "
                        "this (subject to the low-core guard)")
    p.add_argument("--smoke", action="store_true",
                   help="CI preset: tiny workload, 1 repeat")
    p.add_argument("--json", type=Path, default=None,
                   help="write metrics JSON here (bench_diff --fleet-fresh "
                        "input)")
    p.add_argument("--record", default=None, metavar="ENTRY",
                   help="append/refresh this entry in the committed "
                        "BENCH_fleet.json trajectory")
    args = p.parse_args(argv)
    if args.smoke:
        args.requests = min(args.requests, 160)
        args.shapes = min(args.shapes, 4)
        args.repeats = 1
    low_core = (os.cpu_count() or 1) < args.low_core_threshold

    single, fleet = scenario_throughput(args)
    ratio = fleet["throughput_rps"] / max(single["throughput_rps"], 1e-9)
    metrics = scenario_warm_join(args)
    metrics.update({
        "fleet_ratio": round(ratio, 3),
        "fleet_rps": round(fleet["throughput_rps"], 1),
        "single_rps": round(single["throughput_rps"], 1),
        "processes": args.processes,
        "cpus": os.cpu_count(),
        "low_core": low_core,
    })
    for k, v in metrics.items():
        print(f"  {k:>28}: {v}")
    bad = check(metrics)

    if args.json is not None:
        args.json.write_text(json.dumps(
            {"summary": metrics, "smoke_baseline": metrics}, indent=1))
        print(f"[fleet_bench] wrote {args.json}")
    if args.record is not None:
        record_entry(args.record, {
            "host": {"platform": platform.platform(),
                     "python": platform.python_version(),
                     "cpus": os.cpu_count()},
            "config": {"op": args.op, "backend": args.backend,
                       "processes": args.processes,
                       "requests": args.requests, "shapes": args.shapes,
                       "zipf_a": args.zipf_a, "max_batch": args.max_batch,
                       "linger_ms": args.linger_ms,
                       "repeats": args.repeats},
            "single": single, "fleet": fleet,
            "smoke_baseline": metrics,
        })

    ok = True
    if args.min_ratio is not None and ratio < args.min_ratio:
        if low_core and not args.strict:
            print(f"[fleet_bench] WARNING: fleet/single {ratio:.2f}x < "
                  f"{args.min_ratio}x — low-core host, advisory only")
        else:
            print(f"[fleet_bench] FAILED: fleet/single {ratio:.2f}x < "
                  f"{args.min_ratio}x")
            ok = False
    if bad:
        print(f"[fleet_bench] FAILED: {'; '.join(bad)}")
        return 1
    if ok:
        print("[fleet_bench] OK — warm join hydrated "
              f"{metrics['warm_join_hydrated_decisions']} decisions from "
              f"the shared journal with zero newcomer model evals; "
              f"fleet/single throughput {ratio:.2f}x on "
              f"{os.cpu_count()} cpu(s)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
