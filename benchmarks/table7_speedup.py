"""Paper Table VII — measured speedup statistics per subroutine × precision.

Fresh scrambled-Halton test dims (disjoint seed from calibration, as the
paper prescribes), each timed at the default (max-parallelism) config vs.
the ADSALA-predicted config including the live model-evaluation time.
Reports Mean/Std/Min/25%/50%/75%/Max speedup — the paper's headline table.

Backend-parameterised: the same harness measures any registered execution
backend (the repo analogue of the paper's MKL-vs-BLIS columns).  As a CLI it
runs the *full* install→select→measure loop — if the calibration store holds
no artifacts for the requested backend it installs them first through the
shared Backend protocol:

    PYTHONPATH=src python -m benchmarks.table7_speedup --backend cpu_blocked
    PYTHONPATH=src python -m benchmarks.table7_speedup --backend pallas
"""

from __future__ import annotations

import json

import numpy as np

from repro.core.features import SUBROUTINE_NDIMS, footprint_words
from repro.core.halton import sample_dims
from .common import (ADSALA, DEFAULT_BACKEND, OPS, PRECISIONS, csv_row,
                     load_runtime, measure_speedup)

#: per-backend measurement regime.  cpu_blocked mirrors the paper's scaled
#: setup (see the dims note below); pallas interpret-mode on CPU hosts pays
#: a per-(shape,knob) jit compile, so it measures fewer, smaller cases —
#: the loop shape is identical, only the scale differs.
_PROFILES = {
    "cpu_blocked": dict(dim_lo=128, dim_hi=512, precisions=("s", "d")),
    "pallas": dict(dim_lo=128, dim_hi=256, precisions=("s",)),
    "ref": dict(dim_lo=128, dim_hi=512, precisions=("s",)),
}


def run(n_test: int = 8, quick: bool = False,
        backend: str = DEFAULT_BACKEND,
        ops: tuple[str, ...] | None = None) -> list[str]:
    prof = _PROFILES.get(backend, _PROFILES["cpu_blocked"])
    rt = load_runtime(backend=backend)
    rows = []
    if rt is None:
        return [csv_row(f"table7.{backend}.skipped", 0.0,
                        "no-calibration-artifacts")]
    results = {}
    if ops is None:
        ops = OPS if not quick else ("gemm", "symm")
    for op in ops:
        ndims = SUBROUTINE_NDIMS[op]
        for prec in prof["precisions"]:
            if not rt.has(op, np.dtype(PRECISIONS[prec]).itemsize,
                          backend=backend):
                rows.append(csv_row(f"table7.{backend}.{prec}{op}", 0.0,
                                    "untuned"))
                continue
            dtype_bytes = np.dtype(PRECISIONS[prec]).itemsize

            def fp(d):
                return footprint_words(op, d) * dtype_bytes

            # paper tests 2000–7000 dims where ops run 10–1000 ms; the
            # scaled-down analogue here is 128–512 (0.5–20 ms ops) so the
            # per-call model evaluation (~130 µs) plays the same ~1% role.
            # Below that regime the LRU memo cache is what amortises eval.
            dims_list = sample_dims(n_test, ndims, lo=prof["dim_lo"],
                                    hi=prof["dim_hi"],
                                    max_footprint_bytes=6_000_000,
                                    footprint_fn=fp, seed=12345)
            sp, total_us = [], 0.0
            recs = []
            for drow in dims_list:
                r = measure_speedup(op, prec, rt,
                                    tuple(int(v) for v in drow),
                                    backend=backend)
                sp.append(r["speedup"])
                total_us += (r["t_tuned"] + r["t_eval"]) * 1e6
                recs.append(r)
            sp = np.array(sp)
            stats = {"mean": sp.mean(), "std": sp.std(), "min": sp.min(),
                     "p25": np.percentile(sp, 25), "p50": np.median(sp),
                     "p75": np.percentile(sp, 75), "max": sp.max()}
            results[f"{prec}{op}"] = {"stats": stats,
                                      "cases": [
                                          {**r, "dims": list(r["dims"])}
                                          for r in recs]}
            rows.append(csv_row(
                f"table7.{backend}.{prec}{op}", total_us / len(sp),
                f"mean={stats['mean']:.2f};p50={stats['p50']:.2f};"
                f"max={stats['max']:.2f}"))
    suffix = "" if backend == DEFAULT_BACKEND else f"_{backend}"
    out = ADSALA / f"table7_speedup{suffix}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=2, default=float))
    return rows


def _ensure_installed(backend: str, *, samples: int,
                      ops: tuple[str, ...], precisions: tuple[str, ...],
                      log=print) -> None:
    """Install-time calibration for every (op, precision) the measurement
    pass will ask for and the store doesn't hold yet."""
    from repro.backends import get_backend
    from repro.core import ModelRegistry, install_backend

    reg = ModelRegistry(ADSALA / "models")
    have = {(s.op, s.dtype_bytes) for s in reg.load_all(backend)}
    be = get_backend(backend)
    # pallas interpret-mode compiles per (padded shape, knob): keep the
    # sweep small and the knob grid coarse; cpu_blocked affords the
    # calibrate.py-scale defaults
    kw = dict(n_samples=samples, dim_lo=32, dim_hi=256,
              max_footprint_bytes=4_000_000, tune_trials=2,
              candidates=("LinearRegression", "DecisionTree", "XGBoost"))
    sizes = (128, 256) if backend == "pallas" else None
    for prec in precisions:
        dtype = PRECISIONS[prec]
        missing = tuple(op for op in ops
                        if (op, np.dtype(dtype).itemsize) not in have)
        if not missing:
            continue
        log(f"[table7] installing {backend}/{prec}: {','.join(missing)} "
            f"({samples} samples/op) ...")
        install_backend(be, ops=missing, dtype=dtype, sizes=sizes,
                        registry=reg, log=log, **kw)


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--backend", default=DEFAULT_BACKEND)
    p.add_argument("--quick", action="store_true")
    p.add_argument("--n-test", type=int, default=4)
    p.add_argument("--samples", type=int, default=24,
                   help="calibration samples/op when installing")
    p.add_argument("--ops", default="",
                   help="comma list; default = quick pair or all six")
    args = p.parse_args(argv)

    from repro.backends import available_backends
    if args.backend not in available_backends():
        print(f"table7: unknown backend {args.backend!r}; registered: "
              f"{', '.join(available_backends())}")
        return 2

    prof = _PROFILES.get(args.backend, _PROFILES["cpu_blocked"])
    quick = args.quick or args.backend == "pallas"
    ops = tuple(o for o in args.ops.split(",") if o) \
        or (("gemm", "symm") if quick else OPS)
    _ensure_installed(args.backend, samples=args.samples, ops=ops,
                      precisions=prof["precisions"])
    print("name,us_per_call,derived")
    for row in run(n_test=args.n_test, backend=args.backend, ops=ops):
        print(row)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
