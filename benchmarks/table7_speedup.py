"""Paper Table VII — measured speedup statistics per subroutine × precision.

Fresh scrambled-Halton test dims (disjoint seed from calibration, as the
paper prescribes), each timed at the default (max-parallelism) config vs.
the ADSALA-predicted config including the live model-evaluation time.
Reports Mean/Std/Min/25%/50%/75%/Max speedup — the paper's headline table.
"""

from __future__ import annotations

import json

import numpy as np

from repro.core.features import SUBROUTINE_NDIMS, footprint_words
from repro.core.halton import sample_dims
from .common import (ADSALA, OPS, PRECISIONS, csv_row, load_runtime,
                     measure_speedup)


def run(n_test: int = 8, quick: bool = False) -> list[str]:
    rt = load_runtime()
    rows = []
    if rt is None:
        return [csv_row("table7.skipped", 0.0, "no-calibration-artifacts")]
    results = {}
    ops = OPS if not quick else ("gemm", "symm")
    for op in ops:
        ndims = SUBROUTINE_NDIMS[op]
        for prec in ("s", "d"):
            dtype_bytes = np.dtype(PRECISIONS[prec]).itemsize

            def fp(d):
                return footprint_words(op, d) * dtype_bytes

            # paper tests 2000–7000 dims where ops run 10–1000 ms; the
            # scaled-down analogue here is 128–512 (0.5–20 ms ops) so the
            # per-call model evaluation (~130 µs) plays the same ~1% role.
            # Below that regime the LRU memo cache is what amortises eval.
            dims_list = sample_dims(n_test, ndims, lo=128, hi=512,
                                    max_footprint_bytes=6_000_000,
                                    footprint_fn=fp, seed=12345)
            sp, total_us = [], 0.0
            recs = []
            for drow in dims_list:
                r = measure_speedup(op, prec, rt,
                                    tuple(int(v) for v in drow))
                sp.append(r["speedup"])
                total_us += (r["t_tuned"] + r["t_eval"]) * 1e6
                recs.append(r)
            sp = np.array(sp)
            stats = {"mean": sp.mean(), "std": sp.std(), "min": sp.min(),
                     "p25": np.percentile(sp, 25), "p50": np.median(sp),
                     "p75": np.percentile(sp, 75), "max": sp.max()}
            results[f"{prec}{op}"] = {"stats": stats,
                                      "cases": [
                                          {**r, "dims": list(r["dims"])}
                                          for r in recs]}
            rows.append(csv_row(
                f"table7.{prec}{op}", total_us / len(sp),
                f"mean={stats['mean']:.2f};p50={stats['p50']:.2f};"
                f"max={stats['max']:.2f}"))
    out = ADSALA / "table7_speedup.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=2, default=float))
    return rows
