"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import time
from pathlib import Path

import numpy as np

from repro.backends import resolve_backend
from repro.core import AdsalaRuntime, ModelRegistry
from repro.core.timing import time_callable
from repro.kernels.cpu_blocked import make_operands, run_blocked  # noqa: F401 (re-export)

RUNS = Path(__file__).resolve().parents[1] / "runs"
ADSALA = RUNS / "adsala"

PRECISIONS = {"s": np.float32, "d": np.float64}
OPS = ("gemm", "symm", "syrk", "syr2k", "trmm", "trsm")

#: the backend the legacy calibration flow measured (the host black box)
DEFAULT_BACKEND = "cpu_blocked"


def load_runtime(backend: str | None = None) -> AdsalaRuntime | None:
    """Hydrate a runtime from the repo's calibration store; ``backend``
    filters to one tag (None loads every backend's model set)."""
    root = ADSALA / "models"
    if not root.exists():
        return None
    rt = AdsalaRuntime()
    if ModelRegistry(root).load_into(rt, backend=backend) == 0:
        return None
    return rt


def default_knob_from_dataset(op: str, prec: str, backend: str | None = None):
    """The calibration dataset's baseline (max-parallelism) knob; falls back
    to the backend's analytic default when no dataset was persisted."""
    import json
    from repro.core.knobs import Knob
    be_name = backend or DEFAULT_BACKEND
    # only the default backend owns the legacy untagged dataset files —
    # another backend must never inherit a baseline knob from a space it
    # wasn't calibrated over
    names = [f"{be_name}__{op}_{prec}.npz"]
    if be_name == DEFAULT_BACKEND:
        names.append(f"{op}_{prec}.npz")
    for name in names:
        path = ADSALA / "datasets" / name
        if path.exists():
            ds = np.load(path)
            knobs = json.loads(str(ds["knobs"]))
            return Knob(tuple(sorted(knobs[int(ds["default_idx"])].items())))
    return resolve_backend(backend or DEFAULT_BACKEND).default_knob(op)


def measure_speedup(op: str, prec: str, rt: AdsalaRuntime, dims: tuple,
                    *, backend: str = DEFAULT_BACKEND,
                    repeats: int = 2) -> dict:
    """One paper-style measurement: t_default vs t_tuned(+t_eval), executed
    through the shared Backend protocol."""
    be = resolve_backend(backend)
    dtype = PRECISIONS[prec]
    dtype_bytes = np.dtype(dtype).itemsize
    operands = be.prepare(be.make_operands(op, dims, dtype,
                                           seed=hash(dims) % 9973))
    default = default_knob_from_dataset(op, prec, backend=be.name)
    t0 = time.perf_counter()
    knob = rt.select(op, dims, dtype_bytes=dtype_bytes, backend=be.name)
    t_eval = time.perf_counter() - t0
    t_def = time_callable(lambda: be.execute(op, operands, default),
                          warmup=1, repeats=repeats)
    t_tuned = time_callable(lambda: be.execute(op, operands, knob),
                            warmup=1, repeats=repeats)
    return {"dims": dims, "backend": be.name, "t_default": t_def,
            "t_tuned": t_tuned, "t_eval": t_eval,
            "speedup": t_def / (t_tuned + t_eval),
            "knob": knob.dict, "default": default.dict}


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"


def record_trajectory_entry(path: Path, bench_name: str, entry_id: str,
                            payload: dict) -> None:
    """Append/replace a per-PR entry in a committed trajectory file
    (``{"bench": ..., "entries": {id: payload}}``, entries in insertion
    order, newest last — the shape ``scripts/bench_diff.py`` gates on)."""
    import json
    data = {"bench": bench_name, "entries": {}}
    if path.exists():
        data = json.loads(path.read_text())
    data.setdefault("entries", {}).pop(entry_id, None)
    data["entries"][entry_id] = payload
    path.write_text(json.dumps(data, indent=1) + "\n")
