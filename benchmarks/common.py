"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import time
from pathlib import Path

import numpy as np

from repro.core import AdsalaRuntime, ModelRegistry
from repro.core.timing import time_callable
from repro.kernels.cpu_blocked import make_operands, run_blocked
from repro.kernels.ops import knob_space_for

RUNS = Path(__file__).resolve().parents[1] / "runs"
ADSALA = RUNS / "adsala"

PRECISIONS = {"s": np.float32, "d": np.float64}
OPS = ("gemm", "symm", "syrk", "syr2k", "trmm", "trsm")


def load_runtime() -> AdsalaRuntime | None:
    root = ADSALA / "models"
    if not root.exists():
        return None
    rt = AdsalaRuntime()
    ModelRegistry(root).load_into(rt)
    return rt


def default_knob_from_dataset(op: str, prec: str):
    """The calibration dataset's baseline (max-parallelism) knob."""
    import json
    ds = np.load(ADSALA / "datasets" / f"{op}_{prec}.npz")
    knobs = json.loads(str(ds["knobs"]))
    from repro.core.knobs import Knob
    return Knob(tuple(sorted(knobs[int(ds["default_idx"])].items())))


def measure_speedup(op: str, prec: str, rt: AdsalaRuntime, dims: tuple,
                    *, repeats: int = 2) -> dict:
    """One paper-style measurement: t_default vs t_tuned(+t_eval)."""
    dtype = PRECISIONS[prec]
    dtype_bytes = np.dtype(dtype).itemsize
    operands = make_operands(op, dims, dtype, seed=hash(dims) % 9973)
    default = default_knob_from_dataset(op, prec)
    t0 = time.perf_counter()
    knob = rt.select(op, dims, dtype_bytes=dtype_bytes)
    t_eval = time.perf_counter() - t0
    t_def = time_callable(lambda: run_blocked(op, operands, default),
                          warmup=1, repeats=repeats)
    t_tuned = time_callable(lambda: run_blocked(op, operands, knob),
                            warmup=1, repeats=repeats)
    return {"dims": dims, "t_default": t_def, "t_tuned": t_tuned,
            "t_eval": t_eval, "speedup": t_def / (t_tuned + t_eval),
            "knob": knob.dict, "default": default.dict}


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"
