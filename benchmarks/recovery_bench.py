#!/usr/bin/env python
"""Crash-recovery harness: SIGKILL mid-write, torn journals, garbage files.

The durability contract (``repro.core.durable``: atomic checksummed
snapshots + an append-only journal between them) only earns trust if a
process really dying at the worst moment provably loses nothing it
promised to keep.  This bench kills for real and recovers for real:

  1. **SIGKILL mid-snapshot** — a child process builds warm state (a
     decision-cache snapshot, then journaled incremental decisions and an
     opened knob quarantine), starts a second snapshot, and is SIGKILLed
     inside the write window (an injected ``snapshot_write`` latency holds
     the writer with the old snapshot and the journal both still on disk).
     A fresh process must recover the union of snapshot + journal state —
     every decision warm (ZERO model evaluations on recovered shapes), the
     quarantine still open, zero torn records — and serve every request
     submitted against the recovered cache;
  2. **torn journal append** — an injected :class:`TornWrite` truncates one
     journal record mid-append: recovery must drop exactly that record
     (counted), keep its *successor* (appends are newline-prefixed, so a
     torn tail never swallows the next record), and the writer must count
     the failure without raising into the decision path;
  3. **garbage snapshot** — the snapshot file is replaced with non-JSON
     garbage: ``load_decision_cache`` must degrade to a counted cold start
     (never propagate) while the intact journal still replays;
  4. **corrupt snapshot record** — one checksummed record is damaged in
     place (bit rot): recovery drops exactly the damaged record and
     imports the survivors.

Every metric is structural (exact drop counts and pass/fail flags), so the
committed ``BENCH_recovery.json`` trajectory is gated exactly by
``scripts/bench_diff.py --recovery-fresh``.

    PYTHONPATH=src python benchmarks/recovery_bench.py --smoke
    PYTHONPATH=src python benchmarks/recovery_bench.py --json /tmp/r.json
    PYTHONPATH=src python benchmarks/recovery_bench.py --record pr9
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import signal
import subprocess
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.backends import get_backend  # noqa: E402
from repro.core import AdsalaRuntime, ModelRegistry  # noqa: E402
from repro.core.durable import MAGIC, TornWrite  # noqa: E402
from repro.serving import (BlasService, FaultPlan, FaultSpec,  # noqa: E402
                           ServeConfig)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_recovery.json"

#: shapes snapshotted before the kill vs journaled after it — recovery must
#: warm-start the union
SNAP_SHAPES = ((32, 32, 32), (48, 48, 48))
JOURNAL_SHAPES = ((64, 64, 64), (80, 80, 80))


class _CountingSub:
    """Fixed-knob model stand-in whose evaluations are observable — the
    zero-evals-after-recovery assertions hang off ``evals``."""

    def __init__(self, backend: str, knob, op: str = "gemm",
                 dtype_bytes: int = 4) -> None:
        self.backend, self.op, self.dtype_bytes = backend, op, dtype_bytes
        self.knob = knob
        self.artifact_version = 0
        self.evals = 0

    def select(self, dims):
        self.evals += 1
        return self.knob


def _knobs():
    """(model knob, quarantined knob) — both real cpu_blocked candidates,
    distinct so the quarantine never drops the cached decisions."""
    be = get_backend("cpu_blocked")
    default = be.default_knob("gemm")
    bad = next(c for c in be.knob_space("gemm").candidates if c != default)
    return default, bad


# ---------------------------------------------------------------------------
# child process: builds warm state, then dies mid-snapshot
# ---------------------------------------------------------------------------

def child_main(root: str) -> int:
    """Warm-state writer the parent SIGKILLs.  Protocol on stdout:
    ``JOURNALED`` once snapshot+journal are on disk, ``WRITING`` right
    before the held second snapshot (the kill window)."""
    default, bad = _knobs()
    rt = AdsalaRuntime()
    rt.register(_CountingSub("cpu_blocked", default))
    reg = ModelRegistry(root)
    rt.decision_journal = reg.journal_decision
    for d in SNAP_SHAPES:
        rt.select("gemm", d, 4, backend="cpu_blocked")
    reg.save_decision_cache(rt)            # snapshot absorbs SNAP_SHAPES
    for d in JOURNAL_SHAPES:               # journal-only increments
        rt.select("gemm", d, 4, backend="cpu_blocked")
    rt.quarantine_knob("gemm", 4, "cpu_blocked", bad, fallback=default,
                       ttl_s=60.0)         # journaled breaker
    print("JOURNALED", flush=True)
    # the second snapshot is held mid-write: the fault fires BEFORE the
    # temp file exists, so the kill lands with the old snapshot and the
    # journal both intact — the crash the durability contract is for
    plan = FaultPlan([FaultSpec(site="snapshot_write", exc=None,
                                latency_s=30.0, times=None)])
    reg2 = ModelRegistry(root, faults=plan)
    print("WRITING", flush=True)
    reg2.save_decision_cache(rt)
    return 3                               # only reached if the kill missed


# ---------------------------------------------------------------------------
# scenarios
# ---------------------------------------------------------------------------

def scenario_sigkill(futures_seen: list) -> dict:
    """SIGKILL a real child inside the snapshot write window; recover the
    snapshot+journal union with zero model evals and zero lost futures."""
    default, bad = _knobs()
    with tempfile.TemporaryDirectory() as td:
        proc = subprocess.Popen(
            [sys.executable, __file__, "--child", td],
            stdout=subprocess.PIPE, text=True)
        writing = False
        assert proc.stdout is not None
        for line in proc.stdout:
            if line.strip() == "WRITING":
                writing = True
                break
        time.sleep(0.3)                    # well inside the 30s hold
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=30)
        killed = writing and proc.returncode == -signal.SIGKILL

        rt = AdsalaRuntime()
        sub = _CountingSub("cpu_blocked", default)
        rt.register(sub)
        reg = ModelRegistry(td)
        imported = reg.load_decision_cache(rt)
        rec = dict(reg.last_recovery)

        shapes = SNAP_SHAPES + JOURNAL_SHAPES
        # the recovered cache serves real traffic: every shape warm
        cfg = ServeConfig(backend="cpu_blocked", max_batch=1, linger_ms=0.5,
                          workers=1, min_steal=1, exec_retries=0,
                          retry_backoff_s=0.0)
        be = get_backend("ref")
        reqs = [be.make_operands("gemm", d, np.float32, seed=i)
                for i, d in enumerate(shapes)]
        with BlasService(runtime=rt, config=cfg) as svc:
            futs = [svc.submit("gemm", r) for r in reqs]
            futures_seen.extend(futs)
            outs = [np.asarray(f.result(timeout=120), np.float64)
                    for f in futs]
        correct = all(
            np.max(np.abs(out - np.asarray(r[0] @ r[1], np.float64)))
            / (np.max(np.abs(np.asarray(r[0] @ r[1], np.float64))) + 1e-9)
            < 5e-4 for r, out in zip(reqs, outs))
        return {
            "sigkill_mid_write": bool(killed),
            "sigkill_recovered_decisions": bool(imported == len(shapes)),
            "sigkill_snapshot_records": int(rec.get("snapshot_records", -1)),
            "sigkill_journal_records": int(rec.get("journal_records", -1)),
            "sigkill_dropped_records": int(rec.get("dropped_records", -1)),
            "sigkill_quarantine_recovered": bool(
                rt.is_quarantined("gemm", 4, "cpu_blocked", bad)),
            "sigkill_zero_evals": bool(
                sub.evals == 0 and rt.stats.model_evals == 0),
            "sigkill_lost_futures": sum(not f.done() for f in futs),
            "sigkill_served_correct": bool(
                correct and svc.stats.failed == 0),
        }


def scenario_torn_journal() -> dict:
    """TornWrite truncates the FIRST journal append: recovery drops exactly
    that record, keeps its successor, and the writer counts the failure
    instead of raising into the decision path."""
    default, _bad = _knobs()
    with tempfile.TemporaryDirectory() as td:
        plan = FaultPlan([FaultSpec(site="journal_append",
                                    exc=TornWrite(0.5), times=1)])
        reg = ModelRegistry(td, faults=plan)
        rt = AdsalaRuntime()
        rt.register(_CountingSub("cpu_blocked", default))
        rt.decision_journal = reg.journal_decision
        rt.select("gemm", (32, 32, 32), 4, backend="cpu_blocked")  # torn
        rt.select("gemm", (64, 64, 64), 4, backend="cpu_blocked")  # clean

        warm = AdsalaRuntime()
        warm.register(_CountingSub("cpu_blocked", default))
        reg2 = ModelRegistry(td)
        imported = reg2.load_decision_cache(warm)
        rec = dict(reg2.last_recovery)
        survivor = [tuple(e["dims"]) for e in warm.export_cache()]
        return {
            "torn_journal_dropped": int(rec.get("dropped_records", -1)),
            "torn_journal_survivor_imported": bool(
                imported == 1 and survivor == [(64, 64, 64)]),
            "torn_journal_failure_counted": bool(
                rt.stats.journal_failures == 1),
            "torn_journal_injected": int(plan.fired("journal_append")),
        }


def scenario_garbage_snapshot() -> dict:
    """A non-JSON snapshot file degrades to a counted cold start while the
    intact journal still replays — never an exception."""
    default, _bad = _knobs()
    with tempfile.TemporaryDirectory() as td:
        reg = ModelRegistry(td)
        rt = AdsalaRuntime()
        rt.register(_CountingSub("cpu_blocked", default))
        rt.decision_journal = reg.journal_decision
        rt.select("gemm", (32, 32, 32), 4, backend="cpu_blocked")
        reg.save_decision_cache(rt)        # journal truncated here
        rt.select("gemm", (64, 64, 64), 4, backend="cpu_blocked")  # journal
        reg.decision_cache_path.write_bytes(b"garbage {{{ not json")

        warm = AdsalaRuntime()
        warm.register(_CountingSub("cpu_blocked", default))
        reg2 = ModelRegistry(td)
        try:
            imported = reg2.load_decision_cache(warm)
            raised = False
        except Exception:                  # noqa: BLE001 — contract breach
            imported, raised = -1, True
        rec = dict(reg2.last_recovery)
        return {
            "garbage_snapshot_cold_start": bool(
                not raised and rec.get("cold_start") is True),
            "garbage_snapshot_journal_replayed": bool(
                imported == 1 and [tuple(e["dims"])
                                   for e in warm.export_cache()]
                == [(64, 64, 64)]),
        }


def scenario_corrupt_snapshot_record() -> dict:
    """Bit rot in one checksummed snapshot record: recovery drops exactly
    the damaged record and imports the survivors."""
    default, _bad = _knobs()
    shapes = ((32, 32, 32), (48, 48, 48), (64, 64, 64))
    with tempfile.TemporaryDirectory() as td:
        reg = ModelRegistry(td)
        rt = AdsalaRuntime()
        rt.register(_CountingSub("cpu_blocked", default))
        for d in shapes:
            rt.select("gemm", d, 4, backend="cpu_blocked")
        path = reg.save_decision_cache(rt)
        lines = path.read_text().splitlines()
        assert lines[0] == MAGIC
        # lines[1] is the header record, lines[2] the oldest cache entry:
        # flip its checksum so exactly that record fails verification
        lines[2] = ("00000000" + lines[2][8:]) \
            if not lines[2].startswith("00000000") \
            else ("ffffffff" + lines[2][8:])
        path.write_text("\n".join(lines) + "\n")

        warm = AdsalaRuntime()
        warm.register(_CountingSub("cpu_blocked", default))
        reg2 = ModelRegistry(td)
        imported = reg2.load_decision_cache(warm)
        rec = dict(reg2.last_recovery)
        survivors = [tuple(e["dims"]) for e in warm.export_cache()]
        return {
            "corrupt_snapshot_dropped": int(rec.get("dropped_records", -1)),
            "corrupt_snapshot_survivors_imported": bool(
                imported == 2 and survivors == list(shapes[1:])),
        }


def run_scenarios() -> dict:
    futures_seen: list = []
    metrics: dict = {}
    metrics.update(scenario_sigkill(futures_seen))
    metrics.update(scenario_torn_journal())
    metrics.update(scenario_garbage_snapshot())
    metrics.update(scenario_corrupt_snapshot_record())
    metrics["hung_futures"] = sum(not f.done() for f in futures_seen)
    metrics["futures_submitted"] = len(futures_seen)
    return metrics


STRUCTURAL = (("sigkill_mid_write", True),
              ("sigkill_recovered_decisions", True),
              ("sigkill_dropped_records", 0),
              ("sigkill_quarantine_recovered", True),
              ("sigkill_zero_evals", True),
              ("sigkill_lost_futures", 0),
              ("sigkill_served_correct", True),
              ("torn_journal_dropped", 1),
              ("torn_journal_survivor_imported", True),
              ("torn_journal_failure_counted", True),
              ("garbage_snapshot_cold_start", True),
              ("garbage_snapshot_journal_replayed", True),
              ("corrupt_snapshot_dropped", 1),
              ("corrupt_snapshot_survivors_imported", True),
              ("hung_futures", 0))


def check(metrics: dict) -> list[str]:
    """Structural pass/fail list (empty = healthy)."""
    bad = [f"{k}={metrics[k]!r} (want {want!r})"
           for k, want in STRUCTURAL if metrics[k] != want]
    # the journal must really have carried the post-snapshot increments
    # (JOURNAL_SHAPES decisions + the quarantine record)
    want_journal = len(JOURNAL_SHAPES) + 1
    if metrics["sigkill_journal_records"] != want_journal:
        bad.append(f"sigkill_journal_records="
                   f"{metrics['sigkill_journal_records']} "
                   f"(want {want_journal})")
    if metrics["sigkill_snapshot_records"] != len(SNAP_SHAPES):
        bad.append(f"sigkill_snapshot_records="
                   f"{metrics['sigkill_snapshot_records']} "
                   f"(want {len(SNAP_SHAPES)})")
    return bad


def record_entry(entry_id: str, payload: dict, path: Path = BENCH_PATH):
    from common import record_trajectory_entry    # script-mode only module
    record_trajectory_entry(path, "recovery", entry_id, payload)
    print(f"[recovery_bench] recorded entry {entry_id!r} -> {path}")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--child", metavar="DIR", default=None,
                   help=argparse.SUPPRESS)   # internal: the killed writer
    p.add_argument("--smoke", action="store_true",
                   help="CI preset (the scenarios are already small; this "
                        "flag exists for harness symmetry)")
    p.add_argument("--json", type=Path, default=None,
                   help="write metrics JSON here (bench_diff "
                        "--recovery-fresh input)")
    p.add_argument("--record", default=None, metavar="ENTRY",
                   help="append/refresh this entry in the committed "
                        "BENCH_recovery.json trajectory")
    args = p.parse_args(argv)
    if args.child is not None:
        return child_main(args.child)

    metrics = run_scenarios()
    for k, v in metrics.items():
        print(f"  {k:>36}: {v}")
    bad = check(metrics)

    if args.json is not None:
        args.json.write_text(json.dumps(
            {"summary": metrics, "smoke_baseline": metrics}, indent=1))
        print(f"[recovery_bench] wrote {args.json}")
    if args.record is not None:
        record_entry(args.record, {
            "host": {"platform": platform.platform(),
                     "python": platform.python_version()},
            "config": {"snap_shapes": [list(d) for d in SNAP_SHAPES],
                       "journal_shapes": [list(d) for d in JOURNAL_SHAPES]},
            "smoke_baseline": metrics,
        })

    if bad:
        print(f"[recovery_bench] FAILED: {'; '.join(bad)}")
        return 1
    print(f"[recovery_bench] OK — SIGKILL mid-write recovered "
          f"{len(SNAP_SHAPES)} snapshot + {len(JOURNAL_SHAPES)} journal "
          f"decisions and the open quarantine with zero model evals; torn "
          f"journal and corrupt/garbage snapshots dropped exactly the "
          f"damaged records")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
