"""Paper Table VIII — runtime breakdown (kernel-call vs copy/overhead) of
selected high-speedup cases, default config vs ADSALA config.

The paper profiles MKL with VTune; here the black-box BLAS is the numpy
blocked implementation, so the decomposition is exact: per-block matmul time
(= the paper's "kernel call") vs everything else (block slicing, buffer
assembly, Python loop — the analogue of data copies + sync overhead)."""

from __future__ import annotations

import json
import time

import numpy as np

from .common import (ADSALA, csv_row, default_knob_from_dataset,
                     load_runtime)
from repro.kernels.cpu_blocked import make_operands


def _profiled_gemm(a, b, knob) -> dict:
    kd = knob.dict if hasattr(knob, "dict") else dict(knob)
    bm, bk, bn = kd["bm"], kd["bk"], kd["bn"]
    m, k = a.shape
    n = b.shape[1]
    out = np.empty((m, n), dtype=np.float32)
    t_kernel = 0.0
    t0 = time.perf_counter()
    for i0 in range(0, m, bm):
        i1 = min(i0 + bm, m)
        for j0 in range(0, n, bn):
            j1 = min(j0 + bn, n)
            acc = np.zeros((i1 - i0, j1 - j0), dtype=np.float32)
            for l0 in range(0, k, bk):
                l1 = min(l0 + bk, k)
                ablk = a[i0:i1, l0:l1]
                bblk = b[l0:l1, j0:j1]
                tk = time.perf_counter()
                acc += ablk @ bblk
                t_kernel += time.perf_counter() - tk
            out[i0:i1, j0:j1] = acc
    total = time.perf_counter() - t0
    return {"total_s": total, "kernel_s": t_kernel,
            "overhead_s": total - t_kernel}


CASES = [(64, 2048, 64), (256, 1024, 256), (96, 96, 2048)]


def run(quick: bool = False) -> list[str]:
    rt = load_runtime(backend="cpu_blocked")
    if rt is None:
        return [csv_row("table8.skipped", 0.0, "no-calibration-artifacts")]
    rows, out = [], {}
    default = default_knob_from_dataset("gemm", "s", backend="cpu_blocked")
    for dims in CASES if not quick else CASES[:1]:
        a, b = make_operands("gemm", dims, np.float32, seed=5)
        knob = rt.select("gemm", dims, dtype_bytes=4, backend="cpu_blocked")
        prof_def = _profiled_gemm(a, b, default)
        prof_ml = _profiled_gemm(a, b, knob)
        out[str(dims)] = {"default": {**prof_def, "knob": default.dict},
                          "adsala": {**prof_ml, "knob": knob.dict}}
        rows.append(csv_row(
            f"table8.sgemm.{'x'.join(map(str, dims))}",
            prof_ml["total_s"] * 1e6,
            f"default_total={prof_def['total_s']*1e3:.2f}ms;"
            f"ml_total={prof_ml['total_s']*1e3:.2f}ms;"
            f"ml_overhead={prof_ml['overhead_s']*1e3:.2f}ms"))
    (ADSALA / "table8_profiling.json").write_text(
        json.dumps(out, indent=2, default=float))
    return rows
