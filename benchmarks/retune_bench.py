#!/usr/bin/env python
"""Online-retune harness: synthetic drift injection → detect → refit → swap.

Builds a fully deterministic serving scenario around the drift feedback
loop (``repro.serving.retune``), with a synthetic cost model instead of
wall-clock kernels so every metric is reproducible bit-for-bit on any host:

  1. install a DecisionTree subroutine on a pre-drift cost surface where
     the largest block config (lowest grid parallelism ``nt``) is cheapest,
     registry-stamp it (artifact_version 1), serve a fixed dims pool, and
     persist the decision cache;
  2. feed telemetry that matches the predictor exactly — the loop must NOT
     trigger (no-false-trigger phase);
  3. inject drift: the chosen config's measured cost jumps 4x (the cost
     surface becomes non-monotone in ``nt`` — exactly the shape a linear
     family cannot express, which is why the refit family is a tree);
  4. one ``Retuner.step()`` must detect the drift, refit on the blended
     install+telemetry dataset, bump the artifact version through the
     registry, and hot-swap atomically;
  5. post-swap checks: zero stale-knob selections, decisions bit-identical
     to a fresh process loading the retuned artifact from the registry,
     the pre-swap decision cache rejected on version mismatch (and the
     post-swap cache accepted), and the p50 cost-recovery ratio of the new
     decisions over the old ones under the drifted surface.

Everything but the recovery ratio is a structural pass/fail (gated exactly
by ``scripts/bench_diff.py --retune-fresh``); the ratio itself is
deterministic too but gated with the standard tolerance so a re-recorded
cost surface does not need a lockstep gate update.

    PYTHONPATH=src python benchmarks/retune_bench.py --smoke
    PYTHONPATH=src python benchmarks/retune_bench.py --json /tmp/r.json
    PYTHONPATH=src python benchmarks/retune_bench.py --record pr7
"""

from __future__ import annotations

import argparse
import json
import math
import platform
import sys
import tempfile
import threading
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core import (AdsalaRuntime, ModelRegistry,  # noqa: E402
                        install_subroutine)
from repro.kernels import ops  # noqa: E402
from repro.serving import Retuner, RetuneConfig  # noqa: E402

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_retune.json"

#: per-(bm, bn) cost weight, pre-drift.  Monotone increasing in grid
#: parallelism nt (big blocks cheapest) — easy for any family to learn.
#: bk is deliberately absent: the Table-III features cannot see it, so a
#: bk-dependent surface would be unlearnable noise.
WEIGHTS = {(64, 64): 1.0, (64, 32): 2.0, (32, 64): 2.5, (32, 32): 3.0}

#: drift: the pre-drift optimum gets this much slower (co-tenant stealing
#: exactly the resource the big-block config leans on).  The surface is now
#: NON-monotone in nt — mid-parallelism wins.
DRIFT_KNOB = (64, 64)
DRIFT_MULT = 4.0

#: served traffic: fixed non-square (m, k, n) pool — non-square so the two
#: mid-parallelism configs have distinct nt and the tree can split them
POOL = [(96, 64, 160), (192, 96, 64), (64, 32, 128),
        (160, 64, 96), (128, 160, 64), (224, 32, 96)]


def cost(dims, knob, *, drifted: bool = False) -> float:
    """Synthetic per-call seconds: flops-proportional base x block weight."""
    m, k, n = dims
    w = WEIGHTS[(knob["bm"], knob["bn"])]
    if drifted and (knob["bm"], knob["bn"]) == DRIFT_KNOB:
        w *= DRIFT_MULT
    return 1e-4 * (m * k * n) / (64 ** 3) * w


def feed(rt: AdsalaRuntime, measured_fn, *, backend: str = "pallas",
         items: int = 2) -> None:
    """One serving tick: every pool bucket reports ``items`` executions at
    the cost ``measured_fn(dims, chosen_knob)`` — the same
    ``record_batch`` seam ``BlasService._execute`` feeds."""
    for dims in POOL:
        knob = rt.select("gemm", dims, 4, backend=backend)
        per_item = measured_fn(dims, knob)
        rt.record_batch("gemm", dims, 4, backend, 1,
                        exec_seconds=per_item * items, exec_items=items)


def run_scenario(*, n_samples: int = 24, seed: int = 0,
                 hammer_threads: int = 4) -> dict:
    """The full detect→refit→swap scenario; returns the metrics dict."""
    backend = "pallas"
    space = ops.knob_space_for("gemm", sizes=(32, 64))
    sub = install_subroutine(
        "gemm", space, lambda dims, knob: cost(dims, knob),
        n_samples=n_samples, dim_lo=32, dim_hi=256, max_footprint_bytes=None,
        tune_trials=2, candidates=("DecisionTree",), use_lof=False,
        seed=seed, backend=backend)

    with tempfile.TemporaryDirectory() as td:
        reg = ModelRegistry(td)
        reg.save(sub)                                    # artifact_version 1
        rt = AdsalaRuntime()
        rt.register(sub)
        cfg = RetuneConfig(min_samples=len(POOL), drift_threshold=0.5,
                           telemetry_repeat=4, tune_trials=1, seed=seed)
        ret = Retuner(rt, registry=reg, config=cfg)

        # serve the pool pre-drift; persist the v1-stamped decision cache
        old_knobs = {d: rt.select("gemm", d, 4, backend=backend)
                     for d in POOL}
        reg.save_decision_cache(rt)

        # phase A — telemetry that agrees with the predictor: no trigger
        cp = rt.predictor("gemm", 4, backend=backend)
        feed(rt, lambda d, k: float(cp.predict_times(d)[space.index(k)]))
        false_swaps = ret.step()
        ewma_calm, _n = ret.drift("gemm", 4, backend)
        no_false_trigger = not false_swaps and (ewma_calm or 0.0) < 1e-9

        # phase B — drift: the chosen config's measured cost jumps
        feed(rt, lambda d, k: cost(d, k, drifted=True))
        ret.observe()                  # ingest now: step() resets the state
        ewma_drift, _n = ret.drift("gemm", 4, backend)
        swapped = ret.step()
        drift_detected = ret.stats.drift_events >= 1
        retuned = ret.stats.retunes == 1 and swapped == [
            (backend, "gemm", 4)]
        new_sub = rt.subroutine("gemm", 4, backend=backend)

        # post-swap: what a NEW process would decide from the registry
        fresh_rt = AdsalaRuntime()
        loaded = [s for s in ModelRegistry(td).load_all(backend=backend)
                  if s.op == "gemm"]
        fresh_rt.register(loaded[0])
        expected = {d: fresh_rt.select("gemm", d, 4, backend=backend)
                    for d in POOL}

        # zero stale selections: hammer the live runtime from threads,
        # every answer must be the new artifact's decision
        stale = [0]
        stale_lock = threading.Lock()

        def hammer():
            bad = 0
            for _ in range(50):
                for d in POOL:
                    if rt.select("gemm", d, 4, backend=backend) \
                            != expected[d]:
                        bad += 1
            with stale_lock:
                stale[0] += bad

        threads = [threading.Thread(target=hammer)
                   for _ in range(hammer_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        # bit-identical: live post-swap predictions == fresh-process ones
        live_cp = rt.predictor("gemm", 4, backend=backend)
        fresh_cp = fresh_rt.predictor("gemm", 4, backend=backend)
        bit_identical = all(
            np.array_equal(live_cp.predict_times(d), fresh_cp.predict_times(d))
            for d in POOL) and stale[0] == 0

        # the pre-swap (v1) cache must be rejected against the v2 artifact;
        # the post-swap cache must round-trip
        v1_rt = AdsalaRuntime()
        v1_rt.register(loaded[0])
        imported_v1 = reg.load_decision_cache(v1_rt)
        drops = v1_rt.stats.import_drops_version
        reg.save_decision_cache(rt)
        v2_rt = AdsalaRuntime()
        v2_rt.register(loaded[0])
        imported_v2 = reg.load_decision_cache(v2_rt)
        version_mismatch_rejected = (imported_v1 == 0
                                     and drops == len(POOL)
                                     and imported_v2 == len(POOL))

        # p50 recovery: old vs new decisions under the drifted surface
        ratios = sorted(cost(d, old_knobs[d], drifted=True)
                        / cost(d, expected[d], drifted=True) for d in POOL)
        recovery_p50 = float(np.median(ratios))

        return {
            "drift_detected": bool(drift_detected),
            "no_false_trigger": bool(no_false_trigger),
            "retuned": bool(retuned),
            "post_swap_stale_selections": int(stale[0]),
            "swap_bit_identical": bool(bit_identical),
            "version_mismatch_rejected": bool(version_mismatch_rejected),
            "recovery_p50": recovery_p50,
            "drift_ewma": float(ewma_drift) if ewma_drift is not None
            else None,
            "calm_ewma": float(ewma_calm or 0.0),
            "invalidated": int(ret.stats.swap_invalidations),
            "artifact_version_after": int(new_sub.artifact_version),
            "retune_errors": int(ret.stats.errors),
            "last_error": ret.stats.last_error,
        }


STRUCTURAL = (("drift_detected", True), ("no_false_trigger", True),
              ("retuned", True), ("post_swap_stale_selections", 0),
              ("swap_bit_identical", True),
              ("version_mismatch_rejected", True), ("retune_errors", 0))


def check(metrics: dict) -> list[str]:
    """Structural pass/fail list (empty = healthy)."""
    bad = [f"{k}={metrics[k]!r} (want {want!r})"
           for k, want in STRUCTURAL if metrics[k] != want]
    if not (metrics["recovery_p50"] > 1.0):
        bad.append(f"recovery_p50={metrics['recovery_p50']:.2f} (want >1)")
    return bad


def record_entry(entry_id: str, payload: dict, path: Path = BENCH_PATH):
    from common import record_trajectory_entry    # script-mode only module
    record_trajectory_entry(path, "retune", entry_id, payload)
    print(f"[retune_bench] recorded entry {entry_id!r} -> {path}")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--samples", type=int, default=48,
                   help="install-sweep Halton samples")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--threads", type=int, default=4,
                   help="post-swap hammer threads")
    p.add_argument("--smoke", action="store_true",
                   help="small preset for CI (24 install samples)")
    p.add_argument("--json", type=Path, default=None,
                   help="write metrics JSON here (bench_diff --retune-fresh "
                        "input)")
    p.add_argument("--record", default=None, metavar="ENTRY",
                   help="append/refresh this entry in the committed "
                        "BENCH_retune.json trajectory")
    args = p.parse_args(argv)
    if args.smoke:
        args.samples = 24

    metrics = run_scenario(n_samples=args.samples, seed=args.seed,
                           hammer_threads=args.threads)
    for k, v in metrics.items():
        print(f"  {k:>28}: {v}")
    bad = check(metrics)

    if args.json is not None:
        args.json.write_text(json.dumps(
            {"summary": metrics, "smoke_baseline": metrics}, indent=1))
        print(f"[retune_bench] wrote {args.json}")
    if args.record is not None:
        record_entry(args.record, {
            "host": {"platform": platform.platform(),
                     "python": platform.python_version()},
            "config": {"samples": args.samples, "seed": args.seed,
                       "pool": [list(d) for d in POOL],
                       "drift_mult": DRIFT_MULT},
            "smoke_baseline": metrics,
        })

    if bad:
        print(f"[retune_bench] FAILED: {'; '.join(bad)}")
        return 1
    print(f"[retune_bench] OK — drift detected (EWMA "
          f"{metrics['drift_ewma'] or 0.0:.2f}), retuned to artifact v"
          f"{metrics['artifact_version_after']}, p50 recovery "
          f"{metrics['recovery_p50']:.2f}x, 0 stale selections")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
