"""Kernel micro-bench: Pallas BLAS L3 lowering sanity + analytic v5e oracle
timings per knob (the TPU-target tuning signal), plus wall-clock of the CPU
black-box BLAS at default vs tuned configs."""

from __future__ import annotations

import numpy as np

from repro.core import block_knob_space, oracle_time
from .common import csv_row


def run(quick: bool = False) -> list[str]:
    rows = []
    space = block_knob_space(bms=(128, 256, 512), bks=(128, 256, 512),
                             bns=(128, 256, 512))
    for op, dims in [("gemm", (4096, 4096, 4096)),
                     ("syrk", (4096, 1024)),
                     ("trsm", (2048, 2048))]:
        times = np.array([oracle_time(op, dims, k, dtype_bytes=2)
                          for k in space])
        best = int(np.argmin(times))
        worst = int(np.argmax(times))
        rows.append(csv_row(
            f"kernel.oracle.{op}", float(times[best] * 1e6),
            f"best={space.candidates[best].dict};"
            f"range={times[worst]/times[best]:.2f}x"))
    return rows
