#!/usr/bin/env python
"""Zero-copy kernel-execution bench: padded-vs-masked and
full-vs-tri-vs-tri_packed, recorded into ``BENCH_kernels.json``.

The zero-copy contract (PR 5) has two halves:

  * **masked edge tiles** — ⌈dim/block⌉ grids over the unpadded operands
    with in-kernel ragged-tail masking, so the old pad-to-block-multiple
    operand copies and the result slice-back are gone.  Witnessed
    *structurally*: ``host_copy_ops`` counts pad/slice primitives in the
    traced dispatch path (must be zero), and ``pad_bytes_eliminated`` is
    the analytic size of the operand copies the old path allocated at the
    same shapes.
  * **packed triangular grids** — ``tri_packed`` launches exactly the
    n(n+1)/2 live lower-triangle blocks (plus the write-only in-kernel
    mirror step for the rank-k updates) instead of a full n² grid.
    Witnessed by the *actual traced grids* (``grids``) and the
    ``packed_slot_ratio`` (full slots / packed slots).

Structural metrics are deterministic — the bench_diff gate on them is
immune to timing jitter.  Interpret-mode wall-clock ratios are recorded as
informational context only (on a CPU host they measure the Pallas
interpreter, not hardware; grid-cell counts still show through).

``--smoke`` (CI) additionally asserts masked == padded numerics bit-for-bit
across ragged shapes in interpret mode before emitting the metrics JSON:

    PYTHONPATH=src python benchmarks/kernel_bench.py --smoke --json /tmp/k.json
    PYTHONPATH=src python benchmarks/kernel_bench.py --record pr5
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_kernels.json"

#: block edge used for all structural metrics (the MXU-aligned minimum)
BLOCK = 128

#: ragged shapes for the masked-vs-padded contract (a ragged last tile
#: behind full tiles, so every mask actually fires)
RAGGED = {"gemm": (129, 65, 257), "symm": (129, 257), "syrk": (129, 65),
          "syr2k": (129, 65), "trmm": (129, 257), "trsm": (129, 257)}

#: larger dims for the grid-slot accounting (structural: tracing only,
#: nothing is executed)
SLOT_DIMS = {"syrk": (2048, 2048), "syr2k": (2048, 2048),
             "trmm": (2048, 1024)}

TRI_OPS = ("syrk", "syr2k", "trmm")
DIRECT_OPS = ("gemm", "symm", "syrk", "syr2k", "trmm")


def _rup(v: int, b: int = BLOCK) -> int:
    return ((v + b - 1) // b) * b


def _operands(op, dims, seed=0):
    import jax.numpy as jnp
    from repro.kernels.cpu_blocked import make_operands
    return tuple(jnp.asarray(x)
                 for x in make_operands(op, dims, np.float32, seed=seed))


def _knob(variant="full"):
    from repro.core.knobs import Knob
    return Knob(tuple(sorted({"bm": BLOCK, "bk": BLOCK, "bn": BLOCK,
                              "variant": variant}.items())))


# ---------------------------------------------------------------------------
# the frozen padded reference path (what ops.py did before PR 5) lives in
# repro.kernels.padded_ref — ONE copy shared with the unit-test contract
# ---------------------------------------------------------------------------

def padded_run(op, operands, *, variant="full", interpret=True):
    from repro.kernels.padded_ref import padded_run as frozen
    return frozen(op, operands, variant=variant, block=BLOCK,
                  interpret=interpret)


def masked_run(op, operands, *, variant="full", interpret=True):
    from repro.kernels import ops
    return ops.PALLAS_OPS[op](*operands, knob=_knob(variant),
                              interpret=interpret)


# ---------------------------------------------------------------------------
# structural metrics (deterministic — these are what bench_diff gates)
# ---------------------------------------------------------------------------

def structural_metrics() -> dict:
    from repro.kernels import ops
    from repro.kernels.introspect import (copy_op_counts, full_grid_for,
                                          grid_slots, packed_grid_for,
                                          pallas_grids)
    host_copy, pad_bytes, grids, slot_ratio = {}, {}, {}, {}
    for op in DIRECT_OPS:
        dims = RAGGED[op]
        operands = _operands(op, dims)
        counts = copy_op_counts(ops.PALLAS_OPS[op], *operands,
                                knob=_knob(), interpret=True)
        host_copy[op] = int(sum(counts.values()))
        # operand copies the padded path allocated at these shapes
        padded = sum(4 * _rup(x.shape[0]) * _rup(x.shape[1])
                     for x in operands)
        raw = sum(4 * x.shape[0] * x.shape[1] for x in operands)
        pad_bytes[op] = int(padded - raw)
    # trsm's substitution loop legitimately slices A block rows; its
    # zero-copy claim is "no pad" (the old identity-padded diagonal is gone)
    trsm_counts = copy_op_counts(ops.PALLAS_OPS["trsm"],
                                 *_operands("trsm", RAGGED["trsm"]),
                                 knob=_knob(), interpret=True)
    host_copy["trsm_pad"] = int(trsm_counts.get("pad", 0))

    for op in TRI_OPS:
        dims = SLOT_DIMS[op]
        operands = _operands(op, dims)
        per_variant = {}
        for variant in ("full", "tri", "tri_packed"):
            gs = pallas_grids(ops.PALLAS_OPS[op], *operands,
                              knob=_knob(variant), interpret=True)
            if len(gs) != 1:      # explicit raise: this backs a CI gate,
                raise SystemExit(  # so it must survive python -O
                    f"{op}:{variant} traced {len(gs)} pallas_calls: {gs}")
            per_variant[variant] = list(gs[0])
        want_full = full_grid_for(op, dims, BLOCK, BLOCK, BLOCK)
        want_packed = packed_grid_for(op, dims, BLOCK, BLOCK, BLOCK)
        if tuple(per_variant["full"]) != want_full or \
                tuple(per_variant["tri_packed"]) != want_packed:
            raise SystemExit(f"{op}: unexpected grids {per_variant} "
                             f"(want full={want_full}, "
                             f"tri_packed={want_packed})")
        grids[op] = {"dims": list(dims), **per_variant}
        slot_ratio[op] = round(
            grid_slots(tuple(per_variant["full"])) /
            grid_slots(tuple(per_variant["tri_packed"])), 3)
    return {"host_copy_ops": host_copy, "pad_bytes_eliminated": pad_bytes,
            "grids": grids, "packed_slot_ratio": slot_ratio}


# ---------------------------------------------------------------------------
# interpret-mode wall clock (informational only — never gated)
# ---------------------------------------------------------------------------

def _median_wall(fn, repeats=3):
    np.asarray(fn())                         # compile/warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        np.asarray(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def timing_metrics(quick=False) -> dict:
    out = {}
    for op in ("gemm", "syrk", "trmm"):
        dims = RAGGED[op]
        operands = _operands(op, dims)
        masked = _median_wall(lambda: masked_run(op, operands))
        padded = _median_wall(lambda: padded_run(op, operands))
        out[op] = {"dims": list(dims), "masked_ms": round(masked * 1e3, 2),
                   "padded_ms": round(padded * 1e3, 2),
                   "padded_over_masked": round(padded / masked, 3)}
    n = 512 if quick else 1024
    for op in TRI_OPS:
        dims = (n, 256)
        operands = _operands(op, dims)
        row = {"dims": list(dims)}
        for variant in ("full", "tri", "tri_packed"):
            w = _median_wall(
                lambda v=variant: masked_run(op, operands, variant=v))
            row[f"{variant}_ms"] = round(w * 1e3, 2)
        row["full_over_packed"] = round(row["full_ms"] /
                                        row["tri_packed_ms"], 3)
        out[f"{op}_variants"] = row
    return out


# ---------------------------------------------------------------------------
# smoke gate (CI): masked == padded numerics, then the structural metrics
# ---------------------------------------------------------------------------

def smoke_check() -> None:
    from repro.backends.conformance import RAGGED_DIMS
    for oi, op in enumerate(DIRECT_OPS + ("trsm",)):
        for di, dims in enumerate(RAGGED_DIMS[op][:2]):
            # deterministic seed (str hash is PYTHONHASHSEED-salted — the
            # CI gate must run on the same data every process)
            operands = _operands(op, dims, seed=100 * oi + di)
            for variant in (("full", "tri", "tri_packed")
                            if op in TRI_OPS else ("full",)):
                got = np.asarray(masked_run(op, operands, variant=variant))
                want = np.asarray(padded_run(op, operands, variant=variant))
                if op == "trsm":
                    # the ragged diagonal is now solved at its true size;
                    # low solve bits differ from the identity-padded block
                    ok = np.allclose(got, want, rtol=1e-5, atol=1e-5)
                else:
                    ok = np.array_equal(got, want)
                state = "ok" if ok else "MISMATCH"
                print(f"[kernel_bench] masked==padded {op}:{variant} "
                      f"dims={dims}: {state}")
                if not ok:
                    raise SystemExit(
                        f"masked/padded mismatch: {op} {variant} {dims}")


def build_payload(quick=False, smoke=False) -> dict:
    structural = structural_metrics()
    payload = {
        "host": {"platform": platform.platform(),
                 "python": platform.python_version()},
        "config": {"block": BLOCK,
                   "ragged": {k: list(v) for k, v in RAGGED.items()},
                   "slot_dims": {k: list(v) for k, v in SLOT_DIMS.items()}},
        **structural,
        # what bench_diff gates: exact-zero copies + slot-saving ratios
        "smoke_baseline": {
            "host_copy_ops": structural["host_copy_ops"],
            "packed_slot_ratio": structural["packed_slot_ratio"]},
    }
    if not smoke:
        payload["interpret_wall"] = timing_metrics(quick=quick)
    return payload


def record_entry(entry_id: str, payload: dict, path: Path = BENCH_PATH):
    try:                                 # package mode (benchmarks.run)
        from .common import record_trajectory_entry
    except ImportError:                  # script mode (benchmarks/ on path)
        from common import record_trajectory_entry
    record_trajectory_entry(path, "kernels", entry_id, payload)
    print(f"[kernel_bench] recorded entry {entry_id!r} -> {path}")


# ---------------------------------------------------------------------------
# legacy harness hook (benchmarks.run): analytic v5e oracle rows
# ---------------------------------------------------------------------------

def run(quick: bool = False) -> list[str]:
    from repro.core import block_knob_space, oracle_time
    from .common import csv_row
    rows = []
    space = block_knob_space(bms=(128, 256, 512), bks=(128, 256, 512),
                             bns=(128, 256, 512))
    for op, dims in [("gemm", (4096, 4096, 4096)),
                     ("syrk", (4096, 1024)),
                     ("trsm", (2048, 2048))]:
        times = np.array([oracle_time(op, dims, k, dtype_bytes=2)
                          for k in space])
        best = int(np.argmin(times))
        worst = int(np.argmax(times))
        rows.append(csv_row(
            f"kernel.oracle.{op}", float(times[best] * 1e6),
            f"best={space.candidates[best].dict};"
            f"range={times[worst]/times[best]:.2f}x"))
    return rows


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="CI mode: assert masked==padded numerics, emit "
                        "structural metrics only (no wall-clock)")
    p.add_argument("--quick", action="store_true",
                   help="smaller shapes for the wall-clock section")
    p.add_argument("--json", type=Path, default=None,
                   help="write metrics JSON here (bench_diff --kernels-fresh)")
    p.add_argument("--record", default=None, metavar="ENTRY",
                   help="append/replace this per-PR entry in "
                        "BENCH_kernels.json")
    args = p.parse_args(argv)

    if args.smoke:
        smoke_check()
    payload = build_payload(quick=args.quick, smoke=args.smoke)
    for op, ratio in payload["packed_slot_ratio"].items():
        g = payload["grids"][op]
        print(f"[kernel_bench] {op}: full grid {tuple(g['full'])} -> "
              f"tri_packed {tuple(g['tri_packed'])} "
              f"({ratio:.2f}x fewer slots)")
    print(f"[kernel_bench] host copy ops on the masked path: "
          f"{payload['host_copy_ops']}")
    if args.json is not None:
        args.json.write_text(json.dumps(payload, indent=1))
        print(f"[kernel_bench] metrics -> {args.json}")
    if args.record is not None:
        record_entry(args.record, payload)
    return 0


if __name__ == "__main__":
    sys.exit(main())
