"""§Roofline — the three-term roofline per (arch × shape × mesh) cell,
aggregated from the dry-run artifacts (runs/dryrun/*.json)."""

from __future__ import annotations

import json
from pathlib import Path

from .common import RUNS, csv_row


def run(quick: bool = False) -> list[str]:
    d = RUNS / "dryrun"
    if not d.exists():
        return [csv_row("roofline.skipped", 0.0, "no-dryrun-artifacts")]
    rows = []
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok" or rec.get("tag"):
            continue
        r = rec["roofline"]
        dominant_s = max(r["t_compute"], r["t_memory"], r["t_collective"])
        rows.append(csv_row(
            f"roofline.{rec['arch']}.{rec['shape']}.{rec['mesh']}",
            dominant_s * 1e6,
            f"bottleneck={r['bottleneck']};"
            f"comp_ms={r['t_compute']*1e3:.1f};"
            f"mem_ms={r['t_memory']*1e3:.1f};"
            f"coll_ms={r['t_collective']*1e3:.1f};"
            f"useful={r['useful_ratio']:.2f};"
            f"peakGB={rec['memory']['peak_bytes']/1e9:.1f}"))
    return rows
