"""Paper Tables IV/V (best model per subroutine) and VI (per-model detail:
normalised RMSE, ideal/estimated speedups, evaluation time) — read from the
calibration report produced at install time."""

from __future__ import annotations

import json

from .common import ADSALA, csv_row


def run(quick: bool = False) -> list[str]:
    path = ADSALA / "calibration_report.json"
    if not path.exists():
        return [csv_row("table46.skipped", 0.0, "no-calibration-report")]
    report = json.loads(path.read_text())
    rows = []
    for entry in report:
        sub = f"{entry['prec']}{entry['op']}"
        best = entry["best_model"]
        # Table VI detail: eval time + estimated speedup per candidate
        for m in entry["models"]:
            rows.append(csv_row(
                f"table6.{sub}.{m['name']}", m["eval_time_us"],
                f"nrmse={m['normalized_rmse']:.2f};"
                f"ideal={m['ideal_mean_speedup']:.2f};"
                f"est={m['estimated_mean_speedup']:.2f}"))
        rows.append(csv_row(f"table45.{sub}", 0.0, f"best={best}"))
    return rows
