#!/usr/bin/env python
"""Model-serving bench for ADSALA-dispatched GEMMs → ``BENCH_model.json``.

Three claims about routing a transformer's dense matmuls through
``run_op`` / :class:`~repro.core.runtime.AdsalaRuntime` (PR 6), each
measured per PR and gated by ``scripts/bench_diff.py --model-fresh``:

  * **bit-identical routing** — with every contraction dim inside one
    k-tile (≤ 128), the routed forward / prefill / decode_step of a dense,
    a MoE and an MLA smoke config equal the plain ``x @ w`` path
    *bitwise* (single-k-tile f32 accumulation is exact; the MoE expert
    stack executes as one batched grid).  Deterministic — gated exactly.
  * **zero cold evals after prewarm** — harvest → install → select_many →
    ``save_decision_cache`` offline, then a fresh runtime hydrated from
    the registry serves prefill + decode with **0** runtime model
    evaluations (the same keys cost >0 evals without the cache).
    Deterministic — gated exactly.
  * **tuned ≥ default knobs** — jitted prefill tokens/s and per-step
    decode latency under oracle-installed knobs vs the default
    max-parallelism knob at serving-scale dims.  Wall-clock →
    informational (advisory on low-core hosts), recorded for trajectory.

    PYTHONPATH=src python benchmarks/model_bench.py --smoke --json /tmp/m.json
    PYTHONPATH=src python benchmarks/model_bench.py --record pr6
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import platform
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_model.json"

#: one arch per routed-model family; d_ff clamped so EVERY contraction dim
#: (d_model, d_ff, moe_d_ff, kv_lora, n_heads·v_head_dim) fits a single
#: 128-wide k-tile — the bitwise-equality regime (k-splitting regroups the
#: f32 accumulation)
PARITY_ARCHS = ("qwen1.5-4b", "granite-moe-3b-a800m", "deepseek-v2-lite-16b")


def _parity_cfg(arch):
    from repro.configs import get_smoke_config
    return dataclasses.replace(get_smoke_config(arch),
                               compute_dtype="float32",
                               capacity_factor=8.0, d_ff=128)


def _batch_for(cfg, B, S, seed=0):
    import jax
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed),
                                          (B, S), 0, cfg.vocab)}
    if cfg.vision_tokens:
        batch["vision"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.vision_tokens, 32))
    return batch


# ---------------------------------------------------------------------------
# claim 1: routed == unrouted, bitwise (deterministic; gated)
# ---------------------------------------------------------------------------

def parity_metrics() -> dict:
    import jax
    import jax.numpy as jnp
    from repro.core.runtime import AdsalaRuntime
    from repro.models import transformer as tf

    B, S = 2, 16
    per_arch = {}
    for arch in PARITY_ARCHS:
        cfg = _parity_cfg(arch)
        rcfg = dataclasses.replace(cfg, use_pallas_gemm=True)
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        batch = _batch_for(cfg, B, S)
        rt = AdsalaRuntime()

        ref, _ = tf.forward(params, batch, cfg)
        out, _ = tf.forward(params, batch, rcfg, runtime=rt)
        row = {"forward": bool(jnp.array_equal(ref, out))}

        lu, cu = tf.prefill(params, batch, tf.init_decode_state(cfg, B, S + 4),
                            cfg)
        lr, cr = tf.prefill(params, batch,
                            tf.init_decode_state(rcfg, B, S + 4), rcfg,
                            runtime=rt)
        row["prefill"] = bool(jnp.array_equal(lu, lr))
        tok = jnp.argmax(lu[:, -1:], -1).astype(jnp.int32)
        du, _ = tf.decode_step(params, tok, cu, cfg)
        dr, _ = tf.decode_step(params, tok, cr, rcfg, runtime=rt)
        row["decode"] = bool(jnp.array_equal(du, dr))
        per_arch[arch] = row
        print(f"[model_bench] parity {arch}: {row}")
    all_ok = all(v for row in per_arch.values() for v in row.values())
    return {"per_arch": per_arch, "routed_bit_identical": all_ok}


# ---------------------------------------------------------------------------
# claim 2: zero runtime model evals after offline prewarm (deterministic)
# ---------------------------------------------------------------------------

def prewarm_metrics() -> dict:
    import jax
    import jax.numpy as jnp
    from repro.backends import resolve_backend
    from repro.core.oracle import oracle_time
    from repro.core.registry import ModelRegistry
    from repro.core.runtime import AdsalaRuntime
    from repro.core.tuner import install_subroutine
    from repro.models import transformer as tf
    from repro.roofline.costing import prune_dominated_candidates
    from repro.roofline.harvest import harvest_decision_keys

    B, S = 2, 16
    cfg = _parity_cfg(PARITY_ARCHS[0])
    rcfg = dataclasses.replace(cfg, use_pallas_gemm=True)
    backend = resolve_backend(rcfg.gemm_backend)

    keys = harvest_decision_keys(rcfg, batch_size=B, seq_len=S,
                                 programs=("prefill", "decode"))
    dims_list = [k[3] for k in keys]
    db = keys[0][2]
    space = prune_dominated_candidates(
        "gemm", backend.knob_space("gemm", sizes=(128, 256)), dims_list,
        dtype_bytes=db)

    with tempfile.TemporaryDirectory() as tmp:
        registry = ModelRegistry(tmp)
        install_rt = AdsalaRuntime()
        sub = install_subroutine(
            "gemm", space,
            lambda dims, knob: oracle_time("gemm", dims, knob,
                                           dtype_bytes=db),
            n_samples=40, dim_lo=16, dim_hi=256, dtype_bytes=db,
            backend=backend.name, tune_trials=2)
        registry.save(sub)
        install_rt.register(sub)
        install_rt.select_many([(op, dims, b, be)
                                for (be, op, b, dims) in keys],
                               record_hits=False)
        registry.save_decision_cache(install_rt)

        params = tf.init_params(jax.random.PRNGKey(0), rcfg)
        batch = _batch_for(rcfg, B, S)

        def serve(runtime) -> int:
            caches = tf.init_decode_state(rcfg, B, S + 4)
            logits, caches = tf.prefill(params, batch, caches, rcfg,
                                        runtime=runtime)
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
            tf.decode_step(params, tok, caches, rcfg, runtime=runtime)
            return int(runtime.stats.for_backend(backend.name).model_evals)

        cold = AdsalaRuntime()
        registry.load_into(cold, backend=backend.name)
        cold_evals = serve(cold)

        warm = AdsalaRuntime()
        registry.load_into(warm, backend=backend.name)
        cached = registry.load_decision_cache(warm)
        warm_evals = serve(warm)

    out = {"harvested_keys": len(keys), "knob_candidates": len(space),
           "cached_decisions": cached,
           "cold_model_evals": cold_evals,
           "prewarm_model_evals": warm_evals}
    print(f"[model_bench] prewarm: {out}")
    return out


# ---------------------------------------------------------------------------
# claim 3: tuned knobs vs default knobs, jitted serving loop (wall-clock)
# ---------------------------------------------------------------------------

def _median_wall(fn, repeats=3):
    fn()                                     # compile/warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def timing_metrics(quick=False) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.core.oracle import oracle_time
    from repro.core.runtime import AdsalaRuntime
    from repro.core.tuner import install_subroutine
    from repro.kernels.ops import knob_space_for
    from repro.models import transformer as tf
    from repro.roofline.harvest import harvest_decision_keys

    # serving-scale dims: > 128 so block choices genuinely differ (the
    # default max-parallelism knob runs many more grid cells than the
    # oracle's preferred large blocks)
    B, S = 1, 64 if quick else 128
    cfg = dataclasses.replace(_parity_cfg(PARITY_ARCHS[0]),
                              d_model=256, d_ff=512, n_heads=4,
                              kv_heads=4, n_layers=2,
                              use_pallas_gemm=True)

    tuned_rt = AdsalaRuntime()
    keys = harvest_decision_keys(cfg, batch_size=B, seq_len=S,
                                 programs=("prefill", "decode"))
    db = keys[0][2]
    sub = install_subroutine(
        "gemm", knob_space_for("gemm", sizes=(128, 256, 512)),
        lambda dims, knob: oracle_time("gemm", dims, knob, dtype_bytes=db),
        n_samples=40, dim_lo=16, dim_hi=1024, dtype_bytes=db,
        backend="pallas", tune_trials=2)
    tuned_rt.register(sub)
    default_rt = AdsalaRuntime()       # no artifacts → default knob path

    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg, B, S)
    ucfg = dataclasses.replace(cfg, use_pallas_gemm=False)

    def bench_pair(c, rt):
        pre = jax.jit(lambda p, b, ch: tf.prefill(p, b, ch, c, runtime=rt))
        dec = jax.jit(lambda p, t, ch: tf.decode_step(p, t, ch, c,
                                                      runtime=rt))
        caches0 = tf.init_decode_state(c, B, S + 8)
        logits, caches = pre(params, batch, caches0)
        jax.block_until_ready(logits)
        pre_s = _median_wall(
            lambda: jax.block_until_ready(pre(params, batch, caches0)[0]))
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        dec_s = _median_wall(
            lambda: jax.block_until_ready(dec(params, tok, caches)[0]),
            repeats=5)
        return pre_s, dec_s

    rows = {}
    for name, c, rt in (("default_knobs", cfg, default_rt),
                        ("tuned_knobs", cfg, tuned_rt),
                        ("unrouted", ucfg, None)):
        pre_s, dec_s = bench_pair(c, rt)
        rows[name] = {"prefill_tokens_per_s": round(B * S / pre_s, 1),
                      "decode_ms_per_step": round(dec_s * 1e3, 2)}
        print(f"[model_bench] {name}: {rows[name]}")
    speed = {
        "prefill": round(rows["tuned_knobs"]["prefill_tokens_per_s"] /
                         rows["default_knobs"]["prefill_tokens_per_s"], 3),
        "decode": round(rows["default_knobs"]["decode_ms_per_step"] /
                        max(rows["tuned_knobs"]["decode_ms_per_step"], 1e-9),
                        3)}
    print(f"[model_bench] tuned_over_default: {speed}")
    return {"dims": {"batch": B, "seq": S, "d_model": cfg.d_model,
                     "d_ff": cfg.d_ff, "n_layers": cfg.n_layers},
            "paths": rows, "tuned_over_default": speed,
            "low_core": (os.cpu_count() or 1) < 3}


# ---------------------------------------------------------------------------

def build_payload(quick=False, smoke=False) -> dict:
    parity = parity_metrics()
    prewarm = prewarm_metrics()
    payload = {
        "host": {"platform": platform.platform(),
                 "python": platform.python_version(),
                 "cpus": os.cpu_count()},
        "parity": parity,
        "prewarm": prewarm,
        # what bench_diff gates (deterministic: exact bools/counts)
        "smoke_baseline": {
            "routed_bit_identical": parity["routed_bit_identical"],
            "prewarm_model_evals": prewarm["prewarm_model_evals"],
            "cold_model_evals": prewarm["cold_model_evals"],
            "harvested_keys": prewarm["harvested_keys"]},
    }
    if not smoke:
        payload["serving_wall"] = timing_metrics(quick=quick)
    return payload


def record_entry(entry_id: str, payload: dict, path: Path = BENCH_PATH):
    try:                                 # package mode (benchmarks.run)
        from .common import record_trajectory_entry
    except ImportError:                  # script mode (benchmarks/ on path)
        from common import record_trajectory_entry
    record_trajectory_entry(path, "model", entry_id, payload)
    print(f"[model_bench] recorded entry {entry_id!r} -> {path}")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="CI mode: parity + prewarm only (deterministic), "
                        "no wall-clock section")
    p.add_argument("--quick", action="store_true",
                   help="shorter prefill for the wall-clock section")
    p.add_argument("--json", type=Path, default=None,
                   help="write metrics JSON here (bench_diff --model-fresh)")
    p.add_argument("--record", default=None, metavar="ENTRY",
                   help="append/replace this per-PR entry in "
                        "BENCH_model.json")
    args = p.parse_args(argv)

    payload = build_payload(quick=args.quick, smoke=args.smoke)
    base = payload["smoke_baseline"]
    if not base["routed_bit_identical"]:
        raise SystemExit("[model_bench] routed forward is NOT bit-identical")
    if base["prewarm_model_evals"] != 0:
        raise SystemExit(f"[model_bench] prewarmed serving paid "
                         f"{base['prewarm_model_evals']} model evals")
    if args.json is not None:
        args.json.write_text(json.dumps(payload, indent=1))
        print(f"[model_bench] metrics -> {args.json}")
    if args.record is not None:
        record_entry(args.record, payload)
    return 0


if __name__ == "__main__":
    sys.exit(main())
