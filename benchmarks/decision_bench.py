#!/usr/bin/env python
"""Decision-engine latency harness: the runtime knob-decision path.

The paper's selection metric ``s = t_orig / (t_ADSALA + t_eval)`` charges
every microsecond of decision latency against the speedup of every uncached
BLAS call, so this bench tracks the three latencies that matter and pins
them against frozen copies of the superseded implementations:

  cold   one uncached knob decision per model family — reference path
         (np.tile + pipeline object + Python parallelism loop) vs the
         compiled fast path, plus the dominated-candidate pruned variant
         where the artifact allows it.  Each family is ALSO measured
         through a frozen copy of the PR-3 lowering (brute-force KNN
         distance matrix, per-level ArrayTree loop, where-predicated
         stacked forest) so the v2 engine's per-family trajectory
         (``speedup_vs_pr3``) is a same-host, same-run comparison;
  hit    one cached decision through the full per-call path run_op takes
         (default-knob resolution + select_or_default) vs the frozen PR-2
         runtime, and the raw runtime.select hit;
  batch  select_many over B distinct uncached keys vs B individual selects.

Every number is the median of ``--runs`` runs.  Results are appended as a
per-PR entry (``--entry-id``) to ``BENCH_decision.json`` at the repo root —
the perf-trajectory file ``scripts/bench_diff.py`` gates CI against.
``--smoke`` runs a tiny configuration, asserts fast/reference argmin parity
and sanity (fast <= reference), and skips the JSON write unless ``--json``
asks for the dimensionless smoke metrics (the CI regression gate input).

    PYTHONPATH=src python benchmarks/decision_bench.py
    PYTHONPATH=src python benchmarks/decision_bench.py --smoke
    PYTHONPATH=src python benchmarks/decision_bench.py --smoke --json /tmp/s.json
"""

from __future__ import annotations

import argparse
import collections
import json
import platform
import statistics
import sys
import threading
import time
from pathlib import Path

# decision latencies are sub-GIL-quantum: long switch intervals turn any
# cross-thread handoff into multi-ms stalls (serving-bench lesson)
sys.setswitchinterval(5e-4)

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core import AdsalaRuntime, install_subroutine  # noqa: E402
from repro.core.fastpath import compile_predictor  # noqa: E402
from repro.core.ml import PAPER_CANDIDATES  # noqa: E402
from repro.core.runtime import RuntimeStats  # noqa: E402
from repro.kernels import ops  # noqa: E402

REPO_ROOT = Path(__file__).resolve().parents[1]
OUT_PATH = REPO_ROOT / "BENCH_decision.json"

_LEAF = -1


# ---------------------------------------------------------------------------
# frozen pre-PR reference implementations
# ---------------------------------------------------------------------------

class LegacyRuntime:
    """Frozen copy of the PR-2 ``AdsalaRuntime.select``/``select_or_default``
    hot path: RLock held across stats + OrderedDict hit bookkeeping, and a
    second lock round trip in select_or_default."""

    def __init__(self, cache_size: int = 256) -> None:
        self._subs = {}
        self._cache = collections.OrderedDict()
        self._cache_size = cache_size
        self._lock = threading.RLock()
        self.stats = RuntimeStats()

    def register(self, sub, backend: str) -> None:
        self._subs[(backend, sub.op, sub.dtype_bytes)] = sub

    def select(self, op, dims, dtype_bytes=4, backend="pallas"):
        key = (backend, op, dtype_bytes, tuple(int(d) for d in dims))
        with self._lock:
            self.stats.calls += 1
            bstats = self.stats.for_backend(backend)
            bstats.calls += 1
            hit = self._cache.get(key)
            if hit is not None:
                self.stats.cache_hits += 1
                bstats.cache_hits += 1
                self._cache.move_to_end(key)
                return hit
            sub = self._subs[(backend, op, dtype_bytes)]
        t0 = time.perf_counter()
        knob = sub.select(key[3])
        dt = time.perf_counter() - t0
        with self._lock:
            self.stats.model_evals += 1
            self.stats.eval_seconds += dt
            self._cache[key] = knob
            self._cache.move_to_end(key)
            while len(self._cache) > self._cache_size:
                self._cache.popitem(last=False)
        return knob

    def select_or_default(self, op, dims, dtype_bytes, default, *,
                          backend="pallas"):
        with self._lock:
            if (backend, op, dtype_bytes) not in self._subs:
                self.stats.calls += 1
                self.stats.default_calls += 1
                return default
        return self.select(op, dims, dtype_bytes, backend=backend)


def legacy_default_knob(op: str):
    """Pre-PR ``ops.default_knob``: parallelism argmax over the whole knob
    space recomputed per call (now behind functools.lru_cache)."""
    return ops.default_knob.__wrapped__(op)


# -- frozen PR-3 model lowerings (the fast path this PR replaces) -----------

class _Pr3StackedForest:
    """Frozen PR-3 ensemble fold: where-predicated level loop with the
    all-leaves early-exit scan."""

    def __init__(self, trees) -> None:
        offsets = np.cumsum([0] + [t.feature.size for t in trees[:-1]])
        self.roots = offsets.astype(np.int64)
        self.feature = np.concatenate([t.feature for t in trees])
        self.threshold = np.concatenate([t.threshold for t in trees])
        self.left = np.concatenate(
            [t.left + o for t, o in zip(trees, offsets)])
        self.right = np.concatenate(
            [t.right + o for t, o in zip(trees, offsets)])
        self.value = np.concatenate([t.value for t in trees])
        self.depth = max(t.depth for t in trees)

    def descend(self, X):
        N = X.shape[0]
        node = np.repeat(self.roots[:, None], N, axis=1)
        rows = np.arange(N)[None, :]
        for _ in range(self.depth + 1):
            f = self.feature[node]
            is_split = f != _LEAF
            if not is_split.any():
                break
            fx = X[rows, np.maximum(f, 0)]
            go_left = fx <= self.threshold[node]
            nxt = np.where(go_left, self.left[node], self.right[node])
            node = np.where(is_split, nxt, node)
        return self.value[node]


def pr3_predict_fn(model):
    """The predict the PR-3 compiled engine served for ``model`` (frozen:
    timing baseline only, current code never runs this)."""
    name = getattr(model, "NAME", None)
    if name == "KNN":
        def knn_brute(X):        # full distance matrix + argpartition
            X = np.asarray(X, dtype=np.float64)
            k = min(model.k, model.X_.shape[0])
            d2 = ((X[:, None, :] - model.X_[None, :, :]) ** 2).sum(-1)
            nn = np.argpartition(d2, k - 1, axis=1)[:, :k]
            ny = model.y_[nn]
            if model.weights == "distance":
                nd = np.sqrt(np.take_along_axis(d2, nn, axis=1))
                w = 1.0 / np.maximum(nd, 1e-12)
                return (w * ny).sum(1) / w.sum(1)
            return ny.mean(1)
        return knn_brute
    single = getattr(model, "tree_", None)
    if single is not None and name in ("DecisionTree", "DistilledTree"):
        return single.predict    # PR-3 served single trees unfolded
    trees = getattr(model, "trees_", None)
    if not trees:
        return model.predict     # linear family: unchanged since PR-3
    forest = _Pr3StackedForest(list(trees))
    if name == "RandomForest":
        return lambda Z: np.mean(forest.descend(Z), axis=0)
    if name == "XGBoost":
        base, lr = float(model.base_), float(model.learning_rate)

        def xgb(Z):
            P = forest.descend(Z)
            out = np.full(Z.shape[0], base)
            for i in range(P.shape[0]):
                out += lr * P[i]
            return out
        return xgb
    if name == "AdaBoost":
        logw = np.log(1.0 / np.maximum(model.betas_, 1e-300))
        half = 0.5 * logw.sum()

        def ada(Z):
            preds = np.ascontiguousarray(forest.descend(Z).T)
            order = np.argsort(preds, axis=1)
            sp = np.take_along_axis(preds, order, axis=1)
            cum = np.cumsum(logw[order], axis=1)
            pick = (cum >= half).argmax(axis=1)
            return sp[np.arange(preds.shape[0]), pick]
        return ada
    return model.predict


def pr3_compiled(sub):
    """A CompiledPredictor downgraded to the PR-3 lowering: identical
    feature build + fused transform, frozen predict, no duplicate-row
    fold, no raw-threshold folding — isolates exactly what this PR
    changed, on this host."""
    cp = compile_predictor(sub)
    cp._predict = pr3_predict_fn(sub.model)
    cp._dedup = False
    cp._skip_transform = False     # PR-3 transformed on every decision
    return cp


# ---------------------------------------------------------------------------
# measurement helpers
# ---------------------------------------------------------------------------

def _time_us(fn, inner: int) -> float:
    fn()                                  # warmup
    t0 = time.perf_counter()
    for _ in range(inner):
        fn()
    return (time.perf_counter() - t0) / inner * 1e6


def median_us(fn, *, runs: int, inner: int) -> float:
    return statistics.median(_time_us(fn, inner) for _ in range(runs))


def _install(op: str, family: str, *, sizes, n_samples: int):
    space = ops.knob_space_for(op, sizes=sizes)

    def timer(dims, knob):
        # compute term + per-grid-cell launch overhead + block-size cost:
        # the argmin knob shifts with dims, so tuned models have non-trivial
        # live candidate sets
        d = knob.dict
        par = space.parallelism(knob, dims)
        work = float(np.prod(np.asarray(dims, dtype=np.float64)))
        return 1e-9 * work / par + 3e-6 * par \
            + 1e-8 * (d.get("bm", 1) + d.get("bn", 1))

    return install_subroutine(
        op, space, timer, n_samples=n_samples, dim_lo=32, dim_hi=1024,
        max_footprint_bytes=64_000_000, candidates=(family,), tune_trials=1,
        use_lof=False, backend="bench")


# ---------------------------------------------------------------------------
# the three benches
# ---------------------------------------------------------------------------

def bench_cold(families, *, sizes, n_samples, runs, inner,
               dims=(512, 384, 640)):
    """Per model family: reference vs fast (vs fast+prune, vs the frozen
    PR-3 lowering) uncached eval."""
    out = {}
    for family in families:
        sub = _install("gemm", family, sizes=sizes, n_samples=n_samples)
        cp = sub.compiled()
        cp3 = pr3_compiled(sub)
        # families whose lowering this PR did not touch (linear einsum)
        # run byte-for-byte the same code as PR-3: report the identity
        # instead of timing the same instructions twice and calling the
        # host jitter a trajectory
        unchanged = (cp3._predict == cp._predict
                     and not cp._skip_transform and not cp._dedup)
        # interleave the timed loops so host-speed drift hits all three
        # paths alike (ratios stay fair even when the box is jittery)
        ref_r, fast_r, pr3_r = [], [], []
        for _ in range(runs):
            ref_r.append(_time_us(lambda: sub.select(dims),
                                  max(inner // 8, 10)))
            fast_r.append(_time_us(lambda: cp.select(dims), inner))
            if not unchanged:
                pr3_r.append(_time_us(lambda: cp3.select(dims),
                                      max(inner // 4, 10)))
        ref = statistics.median(ref_r)
        fast = statistics.median(fast_r)
        pr3 = fast if unchanged else statistics.median(pr3_r)
        row = {"reference_us": round(ref, 2), "fast_us": round(fast, 2),
               "fast_pr3_us": round(pr3, 2),
               "speedup": round(ref / fast, 2),
               "speedup_vs_pr3": round(pr3 / fast, 2),
               "lowering": cp.lowering, "K": len(sub.knob_space)}
        pruned = sub.compiled(prune=True)
        if pruned is not None and pruned._live is not None:
            mid = tuple(int((a + b) // 2) for a, b in
                        zip(sub.fast_dims_lo, sub.fast_dims_hi))
            row["fast_pruned_us"] = round(median_us(
                lambda: pruned.select(mid), runs=runs, inner=inner), 2)
            row["live_K"] = int(sub.fast_live_idx.size)
        if sub.fast_band_idx is not None:
            row["band_K"] = int(sub.fast_band_idx.size)
        # parity gate: the fast path must agree with the reference argmin
        rng = np.random.default_rng(3)
        for _ in range(25):
            d = tuple(int(v) for v in rng.integers(16, 2048, size=3))
            assert cp.select(d) == sub.select(d), (family, d)
        out[family] = row
    return out


def bench_hit(sub, *, runs, inner):
    """Cached-decision latency: pre-PR vs current, raw select and the full
    per-call path (default-knob resolution + select_or_default)."""
    dims = (512, 384, 640)
    legacy = LegacyRuntime()
    legacy.register(sub, "bench")
    legacy.select("gemm", dims, 4, backend="bench")
    rt = AdsalaRuntime()
    rt.register(sub, backend="bench")
    rt.select("gemm", dims, 4, backend="bench")

    raw_old = median_us(lambda: legacy.select("gemm", dims, 4,
                                              backend="bench"),
                        runs=runs, inner=inner)
    raw_new = median_us(lambda: rt.select("gemm", dims, 4, backend="bench"),
                        runs=runs, inner=inner)
    # the path run_op actually takes per call on a cache hit
    path_old = median_us(
        lambda: legacy.select_or_default("gemm", dims, 4,
                                         legacy_default_knob("gemm"),
                                         backend="bench"),
        runs=runs, inner=max(inner // 10, 50))
    path_new = median_us(
        lambda: rt.select_or_default("gemm", dims, 4,
                                     ops.default_knob("gemm"),
                                     backend="bench"),
        runs=runs, inner=inner)
    return {
        "select_pre_pr_us": round(raw_old, 3),
        "select_us": round(raw_new, 3),
        "select_speedup": round(raw_old / raw_new, 2),
        "call_path_pre_pr_us": round(path_old, 3),
        "call_path_us": round(path_new, 3),
        "call_path_speedup": round(path_old / path_new, 2),
    }


def bench_batch(sub, *, runs, batch=64):
    """select_many over B distinct cold keys vs B individual selects."""
    rng = np.random.default_rng(5)
    dims_list = [tuple(int(v) for v in rng.integers(64, 1024, size=3))
                 for _ in range(batch)]
    rt = AdsalaRuntime(cache_size=4)     # tiny cache: every round is cold
    rt.register(sub, backend="bench")
    reqs = [("gemm", d, 4, "bench") for d in dims_list]

    def many():
        rt.clear_cache()
        rt.select_many(reqs)

    def loop():
        rt.clear_cache()
        for d in dims_list:
            rt.select("gemm", d, 4, backend="bench")

    t_many = median_us(many, runs=runs, inner=5)
    t_loop = median_us(loop, runs=runs, inner=5)
    # equivalence gate
    rt.clear_cache()
    got = rt.select_many(reqs)
    want = [sub.select(d) for d in dims_list]
    assert got == want, "select_many decisions diverge from select"
    return {
        "batch": batch,
        "select_many_us": round(t_many, 1),
        "n_selects_us": round(t_loop, 1),
        "speedup": round(t_loop / t_many, 2),
        "select_many_keys_per_s": round(batch / t_many * 1e6),
        "n_selects_keys_per_s": round(batch / t_loop * 1e6),
    }


def run_suite(families, *, sizes, n_samples, runs, inner, cold_inner):
    """One full measurement pass; returns (cold, hit, batch, summary)."""
    cold = bench_cold(families, sizes=sizes, n_samples=n_samples,
                      runs=runs, inner=cold_inner)
    hit_sub = _install("gemm", "LinearRegression", sizes=sizes,
                       n_samples=n_samples)
    hit = bench_hit(hit_sub, runs=runs, inner=inner)
    batch = bench_batch(hit_sub, runs=runs)
    cold_speedups = [row["speedup"] for row in cold.values()]
    summary = {
        "cold_median_speedup": round(statistics.median(cold_speedups), 2),
        "cold_min_speedup": round(min(cold_speedups), 2),
        "cold_median_speedup_vs_pr3": round(statistics.median(
            [r["speedup_vs_pr3"] for r in cold.values()]), 2),
        "hit_call_path_speedup": hit["call_path_speedup"],
        "batch_speedup": batch["speedup"],
    }
    for fam, key in (("KNN", "knn_speedup_vs_pr3"),
                     ("DecisionTree", "dtree_speedup_vs_pr3")):
        if fam in cold:
            summary[key] = cold[fam]["speedup_vs_pr3"]
    return cold, hit, batch, summary


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--runs", type=int, default=3,
                   help="median-of-N runs per number")
    p.add_argument("--inner", type=int, default=2000,
                   help="timed iterations per run (hit path)")
    p.add_argument("--cold-inner", type=int, default=300,
                   help="timed iterations per run (cold path)")
    p.add_argument("--families", nargs="*",
                   default=list(PAPER_CANDIDATES) + ["DistilledTree"],
                   help="model families to bench cold")
    p.add_argument("--smoke", action="store_true",
                   help="tiny config, parity + sanity asserts, no JSON")
    p.add_argument("--json", type=Path, default=None,
                   help="with --smoke: write the smoke metrics JSON here "
                        "(the bench_diff CI gate input)")
    p.add_argument("--entry-id", default="pr4",
                   help="entry key in the BENCH_decision.json trajectory")
    p.add_argument("--out", type=Path, default=OUT_PATH)
    args = p.parse_args(argv)

    if args.smoke:
        args.families = ["LinearRegression", "DecisionTree", "KNN"]
        sizes, n_samples = (32, 64), 10
        args.inner, args.cold_inner, args.runs = 200, 30, 3
    else:
        sizes, n_samples = (128, 256, 512), 60

    print(f"[decision_bench] cold eval: {len(args.families)} families "
          f"(K={len(ops.knob_space_for('gemm', sizes=sizes))}, "
          f"median of {args.runs})")
    cold, hit, batch, summary = run_suite(
        args.families, sizes=sizes, n_samples=n_samples, runs=args.runs,
        inner=args.inner, cold_inner=args.cold_inner)
    for fam, row in cold.items():
        extra = (f"  pruned {row['fast_pruned_us']}us (live K="
                 f"{row['live_K']})" if "fast_pruned_us" in row else "")
        print(f"  {fam:>18}: ref {row['reference_us']:>8.1f}us  "
              f"fast {row['fast_us']:>7.2f}us  {row['speedup']:>5.1f}x  "
              f"pr3 {row['fast_pr3_us']:>8.1f}us "
              f"({row['speedup_vs_pr3']:.1f}x vs pr3)" + extra)
    print(f"[decision_bench] cache hit: raw select "
          f"{hit['select_pre_pr_us']}us -> {hit['select_us']}us "
          f"({hit['select_speedup']}x); full call path "
          f"{hit['call_path_pre_pr_us']}us -> {hit['call_path_us']}us "
          f"({hit['call_path_speedup']}x)")
    print(f"[decision_bench] batched: {batch['batch']} keys "
          f"{batch['n_selects_us']}us -> {batch['select_many_us']}us "
          f"({batch['speedup']}x, "
          f"{batch['select_many_keys_per_s']} keys/s)")
    print(f"[decision_bench] summary: {summary}")

    if args.smoke:
        assert summary["cold_median_speedup"] > 1.0, \
            "fast path slower than reference"
        assert summary["hit_call_path_speedup"] > 1.0, \
            "hit path slower than pre-PR"
        if args.json is not None:
            args.json.write_text(json.dumps(
                {"bench": "decision-smoke", "summary": summary,
                 "cold_model_eval": cold}, indent=1) + "\n")
            print(f"[decision_bench] wrote smoke metrics {args.json}")
        print("[decision_bench] smoke OK (parity + latency sanity)")
        return 0

    entry = {
        "host": {"platform": platform.platform(),
                 "python": platform.python_version(),
                 "numpy": np.__version__},
        "config": {"runs": args.runs, "inner": args.inner,
                   "cold_inner": args.cold_inner, "knob_sizes": list(sizes),
                   "n_samples": n_samples},
        "cold_model_eval": cold,
        "cache_hit": hit,
        "batched_selection": batch,
        "summary": summary,
    }
    # dimensionless smoke metrics for the CI regression gate
    print("[decision_bench] smoke baseline for bench_diff ...")
    s_cold, s_hit, s_batch, s_summary = run_suite(
        ["LinearRegression", "DecisionTree", "KNN"], sizes=(32, 64),
        n_samples=10, runs=3, inner=200, cold_inner=30)
    entry["smoke_baseline"] = {
        "summary": s_summary,
        "cold_speedups": {f: r["speedup"] for f, r in s_cold.items()},
    }

    payload = {"bench": "decision", "entries": {}}
    if args.out.exists():
        prior = json.loads(args.out.read_text())
        if "entries" in prior:
            payload["entries"] = prior["entries"]
        else:                    # migrate the single-entry PR-3 layout
            prior.pop("bench", None)
            payload["entries"]["pr3"] = prior
    payload["entries"][args.entry_id] = entry
    args.out.write_text(json.dumps(payload, indent=1) + "\n")
    print(f"[decision_bench] wrote {args.out} (entry {args.entry_id!r})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
