"""Benchmark harness — one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV rows:
  table7.*    — paper Table VII: measured ADSALA speedups per op × precision
  table45.*   — paper Tables IV/V: selected best model per subroutine
  table6.*    — paper Table VI: per-model RMSE / eval-time / est. speedup
  fig45.*     — paper Figs 4/5: optimal-config heatmap data + headroom
  table8.*    — paper Table VIII: kernel vs overhead runtime decomposition
  roofline.*  — §Roofline: three-term roofline per (arch × shape × mesh)
  kernel.*    — TPU-target kernel tuning signal (analytic v5e oracle)
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true")
    p.add_argument("--only", default="")
    args = p.parse_args(argv)

    from . import (fig45_heatmaps, kernel_bench, roofline_table,
                   table7_speedup, table46_model_selection, table8_profiling)
    benches = [
        ("table46", table46_model_selection.run),
        ("fig45", fig45_heatmaps.run),
        ("table8", table8_profiling.run),
        ("kernel", kernel_bench.run),
        ("roofline", roofline_table.run),
        ("table7", table7_speedup.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            for row in fn(quick=args.quick):
                print(row)
        except Exception as e:   # noqa: BLE001
            failures += 1
            print(f"{name}.ERROR,0.0,{type(e).__name__}:{e}")
        print(f"{name}.wall,{(time.perf_counter()-t0)*1e6:.0f},elapsed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
