#!/usr/bin/env python
"""Serving-layer load harness: batched (shape-bucketed) vs per-call dispatch.

Generates Zipf-distributed traffic over a pool of repeated GEMM-family
shapes — the serving regime the bucketing scheduler targets — and drives it
through two paths:

  unbatched  every request is its own ``run_op`` call on a bounded thread
             pool (the PR-1 dispatch path);
  batched    requests go through :class:`repro.serving.BlasService`, which
             stacks same-shape requests and executes each bucket as one
             stacked ``run_op`` call.

Arrivals are open-loop: the generator follows a Poisson schedule at
``--rate`` req/s independent of completion (rate 0 = saturation: submit as
fast as possible, which is the throughput-comparison mode).  Reports p50/p99
latency, throughput, mean batch size, and the batched/unbatched speedup.

With ``--warm-start`` the harness also mini-installs a tuned model set,
serves the traffic cold (counting ML model evaluations), persists the
decision cache, then re-serves the same shapes on a fresh warm-started
runtime and asserts it performed ZERO model evaluations.

    PYTHONPATH=src python benchmarks/serve_bench.py --quick
    PYTHONPATH=src python benchmarks/serve_bench.py --requests 2000 \
        --max-batch 32 --rate 0 --backend ref
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import tempfile
import threading
import time

import os

# the harness is dispatch-bound across threads; the GIL switch interval
# shapes how long the submit loop and the execution threads can hold the
# interpreter — tune via env to study the tradeoff (seconds)
sys.setswitchinterval(float(os.environ.get("SERVE_BENCH_SWITCH", "2e-3")))
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core import AdsalaRuntime, ModelRegistry, install_backend  # noqa: E402
from repro.kernels.cpu_blocked import make_operands  # noqa: E402
from repro.kernels.ops import run_op  # noqa: E402
from repro.serving import BlasService, ServeConfig  # noqa: E402


def make_shape_pool(op: str, n_shapes: int, lo: int, hi: int,
                    seed: int) -> list[tuple[int, ...]]:
    """Distinct dims tuples for ``op``; ranks 0..n-1 order the Zipf law."""
    rng = np.random.default_rng(seed)
    ndims = 3 if op == "gemm" else 2
    pool: list[tuple[int, ...]] = []
    seen = set()
    while len(pool) < n_shapes:
        dims = tuple(int(rng.integers(lo // 16, hi // 16 + 1)) * 16
                     for _ in range(ndims))
        if dims not in seen:
            seen.add(dims)
            pool.append(dims)
    return pool


def zipf_schedule(pool_size: int, n_requests: int, a: float,
                  seed: int) -> np.ndarray:
    """Request → shape-rank assignment, p(rank r) ∝ 1/(r+1)^a."""
    p = 1.0 / np.arange(1, pool_size + 1) ** a
    p /= p.sum()
    rng = np.random.default_rng(seed + 1)
    return rng.choice(pool_size, size=n_requests, p=p)


def arrival_times(n: int, rate: float, seed: int) -> np.ndarray:
    """Open-loop Poisson arrival offsets (seconds); zeros when saturating."""
    if rate <= 0:
        return np.zeros(n)
    rng = np.random.default_rng(seed + 2)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def percentiles(lat: list[float]) -> tuple[float, float]:
    arr = np.asarray(lat)
    return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))


def build_traffic(op: str, args) -> list[tuple]:
    pool = make_shape_pool(op, args.shapes, args.dim_lo, args.dim_hi,
                           args.seed)
    ranks = zipf_schedule(len(pool), args.requests, args.zipf_a, args.seed)
    # one operand set per distinct shape — traffic repeats payloads, which
    # is fine: the harness measures dispatch, not arithmetic variety
    payload = {dims: make_operands(op, dims, np.float32,
                                   seed=hash(dims) % (2 ** 31))
               for dims in pool}
    return [(op, pool[r], payload[pool[r]]) for r in ranks]


def warm_jax(traffic, backend: str, runtime, max_batch: int) -> None:
    """Execute each distinct shape once per canonical stack width (the
    power-of-two widths the service pads buckets to) so XLA compile time
    stays out of the measured window for BOTH paths."""
    widths = [1]
    while widths[-1] < max_batch:
        widths.append(min(widths[-1] * 2, max_batch))
    done = set()
    for op, dims, operands in traffic:
        if (op, dims) in done:
            continue
        done.add((op, dims))
        run_op(op, operands, backend=backend, runtime=runtime)
        for width in widths:
            stacked = tuple(np.stack([x] * width) for x in operands)
            run_op(op, stacked, backend=backend, runtime=runtime,
                   stacked=True)


def _drive(traffic, args, submit_one, wait_all):
    """Open-loop load generation: the generator follows the Poisson arrival
    schedule (``--rate`` req/s; 0 = no pacing, i.e. saturation) regardless
    of completions.  Latency = scheduled arrival → completion.  Returns
    (wall_s to last completion, per-request latencies)."""
    arrivals = arrival_times(len(traffic), args.rate, args.seed)
    done_at: list[float] = [0.0] * len(traffic)
    t0 = time.perf_counter()
    for i, (op, _dims, operands) in enumerate(traffic):
        now = time.perf_counter() - t0
        if arrivals[i] > now:
            time.sleep(arrivals[i] - now)
        submit_one(i, op, operands, done_at)
    wait_all()
    # Future.result() can return before the done-callback that stamps
    # done_at has run (set_result notifies waiters first) — wait the
    # stragglers out before reading the timeline
    while not all(done_at):
        time.sleep(0.001)
    wall = max(done_at) - t0
    lat = [done_at[i] - (t0 + arrivals[i]) for i in range(len(traffic))]
    return wall, lat


def bench_unbatched(traffic, args, runtime) -> dict:
    pool = ThreadPoolExecutor(max_workers=args.workers)
    pending = []

    def submit_one(i, op, operands, done_at):
        def one():
            run_op(op, operands, backend=args.backend, runtime=runtime)
            done_at[i] = time.perf_counter()
        pending.append(pool.submit(one))

    def wait_all():
        for f in pending:
            f.result()

    wall, lat = _drive(traffic, args, submit_one, wait_all)
    pool.shutdown()
    p50, p99 = percentiles(lat)
    return {"mode": "unbatched", "wall_s": wall,
            "throughput_rps": len(traffic) / wall,
            "p50_ms": p50 * 1e3, "p99_ms": p99 * 1e3, "mean_batch": 1.0}


def bench_batched(traffic, args, runtime, registry=None) -> dict:
    cfg = ServeConfig(backend=args.backend, max_batch=args.max_batch,
                      linger_ms=args.linger_ms, workers=args.workers,
                      max_pending=args.max_pending)
    svc = BlasService(runtime=runtime, config=cfg, registry=registry)
    futs = []

    def submit_one(i, op, operands, done_at):
        # done-callback fires in the worker at true completion time — the
        # unbatched path records at completion too, so p50/p99 compare fair
        f = svc.submit(op, operands)
        f.add_done_callback(
            lambda _f, i=i: done_at.__setitem__(i, time.perf_counter()))
        futs.append(f)

    def wait_all():
        for f in futs:
            f.result()

    wall, lat = _drive(traffic, args, submit_one, wait_all)
    stats = svc.stats
    svc.close()
    p50, p99 = percentiles(lat)
    return {"mode": "batched", "wall_s": wall,
            "throughput_rps": len(traffic) / wall,
            "p50_ms": p50 * 1e3, "p99_ms": p99 * 1e3,
            "mean_batch": stats.mean_batch, "max_batch": stats.max_batch,
            "batches": stats.batches}


def report(row: dict) -> None:
    extra = "".join(
        f"  {k.split('_')[0]}={row[k]:.1f}" for k in ("mean_batch",)
        if k in row)
    print(f"[serve_bench] {row['mode']:>9}: {row['throughput_rps']:8.1f} "
          f"req/s  p50={row['p50_ms']:7.2f} ms  p99={row['p99_ms']:7.2f} ms"
          f"{extra}")


def warm_start_check(args) -> bool:
    """Cold-serve, persist decision cache, warm-serve: assert 0 model evals."""
    from repro.backends import get_backend
    op = args.op
    print("[serve_bench] warm-start: mini-installing tuned "
          f"{args.backend}/{op} model ...")
    with tempfile.TemporaryDirectory() as td:
        registry = ModelRegistry(td)
        install_backend(get_backend(args.backend), ops=(op,),
                        n_samples=16, dim_lo=32, dim_hi=128,
                        max_footprint_bytes=1_000_000, tune_trials=1,
                        candidates=("LinearRegression", "DecisionTree"),
                        registry=registry, seed=args.seed)
        traffic = build_traffic(op, args)

        cold_rt = AdsalaRuntime()
        registry.load_into(cold_rt)
        with BlasService(runtime=cold_rt, registry=registry,
                         config=ServeConfig(backend=args.backend)) as svc:
            for op_, _dims, operands in traffic:
                svc.submit(op_, operands)
            svc.drain()
        cold_evals = cold_rt.stats.model_evals
        print(f"[serve_bench] cold run:  {cold_evals} model evaluations "
              f"({len(traffic)} requests)")

        warm_rt = AdsalaRuntime()
        registry.load_into(warm_rt)
        with BlasService(runtime=warm_rt, registry=registry,
                         config=ServeConfig(backend=args.backend)) as svc:
            print(f"[serve_bench] warm run:  imported "
                  f"{svc.warm_started} cached decisions")
            for op_, _dims, operands in traffic:
                svc.submit(op_, operands)
            svc.drain()
        warm_evals = warm_rt.stats.model_evals
        print(f"[serve_bench] warm run:  {warm_evals} model evaluations")
        ok = cold_evals > 0 and warm_evals == 0
        print(f"[serve_bench] warm-start: "
              f"{'ok' if ok else 'FAILED (expected cold>0, warm==0)'}")
        return ok


BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_serving.json"


def record_entry(entry_id: str, payload: dict, path: Path = BENCH_PATH):
    """Append/replace the per-PR entry in the committed serving trajectory
    (same shape as BENCH_decision.json)."""
    from common import record_trajectory_entry    # script-mode only module
    record_trajectory_entry(path, "serving", entry_id, payload)
    print(f"[serve_bench] recorded entry {entry_id!r} -> {path}")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--op", default="gemm", choices=(
        "gemm", "symm", "syrk", "syr2k", "trmm", "trsm"))
    p.add_argument("--backend", default="ref",
                   help="execution backend (default ref: the always-"
                        "available jnp path; pallas interpret-mode is slow)")
    p.add_argument("--requests", type=int, default=800)
    p.add_argument("--shapes", type=int, default=8,
                   help="distinct shapes in the Zipf pool")
    p.add_argument("--zipf-a", type=float, default=1.5)
    p.add_argument("--rate", type=float, default=0.0,
                   help="open-loop arrival rate req/s (0 = saturation)")
    p.add_argument("--dim-lo", type=int, default=32)
    p.add_argument("--dim-hi", type=int, default=128)
    p.add_argument("--max-batch", type=int, default=32)
    p.add_argument("--linger-ms", type=float, default=10.0)
    p.add_argument("--workers", type=int, default=None,
                   help="execution threads per mode; default: pinned to 1 "
                        "on low-core hosts (see --low-core-threshold), "
                        "else 2 — unpinned worker counts made batched-vs-"
                        "unbatched ratios GIL-flaky on 2-CPU CI hosts")
    p.add_argument("--low-core-threshold", type=int, default=3,
                   help="hosts with fewer cores than this get the low-core "
                        "guard: workers pinned to 1 and --min-speedup "
                        "demoted to a warning (unless --strict)")
    p.add_argument("--strict", action="store_true",
                   help="enforce --min-speedup even under the low-core "
                        "guard")
    p.add_argument("--max-pending", type=int, default=4096)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--repeats", type=int, default=3,
                   help="measurement repeats per mode; the median-throughput "
                        "run is reported (thread-scheduling phase effects "
                        "make single runs noisy on small hosts)")
    p.add_argument("--quick", action="store_true",
                   help="small preset for CI smoke (200 requests)")
    p.add_argument("--warm-start", action="store_true",
                   help="also run the decision-cache warm-start check")
    p.add_argument("--min-speedup", type=float, default=None,
                   help="exit nonzero unless batched/unbatched >= this")
    p.add_argument("--json", type=Path, default=None,
                   help="write the run's summary metrics to this file "
                        "(consumed by scripts/bench_diff.py --serving-fresh)")
    p.add_argument("--record", default=None, metavar="ENTRY",
                   help="append/replace this per-PR entry (e.g. pr5) in the "
                        "committed BENCH_serving.json trajectory")
    args = p.parse_args(argv)
    low_core = (os.cpu_count() or 1) < args.low_core_threshold
    if args.workers is None:
        args.workers = 1 if low_core else 2
        print(f"[serve_bench] workers pinned to {args.workers} "
              f"({os.cpu_count()} cpus{', low-core host' if low_core else ''})")
    if args.quick:
        args.requests = min(args.requests, 400)
        args.shapes = min(args.shapes, 6)

    traffic = build_traffic(args.op, args)
    print(f"[serve_bench] {args.requests} {args.op} requests over "
          f"{args.shapes} Zipf(a={args.zipf_a}) shapes, backend="
          f"{args.backend}, rate="
          f"{'saturation' if args.rate <= 0 else f'{args.rate}/s'}")
    runtime = AdsalaRuntime()
    warm_jax(traffic, args.backend, runtime, args.max_batch)

    def median_run(fn):
        rows = [fn(traffic, args, AdsalaRuntime())
                for _ in range(max(1, args.repeats))]
        rows.sort(key=lambda r: r["throughput_rps"])
        return rows[len(rows) // 2]

    un = median_run(bench_unbatched)
    report(un)
    ba = median_run(bench_batched)
    report(ba)
    speedup = ba["throughput_rps"] / max(un["throughput_rps"], 1e-9)
    print(f"[serve_bench] batched/unbatched throughput: {speedup:.2f}x "
          f"(mean batch {ba['mean_batch']:.1f}, "
          f"median of {max(1, args.repeats)})")

    summary = {
        "batched_speedup": round(speedup, 3),
        "mean_batch": round(ba["mean_batch"], 2),
        "batched_rps": round(ba["throughput_rps"], 1),
        "unbatched_rps": round(un["throughput_rps"], 1),
        "batched_p99_ms": round(ba["p99_ms"], 3),
        "unbatched_p99_ms": round(un["p99_ms"], 3),
        "cpus": os.cpu_count(),
        "low_core": low_core,
    }
    if args.json is not None:
        args.json.write_text(json.dumps({"summary": summary}, indent=1))
        print(f"[serve_bench] summary metrics -> {args.json}")
    if args.record is not None:
        record_entry(args.record, {
            "host": {"platform": platform.platform(),
                     "python": platform.python_version(),
                     "cpus": os.cpu_count()},
            "config": {"op": args.op, "backend": args.backend,
                       "requests": args.requests, "shapes": args.shapes,
                       "zipf_a": args.zipf_a, "max_batch": args.max_batch,
                       "linger_ms": args.linger_ms, "workers": args.workers,
                       "repeats": args.repeats},
            "unbatched": un, "batched": ba,
            # the dimensionless ratios bench_diff gates (both sides of each
            # ratio measured in the same run on the same host)
            "smoke_baseline": summary,
        })

    ok = True
    if args.warm_start:
        ok = warm_start_check(args) and ok
    if args.min_speedup is not None and speedup < args.min_speedup:
        if low_core and not args.strict:
            # GIL jitter on <=2-core hosts makes the ratio unreliable;
            # correctness gates (warm start, futures) still enforce above
            print(f"[serve_bench] WARNING: speedup {speedup:.2f}x < "
                  f"{args.min_speedup}x — low-core host, advisory only")
        else:
            print(f"[serve_bench] FAILED: speedup {speedup:.2f}x < "
                  f"{args.min_speedup}x")
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
