"""Paper Figures 4/5 — heatmap of the optimal execution config over the
dimension space, per subroutine × precision (CSV: dims → argmin-measured
knob and its grid parallelism = the nt analogue)."""

from __future__ import annotations

import json

import numpy as np

from .common import ADSALA, OPS, csv_row


def run(quick: bool = False) -> list[str]:
    ds_dir = ADSALA / "datasets"
    if not ds_dir.exists():
        return [csv_row("fig45.skipped", 0.0, "no-datasets")]
    rows = []
    out = {}
    for op in OPS if not quick else ("gemm",):
        for prec in ("s", "d"):
            f = ds_dir / f"{op}_{prec}.npz"
            if not f.exists():
                continue
            d = np.load(f)
            dims, times = d["dims"], d["times"]
            knobs = json.loads(str(d["knobs"]))
            best = times.argmin(axis=1)
            # how often does the default (max-parallelism) config win? —
            # the paper's core observation is that it usually does NOT.
            default_idx = int(d["default_idx"])
            default_wins = float(np.mean(best == default_idx))
            cells = [{"dims": dims[i].tolist(),
                      "best_knob": knobs[int(best[i])],
                      "best_ms": float(times[i, best[i]] * 1e3),
                      "default_ms": float(times[i, default_idx] * 1e3)}
                     for i in range(len(dims))]
            out[f"{prec}{op}"] = cells
            headroom = float(np.mean(times[:, default_idx] /
                                     times.min(axis=1)))
            rows.append(csv_row(
                f"fig45.{prec}{op}", float(times.min(axis=1).mean() * 1e6),
                f"default_wins={default_wins:.2f};"
                f"headroom={headroom:.2f}x"))
    (ADSALA / "fig45_heatmaps.json").write_text(
        json.dumps(out, indent=1, default=float))
    return rows
