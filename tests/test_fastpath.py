"""Compiled decision fast path: argmin/bit parity against the reference
path (all six ops x both dtypes x every persisted model family), lock-free
hit-path concurrency (stats stay exact), and select_many equivalence with N
individual selects."""

import random
import threading

import numpy as np
import pytest

from repro.core import AdsalaRuntime, ModelRegistry, install_subroutine
from repro.core.fastpath import CompiledPredictor, compile_predictor
from repro.core.knobs import Knob, thread_knob_space
from repro.kernels import ops


class StubSub:
    """Uncompilable TunedSubroutine stand-in (no pipeline/model): the
    runtime must fall back to its reference ``select``."""

    def __init__(self, backend: str, op: str = "gemm",
                 dtype_bytes: int = 4) -> None:
        self.backend = backend
        self.op = op
        self.dtype_bytes = dtype_bytes
        self.knob = Knob((("bm", 128), ("bn", 128)))
        self.evals = 0

    def select(self, dims):
        self.evals += 1
        return self.knob

#: model families present in the repo's persisted artifact store
PERSISTED_FAMILIES = ("LinearRegression", "DecisionTree")

OPS = ("gemm", "symm", "syrk", "syr2k", "trmm", "trsm")

ARTIFACTS = ModelRegistry("runs/adsala/models")


def _dims_sweep(op: str, n_random: int = 12, seed: int = 7):
    nd = 3 if op == "gemm" else 2
    fixed = [(16,) * nd, (64,) * nd, (512,) * nd, (2048,) * nd,
             (33, 257, 1023)[:nd], (1024, 48, 640)[:nd]]
    rng = np.random.default_rng(seed)
    rand = [tuple(int(v) for v in rng.integers(8, 2048, size=nd))
            for _ in range(n_random)]
    return fixed + rand


def _timer(space):
    """Structured synthetic timer: dims- and knob-dependent (compute +
    per-grid-cell launch overhead + block cost), so fitted models produce a
    dims-dependent argmin structure — including exact prediction ties for
    knobs whose surviving features coincide."""
    def t(dims, knob):
        d = knob.dict
        par = space.parallelism(knob, dims)
        work = float(np.prod(np.asarray(dims, dtype=np.float64)))
        return 1e-9 * work / par + 3e-6 * par \
            + 1e-8 * (d.get("bm", 1) + d.get("bn", 1))
    return t


@pytest.fixture(scope="module")
def installed():
    """One tuned artifact per (op, dtype_bytes, model family)."""
    out = {}
    for op in OPS:
        space = ops.knob_space_for(op, sizes=(32, 64))
        for dtype_bytes in (4, 8):
            for family in PERSISTED_FAMILIES:
                out[(op, dtype_bytes, family)] = install_subroutine(
                    op, space, _timer(space), n_samples=10, dim_lo=16,
                    dim_hi=256, max_footprint_bytes=10_000_000,
                    dtype_bytes=dtype_bytes, candidates=(family,),
                    tune_trials=1, use_lof=False, backend="cpu_blocked")
    return out


# ---------------------------------------------------------------------------
# argmin / bit parity: fast path vs reference path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("dtype_bytes", [4, 8])
@pytest.mark.parametrize("family", PERSISTED_FAMILIES)
def test_parity_installed(installed, op, dtype_bytes, family):
    sub = installed[(op, dtype_bytes, family)]
    cp = compile_predictor(sub)
    assert cp is not None
    for dims in _dims_sweep(op):
        ref_t = sub.predict_times(dims)
        fast_t = cp.predict_times(dims)
        assert np.array_equal(ref_t, fast_t), (op, family, dims)
        assert cp.select(dims) == sub.select(dims), (op, family, dims)


@pytest.mark.skipif(not ARTIFACTS.root.exists(),
                    reason="no persisted artifact store")
def test_parity_persisted_artifacts():
    """Zero argmin decision changes on every artifact the repo ships."""
    subs = ARTIFACTS.load_all()
    assert subs, "artifact store exists but is empty"
    for sub in subs:
        cp = compile_predictor(sub)
        assert cp is not None, (sub.backend, sub.op)
        for dims in _dims_sweep(sub.op, n_random=20, seed=11):
            assert np.array_equal(cp.predict_times(dims),
                                  sub.predict_times(dims)), \
                (sub.backend, sub.op, dims)
            assert cp.select(dims) == sub.select(dims), \
                (sub.backend, sub.op, dims)


# ---------------------------------------------------------------------------
# v2 lowerings: predicated single trees, screened exact KNN
# ---------------------------------------------------------------------------

V2_FAMILIES = ("KNN", "DistilledTree")


@pytest.fixture(scope="module")
def installed_v2():
    """KNN artifacts for every op plus distilled trees for two ops (the
    lowerings PR 4 added), fit on the same structured synthetic timer."""
    out = {}
    for op in OPS:
        space = ops.knob_space_for(op, sizes=(32, 64))
        out[(op, "KNN")] = install_subroutine(
            op, space, _timer(space), n_samples=10, dim_lo=16, dim_hi=256,
            max_footprint_bytes=10_000_000, candidates=("KNN",),
            tune_trials=1, use_lof=False, backend="cpu_blocked")
    for op in ("gemm", "symm"):
        space = ops.knob_space_for(op, sizes=(32, 64))
        out[(op, "DistilledTree")] = install_subroutine(
            op, space, _timer(space), n_samples=10, dim_lo=16, dim_hi=256,
            max_footprint_bytes=10_000_000, candidates=("DistilledTree",),
            tune_trials=1, use_lof=False, backend="cpu_blocked")
    return out


@pytest.mark.parametrize("op", OPS)
def test_parity_knn(installed_v2, op):
    """The screened exact KNN lookup is bit-identical to the reference
    brute-force path on every op's feature space."""
    sub = installed_v2[(op, "KNN")]
    cp = compile_predictor(sub)
    assert cp is not None and cp.lowering == "screened-knn"
    for dims in _dims_sweep(op):
        assert np.array_equal(cp.predict_times(dims),
                              sub.predict_times(dims)), (op, dims)
        assert cp.select(dims) == sub.select(dims)


@pytest.mark.parametrize("op", ("gemm", "symm"))
def test_parity_distilled_tree(installed_v2, op):
    sub = installed_v2[(op, "DistilledTree")]
    cp = compile_predictor(sub)
    assert cp is not None and cp.lowering == "predicated-tree"
    for dims in _dims_sweep(op):
        assert np.array_equal(cp.predict_times(dims),
                              sub.predict_times(dims)), (op, dims)


def test_parity_knn_distance_weights(installed_v2):
    """Distance-weighted KNN: the weighted combine over canonical
    neighbours reproduces the reference bit for bit."""
    from repro.core.ml.knn import KNN
    sub = installed_v2[("gemm", "KNN")]
    m = sub.model
    import dataclasses
    sub2 = dataclasses.replace(
        sub, model=KNN(k=m.k, weights="distance").fit(m.X_, m.y_),
        dataset=None, reports=[])
    cp = compile_predictor(sub2)
    for dims in _dims_sweep("gemm", n_random=8):
        assert np.array_equal(cp.predict_times(dims),
                              sub2.predict_times(dims)), dims


def test_parity_batch_v2(installed_v2):
    """Batched prediction (with its duplicate-row fold) stays bit-identical
    to per-dims prediction for the new lowerings."""
    rng = np.random.default_rng(5)
    for key in ((("gemm", "KNN")), ("gemm", "DistilledTree")):
        sub = installed_v2[key]
        cp = compile_predictor(sub)
        dims_list = [tuple(int(v) for v in rng.integers(8, 2048, size=3))
                     for _ in range(7)]
        dims_list.append(dims_list[0])          # duplicate item
        t = cp.predict_times_batch(dims_list)
        for b, dims in enumerate(dims_list):
            assert np.array_equal(t[b], sub.predict_times(dims)), (key, dims)


def test_lowering_names(installed, installed_v2):
    assert compile_predictor(
        installed[("gemm", 4, "LinearRegression")]).lowering \
        == "reference-predict"
    assert compile_predictor(
        installed[("gemm", 4, "DecisionTree")]).lowering == "predicated-tree"
    assert compile_predictor(
        installed_v2[("gemm", "KNN")]).lowering == "screened-knn"
    assert compile_predictor(
        installed_v2[("gemm", "DistilledTree")]).lowering \
        == "predicated-tree"


def test_screened_knn_screen_path_parity():
    """The sgemm screen + certification + exact rescore, driven directly
    at n >> 4k so the brute-force early exit can NOT mask it: parity with
    the canonical reference on clustered data, duplicate training points
    (exact distance ties), and queries placed exactly on tie boundaries
    (exercising the union fallback)."""
    from repro.core.fastpath import _ScreenedKNN
    from repro.core.ml.knn import KNN
    rng = np.random.default_rng(17)
    n, C = 600, 7
    X = rng.normal(size=(n, C)) * rng.uniform(0.5, 3.0, size=C)
    X[100:140] = X[60:100]          # duplicate blocks: exact tie clusters
    X[500:530] = X[0]               # one point duplicated 30x > PAD
    y = rng.normal(size=n)
    for k, weights in ((5, "uniform"), (15, "distance"), (3, "distance")):
        m = KNN(k=k, weights=weights).fit(X, y)
        sk = _ScreenedKNN(m)
        Q = np.vstack([
            X[rng.integers(0, n, size=6)] + rng.normal(scale=1e-3,
                                                       size=(6, C)),
            X[[0, 60, 100, 500]],   # exactly ON the tie clusters
            rng.normal(size=(4, C)) * 5.0,        # far queries
        ])
        assert np.array_equal(sk.predict(Q), m.predict(Q)), (k, weights)


def test_screened_knn_workspace_reuse_is_bit_stable():
    """The per-thread screen workspace (PR 5: persistent Z32/d2a buffers
    keyed by query-row count) must return the same bits call after call —
    and per-thread buffers must not be shared across threads."""
    import threading

    from repro.core.fastpath import _ScreenedKNN
    from repro.core.ml.knn import KNN
    rng = np.random.default_rng(21)
    X = rng.normal(size=(500, 6))
    m = KNN(k=7).fit(X, rng.normal(size=500))
    sk = _ScreenedKNN(m)
    Q = rng.normal(size=(27, 6))
    first = sk.predict(Q)
    # buffer reuse: same Q-row count hits the same per-thread workspace
    b1 = sk._screen_buffers(27, 6)
    assert sk._screen_buffers(27, 6)[0] is b1[0]
    for _ in range(3):
        assert np.array_equal(sk.predict(Q), first)
    assert np.array_equal(first, m.predict(Q))
    # distinct row counts get distinct buffers; threads get their own
    assert sk._screen_buffers(9, 6)[1] is not b1[1]
    seen = {}

    def worker():
        seen[threading.get_ident()] = sk._screen_buffers(27, 6)[0]
        assert np.array_equal(sk.predict(Q), first)

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    (tid, buf), = seen.items()
    assert tid != threading.get_ident() and buf is not b1[0]


def test_screened_knn_nonfinite_queries_fall_back():
    from repro.core.fastpath import _ScreenedKNN
    from repro.core.ml.knn import KNN
    rng = np.random.default_rng(3)
    X = rng.normal(size=(300, 5))
    m = KNN(k=5).fit(X, rng.normal(size=300))
    sk = _ScreenedKNN(m)
    Q = rng.normal(size=(4, 5))
    Q[2, 3] = np.inf                # feature overflow: exact full rescore
    assert np.array_equal(sk.predict(Q), m.predict(Q))


def test_threshold_fold_saturating_lambda_at_inf(installed):
    """Negative-lambda YJ columns saturate at a finite limit as x -> inf;
    the folded thresholds must route an infinite raw feature exactly like
    the reference transform would."""
    from repro.core.fastpath import _invert_monotone_thresholds
    lam = np.array([-0.5, -0.5, 0.8])
    mean = np.array([0.0, 10.0, 0.0])
    scale = np.array([1.0, 1.0, 1.0])

    def tfun(x):
        return ((np.power(x + 1.0, lam) - 1.0) / lam - mean) / scale

    # node 0: thr below the saturation limit (= (0-1)/-0.5 = 2.0) -> some
    # finite inversion; node 1: thr ABOVE the shifted saturation limit ->
    # +inf (an infinite x still satisfies tfun(x) <= thr); node 2:
    # diverging lambda -> finite inversion
    thr = np.array([1.0, 0.0, 5.0])
    raw = _invert_monotone_thresholds(tfun, thr, saturates=lam < 0)
    assert np.isfinite(raw[0]) and raw[1] == np.inf and np.isfinite(raw[2])
    for x in (0.0, 1.0, 1e300, np.finfo(np.float64).max, np.inf):
        want = tfun(np.full(3, x)) <= thr
        got = np.full(3, x) <= raw
        assert np.array_equal(want, got), x


def test_predicated_tree_layout_fallback(installed):
    """Row counts beyond the slot budget fall back to the generic stacked
    descent — still bit-identical."""
    from repro.core.fastpath import _PredicatedTree
    sub = installed[("gemm", 4, "DecisionTree")]
    tree = sub.model.tree_
    pt = _PredicatedTree(tree)
    pt.CAP = 1                    # force the fallback for every row count
    rng = np.random.default_rng(9)
    ncols = int(tree.feature.max()) + 1
    Z = np.asfortranarray(rng.normal(size=(13, max(ncols, 1))))
    assert np.array_equal(pt.predict(Z), tree.predict(Z))


def test_parity_thread_knob_space(installed):
    """Thread-count spaces are detected as dims-independent (nt computed
    once at compile time) and still match the reference bit-for-bit."""
    base = installed[("gemm", 4, "LinearRegression")]
    space = thread_knob_space(8)
    sub = install_subroutine(
        "gemm", space, lambda dims, knob: 1e-6 * (1.0 + 64.0 / knob["nt"])
        + 1e-9 * dims[0], n_samples=10, dim_lo=16, dim_hi=256,
        max_footprint_bytes=10_000_000, candidates=("LinearRegression",),
        tune_trials=1, use_lof=False)
    cp = compile_predictor(sub)
    assert cp is not None and cp._nt_mode == "const"
    del base
    for dims in _dims_sweep("gemm"):
        assert np.array_equal(cp.predict_times(dims),
                              sub.predict_times(dims))
        assert cp.select(dims) == sub.select(dims)


def test_runtime_serves_fast_path_decisions(installed):
    """register() compiles; runtime.select decisions == reference select."""
    sub = installed[("gemm", 4, "LinearRegression")]
    rt = AdsalaRuntime()
    rt.register(sub)
    assert rt.predictor("gemm", 4, backend="cpu_blocked") is not None
    for dims in _dims_sweep("gemm", n_random=4):
        assert rt.select("gemm", dims, 4, backend="cpu_blocked") \
            == sub.select(dims)


def test_uncompilable_sub_falls_back_to_reference():
    rt = AdsalaRuntime()
    stub = StubSub("b0")
    rt.register(stub)
    assert rt.predictor("gemm", 4, backend="b0") is None
    assert rt.select("gemm", (32, 32, 32), 4, backend="b0") == stub.knob
    assert stub.evals == 1


# ---------------------------------------------------------------------------
# dominated-candidate pruning (opt-in)
# ---------------------------------------------------------------------------

def test_dominated_prune_analysis_persisted(installed, tmp_path):
    sub = installed[("gemm", 4, "LinearRegression")]
    assert sub.fast_live_idx is not None
    assert 0 < sub.fast_live_idx.size <= len(sub.knob_space)
    assert sub.fast_dims_lo.shape == (3,) and sub.fast_dims_hi.shape == (3,)
    # round-trips through the registry
    reg = ModelRegistry(tmp_path)
    reg.save(sub)
    back = reg.load_all()[0]
    assert np.array_equal(back.fast_live_idx, sub.fast_live_idx)
    assert np.array_equal(back.fast_dims_lo, sub.fast_dims_lo)
    assert np.array_equal(back.fast_dims_hi, sub.fast_dims_hi)


def test_dominated_prune_semantics(installed):
    sub = installed[("gemm", 4, "LinearRegression")]
    cp = compile_predictor(sub, prune=True)
    full = compile_predictor(sub)
    lo, hi = sub.fast_dims_lo, sub.fast_dims_hi
    live = set(int(i) for i in sub.fast_live_idx)
    if len(live) < len(sub.knob_space):
        assert cp._live is not None
        # in-bounds dims: decision restricted to the live set, equal to the
        # argmin over the live candidates of the full prediction vector
        mid = tuple(int((a + b) // 2) for a, b in zip(lo, hi))
        idx = cp.select_index(mid)
        assert idx in live
        t = full.predict_times(mid)
        live_sorted = sorted(live)
        assert idx == live_sorted[int(np.argmin(t[live_sorted]))]
    # out-of-bounds dims (extrapolation): full-K evaluation, exact parity
    far = tuple(int(h * 2 + 1) for h in hi)
    assert cp.select(far) == sub.select(far)


# ---------------------------------------------------------------------------
# confidence-band prune (opt-in) + KNN coreset (opt-in)
# ---------------------------------------------------------------------------

def test_band_analysis_persisted_roundtrip(installed, tmp_path):
    sub = installed[("gemm", 4, "LinearRegression")]
    assert sub.fast_band_idx is not None
    assert sub.fast_band_pct == 10.0
    # the band set contains every argmin winner (winners are within 0%)
    assert set(sub.fast_live_idx).issubset(set(sub.fast_band_idx))
    reg = ModelRegistry(tmp_path)
    reg.save(sub)
    back = reg.load_all()[0]
    assert np.array_equal(back.fast_band_idx, sub.fast_band_idx)
    assert back.fast_band_pct == sub.fast_band_pct


def test_band_prune_semantics(installed):
    sub = installed[("gemm", 4, "LinearRegression")]
    cp = compile_predictor(sub, prune="band")
    full = compile_predictor(sub)
    band = set(int(i) for i in sub.fast_band_idx)
    lo, hi = sub.fast_dims_lo, sub.fast_dims_hi
    if len(band) < len(sub.knob_space):
        assert cp._live is not None
        mid = tuple(int((a + b) // 2) for a, b in zip(lo, hi))
        idx = cp.select_index(mid)
        assert idx in band
        t = full.predict_times(mid)
        band_sorted = sorted(band)
        assert idx == band_sorted[int(np.argmin(t[band_sorted]))]
    # out-of-range dims: full-K evaluation, exact parity with the reference
    far = tuple(int(h * 2 + 1) for h in hi)
    assert cp.select(far) == sub.select(far)
    assert np.array_equal(cp.predict_times(far), sub.predict_times(far))


def test_band_is_superset_of_argmin_live(installed):
    """band prune keeps near-winners the argmin-only prune would drop."""
    for key, sub in installed.items():
        if sub.fast_band_idx is None or sub.fast_live_idx is None:
            continue
        assert set(sub.fast_live_idx).issubset(set(sub.fast_band_idx)), key


def test_knn_coreset_optin(installed_v2, tmp_path):
    from repro.core import attach_knn_coreset
    from repro.core.ml.knn import KNN
    sub = installed_v2[("gemm", "KNN")]
    assert sub.fast_knn_coreset is None       # never attached by default
    assert attach_knn_coreset(sub, frac=0.5, min_size=8)
    idx = sub.fast_knn_coreset
    assert idx is not None and 0 < idx.size <= sub.model.X_.shape[0]
    # persists and round-trips
    reg = ModelRegistry(tmp_path)
    reg.save(sub)
    back = reg.load_all()[0]
    assert np.array_equal(back.fast_knn_coreset, idx)
    # DEFAULT compile ignores the coreset: exact parity with the full model
    cp = compile_predictor(back)
    assert cp.lowering == "screened-knn" and not cp.coreset
    for dims in _dims_sweep("gemm", n_random=6):
        assert np.array_equal(cp.predict_times(dims),
                              sub.predict_times(dims))
    # opt-in compile == a KNN fit on the subsample (inexact vs full model)
    cpc = compile_predictor(back, coreset=True)
    assert cpc.lowering == "screened-knn-coreset" and cpc.coreset
    m = sub.model
    msub = KNN(k=m.k, weights=m.weights).fit(m.X_[idx], m.y_[idx])
    import dataclasses
    want = dataclasses.replace(sub, model=msub, dataset=None, reports=[],
                               fast_knn_coreset=None)
    for dims in _dims_sweep("gemm", n_random=6):
        assert np.array_equal(cpc.predict_times(dims),
                              want.predict_times(dims))


def test_runtime_coreset_flag(installed_v2):
    from repro.core import attach_knn_coreset
    sub = installed_v2[("trsm", "KNN")]
    if sub.fast_knn_coreset is None:
        attach_knn_coreset(sub, frac=0.5, min_size=8)
    rt = AdsalaRuntime(fast_knn_coreset=True)
    rt.register(sub)
    cp = rt.predictor("trsm", 4, backend="cpu_blocked")
    assert cp is not None and cp.coreset
    rt_plain = AdsalaRuntime()
    rt_plain.register(sub)
    assert not rt_plain.predictor("trsm", 4, backend="cpu_blocked").coreset


def test_attach_knn_coreset_non_knn(installed):
    from repro.core import attach_knn_coreset
    sub = installed[("gemm", 4, "LinearRegression")]
    assert not attach_knn_coreset(sub)
    assert sub.fast_knn_coreset is None


# ---------------------------------------------------------------------------
# lock-free hit path under concurrency: stats stay exact
# ---------------------------------------------------------------------------

def test_lockfree_hits_stats_exact_under_stress():
    rt = AdsalaRuntime(cache_size=64)
    for name in ("b0", "b1"):
        rt.register(StubSub(name))
    default = Knob((("bm", 16), ("bn", 16)))
    shapes = [(32 * i, 32, 32) for i in range(1, 9)]
    # prefill so the stress is hit-dominated
    for name in ("b0", "b1"):
        for d in shapes:
            rt.select("gemm", d, 4, backend=name)
    prefill = rt.stats
    n_threads, n_iters = 8, 400
    errors = []

    def worker(tid):
        rng = np.random.default_rng(tid)
        try:
            for i in range(n_iters):
                d = shapes[int(rng.integers(len(shapes)))]
                be = ("b0", "b1")[int(rng.integers(2))]
                if i % 10 == 0:
                    rt.select_or_default("gemm", d, 4, default,
                                         backend="untuned")
                else:
                    rt.select("gemm", d, 4, backend=be)
        except Exception as e:           # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    s = rt.stats
    assert s.calls == prefill.calls + n_threads * n_iters
    # every call is exactly one of hit / model eval / default
    assert s.calls == s.cache_hits + s.model_evals + s.default_calls
    # aggregate counters == per-backend sums
    per = list(s.backends.values())
    for counter in ("calls", "cache_hits", "default_calls", "model_evals"):
        assert getattr(s, counter) == sum(getattr(b, counter) for b in per)
    # all stress selects after prefill were hits or defaults (no re-evals)
    assert s.model_evals == prefill.model_evals


# ---------------------------------------------------------------------------
# sharded miss path: same-key coalescing, per-(backend, op) locks
# ---------------------------------------------------------------------------

class SlowStubSub(StubSub):
    """Uncompilable sub whose reference select is slow enough that
    concurrent misses on one key overlap."""

    def select(self, dims):
        import time as _t
        _t.sleep(0.05)
        return super().select(dims)


def test_miss_coalescing_single_eval():
    """N concurrent misses on ONE key -> exactly one model evaluation; the
    other callers count as hits (they rode the in-flight computation)."""
    rt = AdsalaRuntime()
    stub = SlowStubSub("b0")
    rt.register(stub)
    n_threads = 6
    knobs, errors = [], []

    def worker():
        try:
            knobs.append(rt.select("gemm", (64, 64, 64), 4, backend="b0"))
        except Exception as e:        # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert stub.evals == 1
    assert all(k == stub.knob for k in knobs)
    s = rt.stats
    assert s.model_evals == 1
    assert s.cache_hits == n_threads - 1
    assert s.calls == s.cache_hits + s.model_evals + s.default_calls


def test_select_many_coalesces_with_concurrent_select():
    """select_many racing concurrent select calls on the same uncached key
    (the serving prewarm vs a stealing worker) must still cost exactly ONE
    model evaluation per distinct key — select_many's miss path joins the
    same in-flight protocol as the one-at-a-time path."""
    for trial in range(5):
        rt = AdsalaRuntime()
        stub = SlowStubSub("b0")
        rt.register(stub)
        dims_list = [(64, 64, 64), (96, 96, 96), (128, 128, 128)]
        results, errors = [], []

        def many():
            try:
                results.append(rt.select_many(
                    [("gemm", d, 4, "b0") for d in dims_list],
                    record_hits=False))
            except Exception as e:    # noqa: BLE001
                errors.append(e)

        def single(d):
            try:
                results.append(rt.select("gemm", d, 4, backend="b0"))
            except Exception as e:    # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=many),
                   threading.Thread(target=many)] + \
            [threading.Thread(target=single, args=(d,)) for d in dims_list]
        random.shuffle(threads)
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert stub.evals == len(dims_list), trial
        assert rt.stats.model_evals == len(dims_list), trial


def test_miss_shards_are_per_backend_op():
    rt = AdsalaRuntime()
    for name in ("b0", "b1"):
        rt.register(StubSub(name))
        rt.register(StubSub(name, op="symm"))
    rt.select("gemm", (32, 32, 32), 4, backend="b0")
    rt.select("gemm", (32, 32, 32), 4, backend="b1")
    rt.select("symm", (32, 32), 4, backend="b0")
    shards = rt._shards
    assert ("b0", "gemm") in shards and ("b1", "gemm") in shards \
        and ("b0", "symm") in shards
    assert shards[("b0", "gemm")] is not shards[("b1", "gemm")]
    # eval statistics live on the shards and aggregate exactly
    s = rt.stats
    assert s.model_evals == 3
    assert s.for_backend("b0").model_evals == 2
    assert s.for_backend("b1").model_evals == 1


# ---------------------------------------------------------------------------
# trace-time decision batching (ops._select hook)
# ---------------------------------------------------------------------------

def test_trace_batching_batches_concurrent_misses(installed):
    sub = installed[("gemm", 4, "LinearRegression")]
    rt = AdsalaRuntime()
    rt.register(sub, backend="pallas")
    shapes = [(32 * i, 64, 32 * j) for i in range(1, 5) for j in range(1, 5)]
    errors = []
    with ops.trace_batching(linger_ms=1.0) as batcher:
        def worker(tid):
            rng = np.random.default_rng(tid)
            try:
                for _ in range(30):
                    d = shapes[int(rng.integers(len(shapes)))]
                    got = ops._select("gemm", d, np.float32, None, rt)
                    assert got == sub.select(d), d
            except Exception as e:        # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not errors
    # the per-thread rngs are seeded, so the requested key set is exact
    drawn = set()
    for t in range(4):
        rng = np.random.default_rng(t)
        for _ in range(30):
            drawn.add(shapes[int(rng.integers(len(shapes)))])
    # every distinct key evaluated exactly once, through select_many
    s = rt.stats
    assert s.model_evals == len(drawn)
    assert batcher.batches >= 1
    assert batcher.batched_keys >= 1
    assert s.calls == s.cache_hits + s.model_evals + s.default_calls
    # the hook uninstalls on context exit
    assert ops._TRACE_BATCHER is None


def test_trace_batching_untuned_falls_back_to_default():
    rt = AdsalaRuntime()
    with ops.trace_batching(linger_ms=0.1):
        knob = ops._select("gemm", (64, 64, 64), np.float32, None, rt)
    assert knob == ops.default_knob("gemm")
    assert rt.stats.default_calls == 1


# ---------------------------------------------------------------------------
# select_many == N x select
# ---------------------------------------------------------------------------

def _fresh_runtime(installed):
    rt = AdsalaRuntime()
    rt.register(installed[("gemm", 4, "LinearRegression")])
    rt.register(installed[("symm", 4, "DecisionTree")])
    return rt


def test_select_many_equivalent_to_selects(installed):
    gemm_dims = _dims_sweep("gemm", n_random=6)
    symm_dims = _dims_sweep("symm", n_random=6)
    reqs = [("gemm", d, 4, "cpu_blocked") for d in gemm_dims] \
         + [("symm", d, 4, "cpu_blocked") for d in symm_dims] \
         + [("gemm", gemm_dims[0], 4, "cpu_blocked")]   # duplicate key

    batched = _fresh_runtime(installed)
    got = batched.select_many(reqs)

    sequential = _fresh_runtime(installed)
    want = [sequential.select(op, d, b, backend=be)
            for op, d, b, be in reqs]
    assert got == want
    sb, ss = batched.stats, sequential.stats
    assert (sb.calls, sb.cache_hits, sb.model_evals) == \
        (ss.calls, ss.cache_hits, ss.model_evals)
    # a second batched pass is all hits
    assert batched.select_many(reqs) == want
    assert batched.stats.model_evals == sb.model_evals


def test_select_many_mixed_hits_and_unregistered(installed):
    rt = _fresh_runtime(installed)
    d0 = (64, 64, 64)
    warm = rt.select("gemm", d0, 4, backend="cpu_blocked")
    out = rt.select_many([
        ("gemm", d0, 4, "cpu_blocked"),          # hit
        ("gemm", (96, 96, 96), 4, "cpu_blocked"),  # miss
        ("trsm", (64, 64), 4, "cpu_blocked"),    # unregistered -> None
    ])
    assert out[0] == warm
    assert out[1] == rt.subroutine("gemm", 4, "cpu_blocked").select(
        (96, 96, 96))
    assert out[2] is None
    s = rt.stats
    assert s.model_evals == 2 and s.cache_hits == 1


def test_select_many_empty():
    assert AdsalaRuntime().select_many([]) == []


def test_select_many_record_hits_false_keeps_hits_out_of_stats(installed):
    rt = _fresh_runtime(installed)
    d0 = (64, 64, 64)
    rt.select("gemm", d0, 4, backend="cpu_blocked")
    before = rt.stats
    out = rt.select_many(
        [("gemm", d0, 4, "cpu_blocked"),                # cached: silent
         ("gemm", (96, 96, 96), 4, "cpu_blocked")],     # miss: recorded
        record_hits=False)
    assert out[0] is not None and out[1] is not None
    s = rt.stats
    assert s.cache_hits == before.cache_hits            # no synthetic hits
    assert s.model_evals == before.model_evals + 1      # real eval counted
    assert s.calls == before.calls + 1


def test_resolve_backend_reprobes_availability():
    """A memoized resolution must not outlive the backend's availability."""
    from repro.backends import (get_backend, register_backend,
                                resolve_backend, unregister_backend)
    from repro.backends.base import Backend

    class Flaky(Backend):
        name = "flaky"
        up = True

        def is_available(self):
            return self.up

        def knob_space(self, op, *, sizes=None):
            return get_backend("ref").knob_space(op)

        def execute(self, op, operands, knob=None, **kw):
            raise AssertionError("never executed in this test")

    be = Flaky()
    register_backend(be)
    try:
        assert resolve_backend("flaky") is be
        assert resolve_backend("flaky") is be           # memo hit
        be.up = False                                   # no registry change
        assert resolve_backend("flaky").name == "ref"   # falls back
        be.up = True
        assert resolve_backend("flaky") is be
    finally:
        unregister_backend("flaky")
