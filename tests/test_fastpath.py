"""Compiled decision fast path: argmin/bit parity against the reference
path (all six ops x both dtypes x every persisted model family), lock-free
hit-path concurrency (stats stay exact), and select_many equivalence with N
individual selects."""

import threading

import numpy as np
import pytest

from repro.core import AdsalaRuntime, ModelRegistry, install_subroutine
from repro.core.fastpath import CompiledPredictor, compile_predictor
from repro.core.knobs import Knob, thread_knob_space
from repro.kernels import ops


class StubSub:
    """Uncompilable TunedSubroutine stand-in (no pipeline/model): the
    runtime must fall back to its reference ``select``."""

    def __init__(self, backend: str, op: str = "gemm",
                 dtype_bytes: int = 4) -> None:
        self.backend = backend
        self.op = op
        self.dtype_bytes = dtype_bytes
        self.knob = Knob((("bm", 128), ("bn", 128)))
        self.evals = 0

    def select(self, dims):
        self.evals += 1
        return self.knob

#: model families present in the repo's persisted artifact store
PERSISTED_FAMILIES = ("LinearRegression", "DecisionTree")

OPS = ("gemm", "symm", "syrk", "syr2k", "trmm", "trsm")

ARTIFACTS = ModelRegistry("runs/adsala/models")


def _dims_sweep(op: str, n_random: int = 12, seed: int = 7):
    nd = 3 if op == "gemm" else 2
    fixed = [(16,) * nd, (64,) * nd, (512,) * nd, (2048,) * nd,
             (33, 257, 1023)[:nd], (1024, 48, 640)[:nd]]
    rng = np.random.default_rng(seed)
    rand = [tuple(int(v) for v in rng.integers(8, 2048, size=nd))
            for _ in range(n_random)]
    return fixed + rand


def _timer(space):
    """Structured synthetic timer: dims- and knob-dependent (compute +
    per-grid-cell launch overhead + block cost), so fitted models produce a
    dims-dependent argmin structure — including exact prediction ties for
    knobs whose surviving features coincide."""
    def t(dims, knob):
        d = knob.dict
        par = space.parallelism(knob, dims)
        work = float(np.prod(np.asarray(dims, dtype=np.float64)))
        return 1e-9 * work / par + 3e-6 * par \
            + 1e-8 * (d.get("bm", 1) + d.get("bn", 1))
    return t


@pytest.fixture(scope="module")
def installed():
    """One tuned artifact per (op, dtype_bytes, model family)."""
    out = {}
    for op in OPS:
        space = ops.knob_space_for(op, sizes=(32, 64))
        for dtype_bytes in (4, 8):
            for family in PERSISTED_FAMILIES:
                out[(op, dtype_bytes, family)] = install_subroutine(
                    op, space, _timer(space), n_samples=10, dim_lo=16,
                    dim_hi=256, max_footprint_bytes=10_000_000,
                    dtype_bytes=dtype_bytes, candidates=(family,),
                    tune_trials=1, use_lof=False, backend="cpu_blocked")
    return out


# ---------------------------------------------------------------------------
# argmin / bit parity: fast path vs reference path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("dtype_bytes", [4, 8])
@pytest.mark.parametrize("family", PERSISTED_FAMILIES)
def test_parity_installed(installed, op, dtype_bytes, family):
    sub = installed[(op, dtype_bytes, family)]
    cp = compile_predictor(sub)
    assert cp is not None
    for dims in _dims_sweep(op):
        ref_t = sub.predict_times(dims)
        fast_t = cp.predict_times(dims)
        assert np.array_equal(ref_t, fast_t), (op, family, dims)
        assert cp.select(dims) == sub.select(dims), (op, family, dims)


@pytest.mark.skipif(not ARTIFACTS.root.exists(),
                    reason="no persisted artifact store")
def test_parity_persisted_artifacts():
    """Zero argmin decision changes on every artifact the repo ships."""
    subs = ARTIFACTS.load_all()
    assert subs, "artifact store exists but is empty"
    for sub in subs:
        cp = compile_predictor(sub)
        assert cp is not None, (sub.backend, sub.op)
        for dims in _dims_sweep(sub.op, n_random=20, seed=11):
            assert np.array_equal(cp.predict_times(dims),
                                  sub.predict_times(dims)), \
                (sub.backend, sub.op, dims)
            assert cp.select(dims) == sub.select(dims), \
                (sub.backend, sub.op, dims)


def test_parity_thread_knob_space(installed):
    """Thread-count spaces are detected as dims-independent (nt computed
    once at compile time) and still match the reference bit-for-bit."""
    base = installed[("gemm", 4, "LinearRegression")]
    space = thread_knob_space(8)
    sub = install_subroutine(
        "gemm", space, lambda dims, knob: 1e-6 * (1.0 + 64.0 / knob["nt"])
        + 1e-9 * dims[0], n_samples=10, dim_lo=16, dim_hi=256,
        max_footprint_bytes=10_000_000, candidates=("LinearRegression",),
        tune_trials=1, use_lof=False)
    cp = compile_predictor(sub)
    assert cp is not None and cp._nt_mode == "const"
    del base
    for dims in _dims_sweep("gemm"):
        assert np.array_equal(cp.predict_times(dims),
                              sub.predict_times(dims))
        assert cp.select(dims) == sub.select(dims)


def test_runtime_serves_fast_path_decisions(installed):
    """register() compiles; runtime.select decisions == reference select."""
    sub = installed[("gemm", 4, "LinearRegression")]
    rt = AdsalaRuntime()
    rt.register(sub)
    assert rt.predictor("gemm", 4, backend="cpu_blocked") is not None
    for dims in _dims_sweep("gemm", n_random=4):
        assert rt.select("gemm", dims, 4, backend="cpu_blocked") \
            == sub.select(dims)


def test_uncompilable_sub_falls_back_to_reference():
    rt = AdsalaRuntime()
    stub = StubSub("b0")
    rt.register(stub)
    assert rt.predictor("gemm", 4, backend="b0") is None
    assert rt.select("gemm", (32, 32, 32), 4, backend="b0") == stub.knob
    assert stub.evals == 1


# ---------------------------------------------------------------------------
# dominated-candidate pruning (opt-in)
# ---------------------------------------------------------------------------

def test_dominated_prune_analysis_persisted(installed, tmp_path):
    sub = installed[("gemm", 4, "LinearRegression")]
    assert sub.fast_live_idx is not None
    assert 0 < sub.fast_live_idx.size <= len(sub.knob_space)
    assert sub.fast_dims_lo.shape == (3,) and sub.fast_dims_hi.shape == (3,)
    # round-trips through the registry
    reg = ModelRegistry(tmp_path)
    reg.save(sub)
    back = reg.load_all()[0]
    assert np.array_equal(back.fast_live_idx, sub.fast_live_idx)
    assert np.array_equal(back.fast_dims_lo, sub.fast_dims_lo)
    assert np.array_equal(back.fast_dims_hi, sub.fast_dims_hi)


def test_dominated_prune_semantics(installed):
    sub = installed[("gemm", 4, "LinearRegression")]
    cp = compile_predictor(sub, prune=True)
    full = compile_predictor(sub)
    lo, hi = sub.fast_dims_lo, sub.fast_dims_hi
    live = set(int(i) for i in sub.fast_live_idx)
    if len(live) < len(sub.knob_space):
        assert cp._live is not None
        # in-bounds dims: decision restricted to the live set, equal to the
        # argmin over the live candidates of the full prediction vector
        mid = tuple(int((a + b) // 2) for a, b in zip(lo, hi))
        idx = cp.select_index(mid)
        assert idx in live
        t = full.predict_times(mid)
        live_sorted = sorted(live)
        assert idx == live_sorted[int(np.argmin(t[live_sorted]))]
    # out-of-bounds dims (extrapolation): full-K evaluation, exact parity
    far = tuple(int(h * 2 + 1) for h in hi)
    assert cp.select(far) == sub.select(far)


# ---------------------------------------------------------------------------
# lock-free hit path under concurrency: stats stay exact
# ---------------------------------------------------------------------------

def test_lockfree_hits_stats_exact_under_stress():
    rt = AdsalaRuntime(cache_size=64)
    for name in ("b0", "b1"):
        rt.register(StubSub(name))
    default = Knob((("bm", 16), ("bn", 16)))
    shapes = [(32 * i, 32, 32) for i in range(1, 9)]
    # prefill so the stress is hit-dominated
    for name in ("b0", "b1"):
        for d in shapes:
            rt.select("gemm", d, 4, backend=name)
    prefill = rt.stats
    n_threads, n_iters = 8, 400
    errors = []

    def worker(tid):
        rng = np.random.default_rng(tid)
        try:
            for i in range(n_iters):
                d = shapes[int(rng.integers(len(shapes)))]
                be = ("b0", "b1")[int(rng.integers(2))]
                if i % 10 == 0:
                    rt.select_or_default("gemm", d, 4, default,
                                         backend="untuned")
                else:
                    rt.select("gemm", d, 4, backend=be)
        except Exception as e:           # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    s = rt.stats
    assert s.calls == prefill.calls + n_threads * n_iters
    # every call is exactly one of hit / model eval / default
    assert s.calls == s.cache_hits + s.model_evals + s.default_calls
    # aggregate counters == per-backend sums
    per = list(s.backends.values())
    for counter in ("calls", "cache_hits", "default_calls", "model_evals"):
        assert getattr(s, counter) == sum(getattr(b, counter) for b in per)
    # all stress selects after prefill were hits or defaults (no re-evals)
    assert s.model_evals == prefill.model_evals


# ---------------------------------------------------------------------------
# select_many == N x select
# ---------------------------------------------------------------------------

def _fresh_runtime(installed):
    rt = AdsalaRuntime()
    rt.register(installed[("gemm", 4, "LinearRegression")])
    rt.register(installed[("symm", 4, "DecisionTree")])
    return rt


def test_select_many_equivalent_to_selects(installed):
    gemm_dims = _dims_sweep("gemm", n_random=6)
    symm_dims = _dims_sweep("symm", n_random=6)
    reqs = [("gemm", d, 4, "cpu_blocked") for d in gemm_dims] \
         + [("symm", d, 4, "cpu_blocked") for d in symm_dims] \
         + [("gemm", gemm_dims[0], 4, "cpu_blocked")]   # duplicate key

    batched = _fresh_runtime(installed)
    got = batched.select_many(reqs)

    sequential = _fresh_runtime(installed)
    want = [sequential.select(op, d, b, backend=be)
            for op, d, b, be in reqs]
    assert got == want
    sb, ss = batched.stats, sequential.stats
    assert (sb.calls, sb.cache_hits, sb.model_evals) == \
        (ss.calls, ss.cache_hits, ss.model_evals)
    # a second batched pass is all hits
    assert batched.select_many(reqs) == want
    assert batched.stats.model_evals == sb.model_evals


def test_select_many_mixed_hits_and_unregistered(installed):
    rt = _fresh_runtime(installed)
    d0 = (64, 64, 64)
    warm = rt.select("gemm", d0, 4, backend="cpu_blocked")
    out = rt.select_many([
        ("gemm", d0, 4, "cpu_blocked"),          # hit
        ("gemm", (96, 96, 96), 4, "cpu_blocked"),  # miss
        ("trsm", (64, 64), 4, "cpu_blocked"),    # unregistered -> None
    ])
    assert out[0] == warm
    assert out[1] == rt.subroutine("gemm", 4, "cpu_blocked").select(
        (96, 96, 96))
    assert out[2] is None
    s = rt.stats
    assert s.model_evals == 2 and s.cache_hits == 1


def test_select_many_empty():
    assert AdsalaRuntime().select_many([]) == []


def test_select_many_record_hits_false_keeps_hits_out_of_stats(installed):
    rt = _fresh_runtime(installed)
    d0 = (64, 64, 64)
    rt.select("gemm", d0, 4, backend="cpu_blocked")
    before = rt.stats
    out = rt.select_many(
        [("gemm", d0, 4, "cpu_blocked"),                # cached: silent
         ("gemm", (96, 96, 96), 4, "cpu_blocked")],     # miss: recorded
        record_hits=False)
    assert out[0] is not None and out[1] is not None
    s = rt.stats
    assert s.cache_hits == before.cache_hits            # no synthetic hits
    assert s.model_evals == before.model_evals + 1      # real eval counted
    assert s.calls == before.calls + 1


def test_resolve_backend_reprobes_availability():
    """A memoized resolution must not outlive the backend's availability."""
    from repro.backends import (get_backend, register_backend,
                                resolve_backend, unregister_backend)
    from repro.backends.base import Backend

    class Flaky(Backend):
        name = "flaky"
        up = True

        def is_available(self):
            return self.up

        def knob_space(self, op, *, sizes=None):
            return get_backend("ref").knob_space(op)

        def execute(self, op, operands, knob=None, **kw):
            raise AssertionError("never executed in this test")

    be = Flaky()
    register_backend(be)
    try:
        assert resolve_backend("flaky") is be
        assert resolve_backend("flaky") is be           # memo hit
        be.up = False                                   # no registry change
        assert resolve_backend("flaky").name == "ref"   # falls back
        be.up = True
        assert resolve_backend("flaky") is be
    finally:
        unregister_backend("flaky")
