"""Multi-device tests (8 virtual host devices via subprocess isolation —
the parent process must keep 1 device for the other tests).

Covers: sharded DP×TP train step on the real model, EP'd MoE, elastic
re-meshing (checkpoint on 8 devices → restore on 2), GPipe pipeline
parallelism, and the multi-pod mesh builder."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

_SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(body: str, timeout=600) -> dict:
    """Run ``body`` in a subprocess with 8 host devices; returns parsed JSON
    printed as the last line."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_train_step_dp_tp():
    res = _run("""
        from repro.configs import get_smoke_config
        from repro.data import SyntheticLMDataset, make_global_batch
        from repro.launch.train import TrainLoop
        from repro.checkpoint import Checkpointer
        from repro.optim import AdamWConfig
        import tempfile
        import jax
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_smoke_config("llama3-8b")
        ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=32, global_batch=8)
        loop = TrainLoop(cfg=cfg, adamw=AdamWConfig(total_steps=8),
                         mesh=mesh, ckpt=Checkpointer(tempfile.mkdtemp()),
                         dataset=ds, ckpt_every=100, log_every=100)
        out = loop.run(6)
        losses = [h["loss"] for h in out["history"]]
        p = out["state"]["params"]["segments"][0]
        shardings = {str(x.sharding.spec)
                     for x in jax.tree.leaves(p) if hasattr(x, "sharding")}
        print(json.dumps({"final": out["final_step"],
                          "n_sharding_kinds": len(shardings),
                          "tp_active": any("model" in s for s in shardings)}))
    """)
    assert res["final"] == 6
    assert res["tp_active"]


@pytest.mark.slow
def test_moe_expert_parallel_runs_sharded():
    res = _run("""
        from repro.configs import get_smoke_config
        from repro.models import init_params, loss_fn
        from repro.launch.specs import rules_for
        import dataclasses, jax
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke_config("granite_moe_3b")   # 8 experts over 4-way EP
        rules = rules_for(mesh, "train")
        params = init_params(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jnp.ones((4, 32), jnp.int32),
                 "labels": jnp.ones((4, 32), jnp.int32)}
        with mesh:
            loss, m = jax.jit(lambda p, b: loss_fn(p, b, cfg, mesh=mesh,
                                                   rules=rules))(params, batch)
        print(json.dumps({"loss": float(loss), "aux": float(m["moe_aux"])}))
    """)
    assert res["loss"] > 0 and res["aux"] >= 0


@pytest.mark.slow
def test_elastic_checkpoint_reshard_8_to_2():
    res = _run("""
        from repro.checkpoint import Checkpointer
        from repro.distributed import abstract_like
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        import tempfile, jax
        import numpy as np
        devs = jax.devices()
        mesh8 = jax.make_mesh((8,), ("data",))
        x = jax.device_put(np.arange(64, dtype=np.float32).reshape(8, 8),
                           NamedSharding(mesh8, P("data", None)))
        ck = Checkpointer(tempfile.mkdtemp())
        ck.save(3, {"x": x})
        # restore onto a 2-device mesh (elastic downscale)
        mesh2 = Mesh(np.array(devs[:2]), ("data",))
        target = abstract_like({"x": x}, mesh2, lambda p, l: P("data", None))
        restored = ck.restore(3, target)
        r = restored["x"]
        ok = bool(np.array_equal(np.asarray(r), np.asarray(x)))
        n_shards = len(r.sharding.device_set)
        print(json.dumps({"equal": ok, "n_shards": n_shards}))
    """)
    assert res["equal"] and res["n_shards"] == 2


@pytest.mark.slow
def test_gpipe_pipeline_matches_sequential():
    res = _run("""
        from repro.distributed import gpipe_forward, bubble_fraction
        import functools, jax
        import numpy as np
        mesh = jax.make_mesh((4,), ("stage",))
        S, B, D = 4, 8, 16
        rng = np.random.default_rng(0)
        Ws = jnp.asarray(rng.standard_normal((S, D, D)) / np.sqrt(D),
                         jnp.float32)
        x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)
        stage_fn = lambda w, h: jnp.tanh(h @ w)
        out = gpipe_forward(stage_fn, Ws, x, mesh=mesh, n_microbatches=4)
        want = x
        for i in range(S):
            want = jnp.tanh(want @ Ws[i])
        err = float(jnp.max(jnp.abs(out - want)))
        print(json.dumps({"err": err}))
    """)
    assert res["err"] < 1e-5


@pytest.mark.slow
def test_multipod_mesh_axes():
    res = _run("""
        from repro.launch.mesh import make_host_mesh, batch_axes
        import jax
        m = make_host_mesh(data=4, model=2)
        print(json.dumps({"axes": list(m.axis_names),
                          "shape": [int(m.shape[a]) for a in m.axis_names],
                          "batch_axes": list(batch_axes(m))}))
    """)
    assert res["axes"] == ["data", "model"]
    assert res["shape"] == [4, 2]
    assert res["batch_axes"] == ["data"]


@pytest.mark.slow
def test_grad_compression_reduces_collective_operand_dtype():
    res = _run("""
        from repro.configs import get_smoke_config
        from repro.models import init_params, loss_fn
        from repro.optim import (AdamWConfig, adamw_update, init_adamw,
                                 init_error_feedback, compress_decompress)
        from repro.launch.specs import rules_for
        import jax
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        cfg = get_smoke_config("qwen1.5-4b")
        rules = rules_for(mesh, "train")
        params = init_params(jax.random.PRNGKey(0), cfg)
        ef = init_error_feedback(params)
        batch = {"tokens": jnp.ones((8, 32), jnp.int32),
                 "labels": jnp.ones((8, 32), jnp.int32)}

        def step(p, e, b):
            (_, _), g = jax.value_and_grad(
                lambda pp: loss_fn(pp, b, cfg, mesh=mesh, rules=rules),
                has_aux=True)(p)
            g, e = compress_decompress(g, e)
            return g, e

        with mesh:
            hlo = jax.jit(step).lower(params, ef, batch).compile().as_text()
        print(json.dumps({"int8_in_hlo": ("s8[" in hlo)}))
    """)
    assert res["int8_in_hlo"]
