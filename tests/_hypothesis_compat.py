"""Optional-hypothesis shim: property sweeps skip cleanly when the dep is
absent (it is a test extra, see pyproject.toml), the rest of the module runs.

    from _hypothesis_compat import given, settings, st
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    class _NoStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NoStrategies()

    def given(*a, **k):
        return lambda fn: pytest.mark.skip("hypothesis not installed")(fn)

    def settings(*a, **k):
        return lambda fn: fn

__all__ = ["given", "settings", "st"]
