"""The zero-copy execution contract (PR 5).

Three properties, each asserted at its own level:

  * **numerics** — masked edge-tile kernels are bit-identical to the old
    zero-pad + slice-back path on ragged shapes (the masked zeros occupy
    exactly the lanes the padding filled), and the native leading-batch
    grid is bit-identical to per-item execution;
  * **structure** — the traced dispatch path contains no pad/slice
    primitives, the ``beta == 0`` call takes no C operand at all, and the
    ``tri_packed`` variant launches exactly the n(n+1)/2 packed grid;
  * **knob space** — ``tri_packed`` is a first-class candidate that
    calibration can produce and legacy persisted artifacts keep selecting
    from their own (smaller) persisted spaces.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.knobs import Knob
from repro.kernels import ops
from repro.kernels.cpu_blocked import make_operands
from repro.kernels.gemm import gemm_pallas
from repro.kernels.introspect import (copy_op_counts, full_grid_for,
                                      packed_grid_for, pallas_grids)
from repro.kernels.syrk import detri, tri_count

TRI_OPS = ("syrk", "syr2k", "trmm")

RAGGED = {"gemm": (129, 65, 257), "symm": (129, 257), "syrk": (129, 65),
          "syr2k": (129, 65), "trmm": (129, 257), "trsm": (129, 257)}


def _knob(variant="full", bm=128, bk=128, bn=128):
    return Knob(tuple(sorted({"bm": bm, "bk": bk, "bn": bn,
                              "variant": variant}.items())))


def _jops(op, dims, seed=0):
    return tuple(jnp.asarray(x)
                 for x in make_operands(op, dims, np.float32, seed=seed))


def _padded_run(op, operands, variant="full"):
    """The frozen pre-PR-5 dispatch (ONE copy, shared with the CI smoke
    gate): zero-pad to block multiples (identity-pad the TRSM diagonal),
    run aligned, slice back."""
    from repro.kernels.padded_ref import padded_run
    return padded_run(op, operands, variant=variant, interpret=True)


# ---------------------------------------------------------------------------
# numerics: masked == padded, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", ("gemm", "symm", "syrk", "syr2k", "trmm"))
@pytest.mark.parametrize("dims_key", ("ragged", "one-row"))
def test_masked_bitmatches_padded(op, dims_key):
    dims = RAGGED[op] if dims_key == "ragged" else \
        {"gemm": (1, 300, 384)}.get(op, (1, 384))
    operands = _jops(op, dims, seed=5)
    got = np.asarray(ops.run_op(op, operands, knob=_knob(), interpret=True))
    want = np.asarray(_padded_run(op, operands))
    assert np.array_equal(got, want), (op, dims)


def test_trsm_masked_matches_padded():
    """TRSM solves the ragged diagonal block at its true size instead of
    identity-padding it, so only the solve's low bits may move."""
    operands = _jops("trsm", RAGGED["trsm"], seed=5)
    got = np.asarray(ops.run_op("trsm", operands, knob=_knob(),
                                interpret=True))
    want = np.asarray(_padded_run("trsm", operands))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("op", TRI_OPS)
@pytest.mark.parametrize("dims", [(256, 128), (129, 65), (300, 300)])
def test_tri_packed_bitmatches_tri(op, dims):
    """The packed grid computes the identical per-block dot sequence — only
    the launch structure changes, so results are bit-identical."""
    operands = _jops(op, dims if op != "trmm" else (dims[0], dims[1]),
                     seed=9)
    tri = np.asarray(ops.run_op(op, operands, knob=_knob("tri"),
                                interpret=True))
    packed = np.asarray(ops.run_op(op, operands, knob=_knob("tri_packed"),
                                   interpret=True))
    assert np.array_equal(packed, tri), (op, dims)


@pytest.mark.parametrize("op", ("syrk", "syr2k"))
def test_tri_packed_beta_matches_tri(op):
    operands = _jops(op, (129, 65), seed=13)
    n = operands[0].shape[0]
    c = jnp.asarray(np.random.default_rng(1).standard_normal((n, n)),
                    jnp.float32)
    kw = dict(alpha=1.5, beta=0.5, interpret=True)
    tri = np.asarray(ops.run_op(op, operands + (c,), knob=_knob("tri"), **kw))
    packed = np.asarray(ops.run_op(op, operands + (c,),
                                   knob=_knob("tri_packed"), **kw))
    assert np.array_equal(packed, tri)


# ---------------------------------------------------------------------------
# numerics: native stacked batching == per-item execution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", ("gemm", "symm", "syrk", "syr2k", "trmm"))
def test_stacked_bitmatches_per_item(op):
    B = 3
    items = [_jops(op, RAGGED[op], seed=i) for i in range(B)]
    stacked = tuple(jnp.stack([it[i] for it in items])
                    for i in range(len(items[0])))
    knob = _knob("tri_packed" if op in TRI_OPS else "full")
    got = np.asarray(ops.run_op(op, stacked, knob=knob, stacked=True,
                                interpret=True))
    want = np.stack([np.asarray(ops.run_op(op, it, knob=knob,
                                           interpret=True))
                     for it in items])
    assert np.array_equal(got, want), op


def test_stacked_trsm_matches_per_item():
    B = 3
    items = [_jops("trsm", RAGGED["trsm"], seed=i) for i in range(B)]
    stacked = tuple(jnp.stack([it[i] for it in items]) for i in range(2))
    got = np.asarray(ops.run_op("trsm", stacked, knob=_knob(), stacked=True,
                                interpret=True))
    want = np.stack([np.asarray(ops.run_op("trsm", it, knob=_knob(),
                                           interpret=True))
                     for it in items])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_stacked_is_one_native_grid():
    """The stack executes as ONE pallas_call whose leading grid dim is the
    batch width (not a vmap batching-rule artifact)."""
    B = 4
    a = jnp.ones((B, 129, 65), jnp.float32)
    b = jnp.ones((B, 65, 257), jnp.float32)
    grids = pallas_grids(ops.gemm, a, b, knob=_knob(), interpret=True)
    assert len(grids) == 1
    assert grids[0] == (B, 2, 3, 1)      # (B, ⌈m/bm⌉, ⌈n/bn⌉, ⌈k/bk⌉)


# ---------------------------------------------------------------------------
# structure: the zero-copy jaxpr contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", ("gemm", "symm", "syrk", "syr2k", "trmm"))
def test_no_pad_or_slice_in_dispatch(op):
    operands = _jops(op, RAGGED[op], seed=3)
    knob = _knob("tri_packed" if op in TRI_OPS else "full")
    counts = copy_op_counts(ops.PALLAS_OPS[op], *operands, knob=knob,
                            interpret=True)
    assert counts == {}, (op, counts)


def test_trsm_has_no_pad():
    """TRSM's substitution loop slices block rows (that's the algorithm)
    but never pads an operand — the identity-padded diagonal is gone."""
    operands = _jops("trsm", RAGGED["trsm"], seed=3)
    counts = copy_op_counts(ops.PALLAS_OPS["trsm"], *operands, knob=_knob(),
                            interpret=True)
    assert counts.get("pad", 0) == 0, counts


def test_beta_zero_takes_no_c_operand():
    """``beta == 0`` must not materialize (or DMA) a zeros C operand."""
    import jax
    a = jnp.ones((129, 65), jnp.float32)
    b = jnp.ones((65, 257), jnp.float32)

    def n_pallas_inputs(fn, *args, **kw):
        found = []

        def walk(jx):
            for e in jx.eqns:
                if e.primitive.name == "pallas_call":
                    found.append(len(e.invars))
                    continue
                for v in e.params.values():
                    if hasattr(v, "jaxpr"):
                        walk(v.jaxpr)
        walk(jax.make_jaxpr(lambda *xs: fn(*xs, **kw))(*args).jaxpr)
        return found

    assert n_pallas_inputs(
        lambda x, y: gemm_pallas(x, y, interpret=True), a, b) == [2]
    c = jnp.ones((129, 257), jnp.float32)
    assert n_pallas_inputs(
        lambda x, y, z: gemm_pallas(x, y, z, beta=0.5, interpret=True),
        a, b, c) == [3]
    # beta == 0 with a C present: C is still dead — not an input
    assert n_pallas_inputs(
        lambda x, y, z: gemm_pallas(x, y, z, beta=0.0, interpret=True),
        a, b, c) == [2]


@pytest.mark.parametrize("op", TRI_OPS)
def test_packed_grid_is_exactly_triangular(op):
    """tri_packed launches n(n+1)/2 packed blocks — times (k-steps + the
    write-only mirror step) for the rank-k ops, times the n-blocks for
    trmm — vs the full n²-block grid."""
    dims = (1024, 512) if op in ("syrk", "syr2k") else (1024, 512)
    operands = _jops(op, dims, seed=1)
    for variant, want in (
            ("full", full_grid_for(op, dims, 128, 128, 128)),
            ("tri", full_grid_for(op, dims, 128, 128, 128)),
            ("tri_packed", packed_grid_for(op, dims, 128, 128, 128))):
        grids = pallas_grids(ops.PALLAS_OPS[op], *operands,
                             knob=_knob(variant), interpret=True)
        assert grids == [want], (op, variant, grids)
    nb = -(-dims[0] // 128)
    packed = packed_grid_for(op, dims, 128, 128, 128)
    assert tri_count(nb) in packed       # n(n+1)/2 really is a grid dim


def test_detri_is_exact():
    t = jnp.arange(tri_count(64))
    i, j = detri(t)
    i, j = np.asarray(i), np.asarray(j)
    want_i = np.repeat(np.arange(64), np.arange(1, 65))
    want_j = np.concatenate([np.arange(r + 1) for r in range(64)])
    assert np.array_equal(i, want_i) and np.array_equal(j, want_j)


# ---------------------------------------------------------------------------
# knob space: tri_packed is a first-class candidate
# ---------------------------------------------------------------------------

def test_knob_space_exposes_tri_packed():
    for op in TRI_OPS:
        space = ops.knob_space_for(op)
        variants = {c.dict["variant"] for c in space.candidates}
        assert variants == {"full", "tri", "tri_packed"}, op
    # gemm/symm/trsm spaces unchanged
    assert {c.dict["variant"] for c in ops.knob_space_for("gemm")} == \
        {"full"}
    # the baseline (max-parallelism) knob stays the full variant — legacy
    # defaults and decision caches keep meaning what they meant
    for op in TRI_OPS:
        assert ops.default_knob(op).dict["variant"] == "full"


def test_tri_packed_is_feature_distinguishable():
    """The parallelism feature (the paper's nt analogue, the only
    knob-dependent feature channel) must separate tri_packed from full —
    otherwise their Table-III rows are byte-identical and no model could
    ever learn to select the packed variant.  full and tri launch the same
    grid, so those two deliberately share a row (and tie toward full)."""
    for op in TRI_OPS:
        space = ops.knob_space_for(op)
        by_var = {}
        for c in space.candidates:
            d = c.dict
            if d["bm"] == 128 and d["bn"] == 128:
                by_var[d["variant"]] = space.parallelism(c, (2048, 512))
        assert by_var["full"] == by_var["tri"]
        assert by_var["tri_packed"] < by_var["full"]
        cm, cn = 2048 // 128, 512 // 128
        assert by_var["tri_packed"] == (cm + 1) * cn / 2.0
    # degenerate single-block-row shapes tie (nothing to pack)
    space = ops.knob_space_for("syrk")
    for c in space.candidates:
        if c.dict["bm"] == 128 and c.dict["bn"] == 128:
            assert space.parallelism(c, (64, 128)) == 1.0


def test_tri_packed_knob_executes_everywhere():
    """The enlarged candidate set must be *executable* by every backend
    that shares the knob space (calibration sweeps all candidates)."""
    from repro.kernels.cpu_blocked import run_blocked
    for op in TRI_OPS:
        dims = (129, 65)
        operands = make_operands(op, dims, np.float32, seed=2)
        knob = _knob("tri_packed", bm=64, bk=64, bn=64)
        got = run_blocked(op, operands, knob)
        want = run_blocked(op, operands, _knob("full", bm=64, bk=64, bn=64))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_fresh_calibration_covers_tri_packed(tmp_path):
    """A fresh install over the enlarged space produces an artifact whose
    model scores tri_packed candidates — and whose selection executes."""
    from repro.backends import get_backend
    from repro.core import AdsalaRuntime, ModelRegistry, install_backend
    registry = ModelRegistry(tmp_path)
    rt = AdsalaRuntime()
    subs = install_backend(get_backend("cpu_blocked"), ops=("syrk",),
                           n_samples=10, dim_lo=32, dim_hi=128,
                           max_footprint_bytes=1_000_000, tune_trials=1,
                           candidates=("DecisionTree",), runtime=rt,
                           registry=registry, seed=0)
    sub = subs["syrk"]
    variants = {c.dict["variant"] for c in sub.knob_space.candidates}
    assert "tri_packed" in variants
    knob = rt.select("syrk", (96, 64), 4, backend="cpu_blocked")
    assert knob in sub.knob_space.candidates
    # whatever it picked executes correctly (including tri_packed)
    operands = make_operands("syrk", (96, 64), np.float32, seed=3)
    from repro.kernels.cpu_blocked import run_blocked
    got = run_blocked("syrk", operands, knob)
    want = operands[0] @ operands[0].T
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_legacy_artifacts_still_select():
    """Persisted pre-PR-5 artifacts carry their own (smaller) knob spaces;
    they must keep loading and selecting valid, executable knobs."""
    from repro.core import AdsalaRuntime, ModelRegistry
    reg = ModelRegistry("runs/adsala/models")
    rt = AdsalaRuntime()
    if reg.load_into(rt, backend="cpu_blocked") == 0:
        pytest.skip("no persisted artifacts in the repo checkout")
    for op in ("syrk", "trmm"):
        knob = rt.select(op, (160, 96), 4, backend="cpu_blocked")
        d = knob.dict
        assert d["variant"] in ("full", "tri", "tri_packed")
        operands = make_operands(op, (160, 96), np.float32, seed=4)
        from repro.kernels.cpu_blocked import run_blocked
        got = run_blocked(op, operands, knob)
        want = operands[0] @ operands[0].T if op == "syrk" \
            else np.tril(operands[0]) @ operands[1]
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
