"""Tests for the shape-bucketed BLAS serving layer: correctness through the
async path, bucket grouping/flush policy, padding, error propagation,
per-bucket stats, and warm-start via the persisted decision cache."""

import numpy as np
import pytest

from repro.backends import get_backend
from repro.backends.conformance import oracle
from repro.core import AdsalaRuntime, ModelRegistry, install_backend
from repro.serving import BlasService, ServeConfig, bucket_key
from repro.serving.service import SERVABLE_OPS


def make(op, dims, seed=0, dtype=np.float32):
    return get_backend("ref").make_operands(op, dims, dtype, seed=seed)


def test_serve_config_validation():
    with pytest.raises(ValueError):
        ServeConfig(max_batch=0)
    with pytest.raises(ValueError):
        ServeConfig(workers=0)
    with pytest.raises(ValueError):
        ServeConfig(linger_ms=-1)


def test_bucket_key_splits_on_shape_dtype_backend_and_scalars():
    a32 = [(48, 32), (32, 40)]
    f32x2 = [np.float32, np.float32]
    base = bucket_key("gemm", a32, f32x2, "ref")
    assert base == ("ref", "gemm", 4, (48, 32, 40),
                    ("float32", "float32"), ())
    assert bucket_key("gemm", a32, [np.float64] * 2, "ref") != base
    assert bucket_key("gemm", a32, f32x2, "pallas") != base
    assert bucket_key("gemm", [(48, 32), (32, 48)], f32x2, "ref") != base
    assert bucket_key("gemm", a32, f32x2, "ref", (("alpha", 2.0),)) != base
    # equal itemsize must NOT merge distinct dtypes (f32 vs i32 would
    # silently promote under np.stack) — in ANY operand position
    assert bucket_key("gemm", a32, [np.int32, np.int32], "ref") != base
    assert bucket_key("gemm", a32, [np.float32, np.float64], "ref") != base


def test_mixed_traffic_round_trip():
    rt = AdsalaRuntime()
    cfg = ServeConfig(backend="ref", max_batch=8, linger_ms=2.0)
    cases = []
    with BlasService(runtime=rt, config=cfg) as svc:
        for i in range(30):
            op = SERVABLE_OPS[i % len(SERVABLE_OPS)]
            dims = {"gemm": (48, 32, 40), "symm": (48, 40),
                    "syrk": (48, 32), "syr2k": (48, 32),
                    "trmm": (48, 40), "trsm": (48, 40)}[op]
            operands = make(op, dims, seed=i)
            cases.append((op, operands, svc.submit(op, operands)))
        for op, operands, fut in cases:
            got = np.asarray(fut.result(timeout=30), np.float64)
            want = oracle(op, operands)
            rel = np.max(np.abs(got - want)) / (np.max(np.abs(want)) + 1e-9)
            assert rel < 5e-4, (op, rel)
    assert svc.stats.completed == 30 and svc.stats.failed == 0


def test_full_bucket_flushes_as_one_batch():
    rt = AdsalaRuntime()
    cfg = ServeConfig(backend="ref", max_batch=8, linger_ms=60_000.0,
                      min_steal=8)     # no early steal: deterministic batch
    with BlasService(runtime=rt, config=cfg) as svc:
        futs = [svc.submit("gemm", make("gemm", (32, 32, 32), seed=i))
                for i in range(8)]
        for f in futs:
            f.result(timeout=30)     # resolves without any linger expiry
        assert svc.stats.batches == 1
        assert svc.stats.max_batch == 8
        key = ("ref", "gemm", 4, (32, 32, 32))
        b = svc.bucket_stats()[key]
        assert (b.batches, b.requests, b.max_batch) == (1, 8, 8)
    assert rt.stats.calls == 1       # ONE knob decision for all 8 requests


def test_linger_flushes_partial_bucket():
    rt = AdsalaRuntime()
    cfg = ServeConfig(backend="ref", max_batch=1000, linger_ms=30.0)
    with BlasService(runtime=rt, config=cfg) as svc:
        futs = [svc.submit("gemm", make("gemm", (32, 32, 32), seed=i))
                for i in range(3)]
        for f in futs:
            f.result(timeout=30)
        assert svc.stats.batches == 1          # one linger-triggered flush
        assert svc.stats.completed == 3


def test_padding_to_canonical_width():
    rt = AdsalaRuntime()
    cfg = ServeConfig(backend="ref", max_batch=16, linger_ms=10.0,
                      pad_batches=True)
    with BlasService(runtime=rt, config=cfg) as svc:
        futs = [svc.submit("gemm", make("gemm", (32, 32, 32), seed=i))
                for i in range(3)]
        outs = [np.asarray(f.result(timeout=30)) for f in futs]
    assert svc.stats.padded_items == 1         # 3 → width 4
    for i, out in enumerate(outs):             # padding never leaks out
        want = oracle("gemm", make("gemm", (32, 32, 32), seed=i))
        assert np.max(np.abs(out - want)) / np.max(np.abs(want)) < 5e-4


def test_loop_backends_are_not_padded():
    rt = AdsalaRuntime()
    cfg = ServeConfig(backend="cpu_blocked", max_batch=16, linger_ms=10.0,
                      pad_batches=True)
    with BlasService(runtime=rt, config=cfg) as svc:
        futs = [svc.submit("gemm", make("gemm", (32, 32, 32), seed=i))
                for i in range(3)]
        for f in futs:
            f.result(timeout=30)
    # cpu_blocked executes stacks as a loop — padding would be wasted ops
    assert svc.stats.padded_items == 0


def test_scalar_kwargs_get_their_own_bucket():
    rt = AdsalaRuntime()
    cfg = ServeConfig(backend="ref", max_batch=8, linger_ms=10.0)
    operands = make("gemm", (32, 32, 32), seed=1)
    with BlasService(runtime=rt, config=cfg) as svc:
        f1 = svc.submit("gemm", operands)
        f2 = svc.submit("gemm", operands, alpha=2.0)
        r1 = np.asarray(f1.result(timeout=30))
        r2 = np.asarray(f2.result(timeout=30))
    assert svc.stats.batches == 2              # alpha split the bucket
    np.testing.assert_allclose(2.0 * r1, r2, rtol=1e-5)


def test_execution_error_fails_the_whole_bucket():
    rt = AdsalaRuntime()
    cfg = ServeConfig(backend="ref", max_batch=4, linger_ms=5.0)
    bad = (np.ones((8, 8), np.float32), np.ones((4, 4), np.float32))
    with BlasService(runtime=rt, config=cfg) as svc:
        futs = [svc.submit("gemm", bad) for _ in range(2)]
        for f in futs:
            with pytest.raises(Exception):
                f.result(timeout=30)
    assert svc.stats.failed == 2 and svc.stats.completed == 0


def test_submit_validation():
    with BlasService(runtime=AdsalaRuntime(),
                     config=ServeConfig(backend="ref")) as svc:
        with pytest.raises(ValueError, match="unknown op"):
            svc.submit("axpy", (np.ones((4, 4), np.float32),))
        with pytest.raises(ValueError, match="2-D"):
            svc.submit("gemm", (np.ones((2, 4, 4), np.float32),
                                np.ones((2, 4, 4), np.float32)))


def test_submit_after_close_raises():
    svc = BlasService(runtime=AdsalaRuntime(),
                      config=ServeConfig(backend="ref"))
    svc.close()
    svc.close()                                 # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit("gemm", make("gemm", (32, 32, 32)))


def test_backpressure_bound_still_completes():
    rt = AdsalaRuntime()
    cfg = ServeConfig(backend="ref", max_batch=4, linger_ms=1.0,
                      max_pending=8)
    with BlasService(runtime=rt, config=cfg) as svc:
        futs = [svc.submit("gemm", make("gemm", (32, 32, 32), seed=i))
                for i in range(40)]             # 5× the pending bound
        for f in futs:
            f.result(timeout=60)
    assert svc.stats.completed == 40


@pytest.mark.slow
def test_warm_start_skips_model_evals(tmp_path):
    """Cold server evaluates models once per shape; a restarted server
    warm-started from the persisted decision cache evaluates none."""
    registry = ModelRegistry(tmp_path)
    install_backend(get_backend("ref"), ops=("gemm",), n_samples=12,
                    dim_lo=32, dim_hi=128, max_footprint_bytes=1_000_000,
                    tune_trials=1, candidates=("LinearRegression",),
                    registry=registry, seed=0)
    shapes = [(32, 32, 32), (64, 32, 64), (96, 96, 96)]

    def serve(runtime):
        cfg = ServeConfig(backend="ref", max_batch=4, linger_ms=2.0)
        with BlasService(runtime=runtime, config=cfg,
                         registry=registry) as svc:
            warm = svc.warm_started
            futs = [svc.submit("gemm", make("gemm", dims, seed=i))
                    for i, dims in enumerate(shapes * 3)]
            for f in futs:
                f.result(timeout=30)
        return warm

    cold_rt = AdsalaRuntime()
    registry.load_into(cold_rt)
    assert serve(cold_rt) == 0
    assert cold_rt.stats.model_evals == len(shapes)
    assert registry.decision_cache_path.exists()

    warm_rt = AdsalaRuntime()
    registry.load_into(warm_rt)
    assert serve(warm_rt) == len(shapes)
    assert warm_rt.stats.model_evals == 0
    assert warm_rt.stats.cache_hits == warm_rt.stats.calls


def test_trace_batching_auto_installs_and_restores():
    """ServeConfig(trace_batching="auto") installs the process-wide
    trace-time decision batcher for the service's lifetime and restores
    the previous one (normally none) on close."""
    from repro.kernels import ops as kops

    assert kops._TRACE_BATCHER is None
    svc = BlasService(runtime=AdsalaRuntime(),
                      config=ServeConfig(backend="ref",
                                         trace_batching="auto"))
    try:
        assert kops._TRACE_BATCHER is svc.trace_batcher is not None
        futs = [svc.submit("gemm", make("gemm", (32, 32, 32), seed=i))
                for i in range(6)]
        for f in futs:
            out = f.result(timeout=30)
            assert out.shape == (32, 32)
    finally:
        svc.close()
    assert kops._TRACE_BATCHER is None
    assert svc.trace_batcher.batches >= 0     # introspection stays readable


def test_trace_batching_defaults_off():
    from repro.kernels import ops as kops
    with BlasService(runtime=AdsalaRuntime(),
                     config=ServeConfig(backend="ref")) as svc:
        assert svc.trace_batcher is None
        assert kops._TRACE_BATCHER is None


def test_trace_batching_restores_previous_batcher():
    """A service-scoped batcher nests inside an explicitly installed one."""
    from repro.kernels import ops as kops
    outer = kops.enable_trace_batching()
    try:
        with BlasService(runtime=AdsalaRuntime(),
                         config=ServeConfig(backend="ref",
                                            trace_batching=True)) as svc:
            assert kops._TRACE_BATCHER is svc.trace_batcher is not outer
        assert kops._TRACE_BATCHER is outer
    finally:
        kops.disable_trace_batching()
